"""Version compatibility shims for the jax API surface this repo uses.

The repo targets the modern spellings (``jax.shard_map`` with
``check_vma``, dict-valued ``Compiled.cost_analysis()``); older jaxlib
builds (0.4.x) ship ``jax.experimental.shard_map.shard_map`` with
``check_rep`` and return ``cost_analysis()`` as a one-element list.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "cost_analysis_dict"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict across jax versions."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
