"""Project-specific static analysis: the repo's own invariants as a gate.

Five AST-based passes over the codebase (``python -m repro.analysis``):

  - ``units``          — _us/_ns suffix discipline (UNITS001/002)
  - ``engine-parity``  — SimRunConfig fields vs the batched engine
                         (PARITY001/002)
  - ``scan-purity``    — lax.scan/jit/vmap body hygiene (SCAN001–004)
  - ``lock-discipline``— TryLock/threading.Lock rules (LOCK001–003)
  - ``races``          — Eraser-style shared-state lockset analysis
                         over thread entry points (RACE001–003)

Stdlib-only (``ast`` + ``json``): importable and runnable without jax,
so the CI gate costs seconds.  See ``repro.analysis.core`` for the
framework, ``repro.analysis.sanitizer`` for the dynamic counterpart
that confirms or refutes RACE findings against real threaded runs, and
``analysis_baseline.json`` for grandfathered findings.
"""

from .core import (
    AnalysisPass,
    AnalysisResult,
    Baseline,
    Finding,
    SourceFile,
    collect_files,
    register,
    registered_passes,
    run_analysis,
)
from .locks import LockDisciplinePass
from .parity import EngineParityPass
from .races import RacePass
from .scanpurity import ScanPurityPass
from .units import UnitsPass

__all__ = [
    "AnalysisPass",
    "AnalysisResult",
    "Baseline",
    "Finding",
    "SourceFile",
    "collect_files",
    "register",
    "registered_passes",
    "run_analysis",
    "UnitsPass",
    "EngineParityPass",
    "ScanPurityPass",
    "LockDisciplinePass",
    "RacePass",
]
