"""Engine-parity pass: the two simulation engines share one config.

``SimRunConfig`` (defined in ``simcore.py``) is the single environment
surface for both the exact event engine and the batched JAX engine.
PR 3/4 kept them in sync with a hand-maintained drift guard
(``unsupported_config_fields`` over module-level ``*_FIELDS`` tuples in
``batched.py``); this pass derives the guard instead of trusting it:

  - **PARITY001** — a ``SimRunConfig`` field is neither read as
    ``cfg.<field>`` in the batched engine module nor named in one of
    its module-level ``*_FIELDS`` tuples.  Adding a config knob that
    the event engine honors and the batched engine silently ignores is
    exactly how the engines drift apart.
  - **PARITY002** — a ``*_FIELDS`` entry is stale: it names something
    that is no longer a ``SimRunConfig`` field, or a field the batched
    engine now *does* read (the declaration claims unsupported, the
    code says otherwise).

File discovery is structural, not hard-wired: any scanned file defining
``class SimRunConfig`` is paired with every sibling engine module
(``batched.py`` and, when present, the event-jump kernel
``batched_adaptive.py``) in the same directory, so fixture mini-repos
exercise the pass the same way ``src/repro/runtime`` does — each engine
file must independently read-or-declare every config field.
"""

from __future__ import annotations

import ast

from .core import ERROR, AnalysisPass, Finding, SourceFile, register

__all__ = ["EngineParityPass"]

CONFIG_CLASS = "SimRunConfig"
ENGINE_BASENAMES = ("batched.py", "batched_adaptive.py")
# attribute bases that denote "the config object" in the engine module
CONFIG_BASES = ("cfg", "config")


def _config_fields(sf: SourceFile) -> dict[str, int] | None:
    """``{field: lineno}`` for the config dataclass, or None if this
    file doesn't define it.  Fields are the class body's annotated
    assignments — properties and methods are behavior, not config."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef) and node.name == CONFIG_CLASS:
            return {st.target.id: st.lineno for st in node.body
                    if isinstance(st, ast.AnnAssign)
                    and isinstance(st.target, ast.Name)
                    and not st.target.id.startswith("_")}
    return None


def _is_config_base(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id in CONFIG_BASES
    if isinstance(node, ast.Attribute):          # self.cfg.<field>
        return node.attr in CONFIG_BASES
    return False


def _engine_reads(sf: SourceFile) -> set[str]:
    """Field names the engine module reads off a config object, either
    as ``cfg.<field>`` attribute access or dynamically via
    ``getattr(cfg, <literal>)``."""
    reads: set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Attribute) and _is_config_base(node.value):
            reads.add(node.attr)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Name)
              and node.func.id == "getattr"
              and len(node.args) >= 2
              and _is_config_base(node.args[0])
              and isinstance(node.args[1], ast.Constant)
              and isinstance(node.args[1].value, str)):
            reads.add(node.args[1].value)
    return reads


def _declared_fields(sf: SourceFile) -> dict[str, tuple[int, str]]:
    """Entries of module-level ``*_FIELDS`` tuple assignments:
    ``{field: (lineno, tuple_name)}``."""
    out: dict[str, tuple[int, str]] = {}
    for node in sf.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Name) and tgt.id.endswith("_FIELDS")):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            for elt in node.value.elts:
                if (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, str)):
                    out[elt.value] = (elt.lineno, tgt.id)
    return out


@register
class EngineParityPass(AnalysisPass):
    name = "engine-parity"
    rules = {
        "PARITY001": ("SimRunConfig field is neither read by the "
                      "batched engine module nor declared in one of "
                      "its *_FIELDS tuples"),
        "PARITY002": ("stale *_FIELDS entry: not a SimRunConfig field, "
                      "or a field the batched engine actually reads"),
    }

    def run(self, files: list[SourceFile]) -> list[Finding]:
        findings: list[Finding] = []
        by_dir = {}
        for sf in files:
            by_dir.setdefault(sf.path.parent, []).append(sf)
        for sf in files:
            fields = _config_fields(sf)
            if fields is None:
                continue
            engines = [e for e in by_dir.get(sf.path.parent, [])
                       if e.path.name in ENGINE_BASENAMES]
            for engine in engines:
                findings.extend(self._check_pair(sf, engine, fields))
        return findings

    def _check_pair(self, config_sf: SourceFile, engine_sf: SourceFile,
                    fields: dict[str, int]) -> list[Finding]:
        reads = _engine_reads(engine_sf)
        declared = _declared_fields(engine_sf)
        out: list[Finding] = []
        for fld, lineno in sorted(fields.items()):
            if fld not in reads and fld not in declared:
                out.append(Finding(
                    rule="PARITY001", severity=ERROR, path=config_sf.rel,
                    line=lineno, col=0,
                    message=(f"{CONFIG_CLASS}.{fld} is not read by "
                             f"{engine_sf.rel} and not declared in any "
                             "of its *_FIELDS tuples: the batched "
                             "engine would silently ignore it")))
        for fld, (lineno, tup) in sorted(declared.items()):
            if fld not in fields:
                out.append(Finding(
                    rule="PARITY002", severity=ERROR, path=engine_sf.rel,
                    line=lineno, col=0,
                    message=(f"stale {tup} entry '{fld}': no such "
                             f"{CONFIG_CLASS} field in {config_sf.rel}")))
            elif fld in reads:
                out.append(Finding(
                    rule="PARITY002", severity=ERROR, path=engine_sf.rel,
                    line=lineno, col=0,
                    message=(f"stale {tup} entry '{fld}': the engine "
                             "module reads this field, so the "
                             "declaration no longer matches the code")))
        return out
