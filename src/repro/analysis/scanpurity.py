"""Scan-purity pass: traced JAX bodies must stay pure and un-shadowed.

Bodies traced by ``lax.scan`` / ``jax.jit`` / ``jax.vmap`` execute once
at trace time; Python-level effects inside them are silently frozen or
simply wrong.  PR 5 shipped (and had to fix) the canonical instance: a
local variable in a scan ``step`` clobbered a same-named carry element,
so the carry returned the local's value and the accumulator was lost.
Four rules over every traced body found in the scanned files:

  - **SCAN001** — carry-tuple hazards in ``lax.scan`` bodies: a carry
    element is overwritten before it is ever read (the RHS does not
    mention it — the PR-5 bug class: the carried value is silently
    dropped), or a carry name shadows a variable of the enclosing
    function (one name, two meanings at trace time).
  - **SCAN002** — calls into Python's ``random`` / ``time`` /
    ``datetime`` or ``numpy.random`` inside a traced body: these run
    once at trace and bake a constant into the compiled program.
  - **SCAN003** — mutation of closed-over state (``x[i] = ...``,
    ``x.append(...)`` on a free variable): a trace-time side effect
    that will not re-run per step/batch element.
  - **SCAN004** — ``float()`` / ``int()`` / ``bool()`` or Python
    ``if``/``while`` applied to tracer-derived names (function params,
    carry elements, and anything assigned from them): concretization
    errors waiting to happen once the body is actually traced.

Traced bodies are discovered structurally: first argument of
``lax.scan`` calls, functions wrapped in ``jax.jit``/``jax.vmap``/
``jax.grad`` (including ``functools.partial(jax.jit, ...)``
decorators).  Import aliases are resolved, so ``from jax import jit``
and ``import jax.numpy as jnp`` both work.
"""

from __future__ import annotations

import ast

from .core import ERROR, AnalysisPass, Finding, SourceFile, register

__all__ = ["ScanPurityPass"]

_IMPURE_PREFIXES = ("random.", "time.", "datetime.",
                    "numpy.random.", "np.random.")
_IMPURE_EXACT = {"random", "time", "datetime"}
_MUTATORS = {"append", "extend", "insert", "add", "update", "pop",
             "popitem", "remove", "discard", "clear", "setdefault",
             "sort", "reverse"}
_CASTS = {"float", "int", "bool"}


def _resolve_imports(tree: ast.Module) -> dict[str, str]:
    """Local name -> dotted module path, from import statements."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` chain as a string, or None for non-trivial bases."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _TracedBody:
    """One body to check: the function/lambda node, whether it is a
    ``lax.scan`` step (carry semantics apply), its enclosing function
    chain (for shadow detection), and any ``static_argnames``/
    ``static_argnums`` params (static at trace time, not tracers)."""

    def __init__(self, fn, is_scan_step: bool, ancestors: list,
                 static_names: frozenset[str] = frozenset()):
        self.fn = fn
        self.is_scan_step = is_scan_step
        self.ancestors = ancestors
        self.static_names = static_names


def _parent_map(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _enclosing_functions(node: ast.AST,
                         parents: dict[ast.AST, ast.AST]) -> list:
    out = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            out.append(cur)
        cur = parents.get(cur)
    return out


def _find_traced_bodies(sf: SourceFile,
                        imports: dict[str, str]) -> list[_TracedBody]:
    parents = _parent_map(sf.tree)
    defs_by_name: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef):
            defs_by_name.setdefault(node.name, []).append(node)

    def qualified(call_func: ast.AST) -> str:
        d = _dotted(call_func) or ""
        head, _, rest = d.partition(".")
        base = imports.get(head, head)
        return f"{base}.{rest}" if rest else base

    def is_scan(call: ast.Call) -> bool:
        q = qualified(call.func)
        return q.endswith("lax.scan") or q == "jax.lax.scan"

    def is_tracer_wrap(func: ast.AST) -> bool:
        q = qualified(func)
        return q in ("jax.jit", "jax.vmap", "jax.grad",
                     "jax.value_and_grad", "jax.pmap", "jax.checkpoint",
                     "jax.remat")

    bodies: list[_TracedBody] = []
    seen: set[int] = set()

    def add(fn, is_scan_step: bool,
            static: tuple[list[str], list[int]] = ([], [])) -> None:
        if fn is None or id(fn) in seen:
            return
        seen.add(id(fn))
        names, nums = static
        static_names = set(names)
        if nums and not isinstance(fn, ast.Lambda):
            params = _param_names(fn)
            static_names |= {params[i] for i in nums
                             if 0 <= i < len(params)}
        bodies.append(_TracedBody(fn, is_scan_step,
                                  _enclosing_functions(fn, parents),
                                  frozenset(static_names)))

    def static_args(call: ast.Call) -> tuple[list[str], list[int]]:
        """static_argnames/static_argnums of a jit-style call."""
        names: list[str] = []
        nums: list[int] = []
        for kw in call.keywords:
            vals = (kw.value.elts
                    if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            consts = [v.value for v in vals
                      if isinstance(v, ast.Constant)]
            if kw.arg == "static_argnames":
                names.extend(c for c in consts if isinstance(c, str))
            elif kw.arg == "static_argnums":
                nums.extend(c for c in consts if isinstance(c, int))
        return names, nums

    def resolve_fn_arg(node: ast.AST):
        """A function argument: lambda, local def by name, or a nested
        tracer wrap (``jax.jit(jax.vmap(f))``)."""
        if isinstance(node, ast.Lambda):
            return node
        if isinstance(node, ast.Name):
            cands = defs_by_name.get(node.id, [])
            return cands[-1] if cands else None
        if isinstance(node, ast.Call) and is_tracer_wrap(node.func):
            return resolve_fn_arg(node.args[0]) if node.args else None
        return None

    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            if is_scan(node) and node.args:
                add(resolve_fn_arg(node.args[0]), True)
            elif is_tracer_wrap(node.func) and node.args:
                add(resolve_fn_arg(node.args[0]), False,
                    static_args(node))
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if is_tracer_wrap(dec):
                    add(node, False)
                elif (isinstance(dec, ast.Call)
                      and qualified(dec.func).endswith("partial")
                      and dec.args and is_tracer_wrap(dec.args[0])):
                    add(node, False, static_args(dec))
                elif isinstance(dec, ast.Call) and is_tracer_wrap(dec.func):
                    add(node, False, static_args(dec))
    return bodies


def _body_stmts(fn) -> list[ast.stmt]:
    if isinstance(fn, ast.Lambda):
        return []               # expression bodies: nothing to unpack
    return fn.body


def _param_names(fn) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _assigned_names(fn) -> set[str]:
    """Names bound anywhere in ``fn``, nested functions included —
    used to decide free-vs-local for the mutation rule (conservative:
    a name bound anywhere inside is treated as local)."""
    out: set[str] = set(_param_names(fn)) if not isinstance(
        fn, ast.Lambda) else set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store,)):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(node.name)
    return out


def _scope_bindings(fn) -> dict[str, int]:
    """Names bound in ``fn``'s own scope only (nested function bodies
    excluded) -> first binding line.  Params bind at the def line."""
    out: dict[str, int] = {}
    if isinstance(fn, ast.Lambda):
        return out
    for p in _param_names(fn):
        out[p] = fn.lineno

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef,
                                  ast.AsyncFunctionDef)):
                out.setdefault(child.name, child.lineno)
                continue                    # don't enter nested scopes
            if isinstance(child, ast.Lambda):
                continue
            if isinstance(child, ast.Name) and isinstance(
                    child.ctx, ast.Store):
                out.setdefault(child.id, child.lineno)
            walk(child)

    for st in fn.body:
        walk(st)
        if isinstance(st, ast.Name) and isinstance(st.ctx, ast.Store):
            out.setdefault(st.id, st.lineno)
    return out


def _flatten_target(node: ast.AST) -> list[str]:
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            out.extend(_flatten_target(e))
        return out
    return []


def _loads_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


# attribute reads that are static under tracing: branching on a
# tracer's shape/dtype is fine, branching on its *value* is not
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "weak_type"}


def _value_loads_in(node: ast.AST) -> set[str]:
    """Like ``_loads_in`` but skips subtrees under static attribute
    access (``x.shape``, ``x.ndim`` ...): those reads never
    concretize a tracer's value."""
    out: set[str] = set()

    def walk(n: ast.AST) -> None:
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
            out.add(n.id)
        for child in ast.iter_child_nodes(n):
            walk(child)

    walk(node)
    return out


@register
class ScanPurityPass(AnalysisPass):
    name = "scan-purity"
    rules = {
        "SCAN001": ("lax.scan carry hazard: carry element overwritten "
                    "before any read (PR-5 bug class) or carry name "
                    "shadows an enclosing-scope variable"),
        "SCAN002": ("Python random/time/datetime (or numpy.random) "
                    "call inside a traced body: runs once at trace "
                    "time, not per step"),
        "SCAN003": ("mutation of closed-over state inside a traced "
                    "body: a trace-time side effect"),
        "SCAN004": ("float()/int()/bool() or Python if/while on a "
                    "tracer-derived name inside a traced body"),
    }

    def run(self, files: list[SourceFile]) -> list[Finding]:
        out: list[Finding] = []
        for sf in files:
            imports = _resolve_imports(sf.tree)
            for body in _find_traced_bodies(sf, imports):
                out.extend(_check_body(sf, body, imports))
        # nested traced bodies are walked twice (own pass + enclosing
        # body's walk): dedupe identical findings
        uniq, seen = [], set()
        for f in out:
            key = (f.rule, f.path, f.line, f.col, f.message)
            if key not in seen:
                seen.add(key)
                uniq.append(f)
        return uniq


def _check_body(sf: SourceFile, body: _TracedBody,
                imports: dict[str, str]) -> list[Finding]:
    fn = body.fn
    findings: list[Finding] = []
    params = _param_names(fn) if not isinstance(fn, ast.Lambda) else [
        p.arg for p in fn.args.args]

    # -- carry analysis (scan steps only) ---------------------------------------
    carry_elems: list[str] = []
    unpack_stmt: ast.stmt | None = None
    if body.is_scan_step and params:
        carry_param = params[0]
        for st in _body_stmts(fn):
            if (isinstance(st, ast.Assign) and len(st.targets) == 1
                    and isinstance(st.value, ast.Name)
                    and st.value.id == carry_param
                    and isinstance(st.targets[0], (ast.Tuple, ast.List))):
                carry_elems = _flatten_target(st.targets[0])
                unpack_stmt = st
                break

    if carry_elems:
        # shadowing of enclosing-scope names bound BEFORE the body's
        # def: a later `(a, b), _ = lax.scan(step, ...)` result unpack
        # is the idiom, not a hazard
        outer: set[str] = set()
        for anc in body.ancestors:
            outer |= {nm for nm, line in _scope_bindings(anc).items()
                      if line < fn.lineno}
        for nm in carry_elems:
            if nm in outer:
                findings.append(Finding(
                    rule="SCAN001", severity=ERROR, path=sf.rel,
                    line=unpack_stmt.lineno, col=unpack_stmt.col_offset,
                    message=(f"carry element '{nm}' shadows a variable "
                             "of the enclosing function: one name, two "
                             "meanings at trace time")))
        findings.extend(_check_dead_overwrite(
            sf, fn, carry_elems, unpack_stmt))

    # -- walk the body for impurity / mutation / concretization ------------------
    local = _assigned_names(fn)
    tainted = (set(params) | set(carry_elems)) - body.static_names
    # forward taint propagation to a fixpoint (bounded)
    for _ in range(3):
        grew = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if _loads_in(node.value) & tainted:
                    for tgt in node.targets:
                        for nm in _flatten_target(tgt):
                            if nm not in tainted:
                                tainted.add(nm)
                                grew = True
        if not grew:
            break

    def qualified(call_func: ast.AST) -> str:
        d = _dotted(call_func) or ""
        head, _, rest = d.partition(".")
        base = imports.get(head, head)
        return f"{base}.{rest}" if rest else base

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            q = qualified(node.func)
            if (q in _IMPURE_EXACT
                    or any(q.startswith(p) for p in _IMPURE_PREFIXES)):
                findings.append(Finding(
                    rule="SCAN002", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"call to '{q}' inside a traced body runs "
                             "at trace time, not per step")))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _MUTATORS):
                root = _dotted(node.func.value)
                root_head = root.split(".")[0] if root else None
                if root_head and root_head not in local:
                    findings.append(Finding(
                        rule="SCAN003", severity=ERROR, path=sf.rel,
                        line=node.lineno, col=node.col_offset,
                        message=(f"'{root}.{node.func.attr}(...)' "
                                 "mutates closed-over state inside a "
                                 "traced body")))
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in _CASTS
                  and any(_value_loads_in(a) & tainted
                          for a in node.args)):
                findings.append(Finding(
                    rule="SCAN004", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"{node.func.id}() on a tracer-derived "
                             "value inside a traced body forces "
                             "concretization")))
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    root = _dotted(tgt.value if isinstance(
                        tgt, ast.Subscript) else tgt.value)
                    root_head = root.split(".")[0] if root else None
                    if root_head and root_head not in local:
                        findings.append(Finding(
                            rule="SCAN003", severity=ERROR, path=sf.rel,
                            line=node.lineno, col=node.col_offset,
                            message=(f"write to closed-over '{root}' "
                                     "inside a traced body is a "
                                     "trace-time side effect")))
        elif isinstance(node, (ast.If, ast.While)):
            hot = _value_loads_in(node.test) & tainted
            if hot:
                nm = sorted(hot)[0]
                findings.append(Finding(
                    rule="SCAN004", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"Python {'if' if isinstance(node, ast.If) else 'while'} "
                             f"on tracer-derived '{nm}' inside a traced "
                             "body; use lax.cond/jnp.where")))
    return findings


def _check_dead_overwrite(sf: SourceFile, fn, carry_elems: list[str],
                          unpack_stmt: ast.stmt) -> list[Finding]:
    """First event per carry element must not be a store whose RHS
    ignores it: that drops the carried value on the floor (the PR-5
    ``win`` bug)."""
    events: dict[str, list[tuple[int, int, str]]] = {
        nm: [] for nm in carry_elems}
    for node in ast.walk(fn):
        if node is unpack_stmt:
            continue
        if isinstance(node, ast.Assign):
            reads = _loads_in(node.value)
            for tgt in node.targets:
                for nm in _flatten_target(tgt):
                    if nm in events:
                        kind = "read" if nm in reads else "store"
                        events[nm].append(
                            (node.lineno, node.col_offset, kind))
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in events:
                events[node.id].append(
                    (node.lineno, node.col_offset, "read"))
    # drop the unpack statement's own loads (the carry param read)
    out: list[Finding] = []
    for nm, evs in events.items():
        evs = [e for e in evs if e[0] != unpack_stmt.lineno]
        if not evs:
            continue
        evs.sort()
        line, col, kind = evs[0]
        if kind == "store":
            out.append(Finding(
                rule="SCAN001", severity=ERROR, path=sf.rel,
                line=line, col=col,
                message=(f"carry element '{nm}' is overwritten before "
                         "it is ever read: the carried value is "
                         "silently dropped (PR-5 bug class)")))
    return out
