"""Framework for the project-specific static-analysis suite.

Generic linters can't see this repo's invariants: microsecond vs
nanosecond naming discipline, the two simulation engines that must stay
field-for-field in sync, ``lax.scan`` bodies that must stay pure and
un-shadowed, and the ``TryLock``/``threading.Lock`` discipline the
threaded ``Runtime`` depends on.  Each of those is an AST-checkable
property; this module provides the shared machinery:

  - ``SourceFile``: a parsed file handed to every pass;
  - ``Finding``: one diagnostic with a stable ``fingerprint`` (rule +
    path + message — deliberately line-free, so baselines survive
    unrelated edits);
  - ``AnalysisPass``: the pass protocol plus the ``@register`` registry;
  - ``Baseline``: a JSON-persistable multiset of grandfathered
    fingerprints (``analysis_baseline.json``) — findings matched by the
    baseline are reported but don't gate; *new* findings fail the run;
  - ``run_analysis``: collect files, run every registered pass, split
    findings into new vs baselined.

The suite is stdlib-only on purpose (``ast`` + ``json``): the CI gate
must run in seconds on a bare Python, before any jax install.
"""

from __future__ import annotations

import ast
import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path

__all__ = [
    "SourceFile",
    "Finding",
    "AnalysisPass",
    "Baseline",
    "AnalysisResult",
    "register",
    "registered_passes",
    "collect_files",
    "run_analysis",
]

ERROR = "error"
WARNING = "warning"


@dataclass(frozen=True)
class SourceFile:
    """One parsed input: absolute path, repo-relative posix path (the
    identity used in findings and baselines), source text, AST."""

    path: Path
    rel: str
    text: str
    tree: ast.Module


@dataclass(frozen=True)
class Finding:
    rule: str                # e.g. "UNITS001"
    severity: str            # "error" | "warning"
    path: str                # repo-relative posix path
    line: int
    col: int
    message: str

    @property
    def fingerprint(self) -> str:
        """Stable identity for baseline matching.  Excludes line/col so
        grandfathered findings survive edits elsewhere in the file; the
        message must therefore not embed line numbers (pass authors'
        contract)."""
        raw = f"{self.rule}|{self.path}|{self.message}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message, "fingerprint": self.fingerprint}


class AnalysisPass:
    """One analysis: ``run`` sees every collected file at once (passes
    like engine-parity correlate across files).  Subclasses set ``name``
    and ``rules`` (rule id -> one-line description, surfaced by
    ``--list-rules`` and the README)."""

    name: str = ""
    rules: dict[str, str] = {}

    def run(self, files: list[SourceFile]) -> list[Finding]:
        raise NotImplementedError


_REGISTRY: list[AnalysisPass] = []


def register(cls):
    """Class decorator: instantiate and add to the global pass list."""
    _REGISTRY.append(cls())
    return cls


def registered_passes() -> list[AnalysisPass]:
    # import for side effect: each pass module registers itself
    from . import locks, parity, races, scanpurity, units  # noqa: F401
    return list(_REGISTRY)


@dataclass
class Baseline:
    """Grandfathered findings as a fingerprint multiset.  Multiset (not
    set) semantics: if the baseline holds two findings with one
    fingerprint and a third identical one appears, the third is new."""

    counts: dict[str, int] = field(default_factory=dict)
    entries: list[dict] = field(default_factory=list)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        entries = data.get("findings", [])
        counts: dict[str, int] = {}
        for e in entries:
            fp = e["fingerprint"]
            counts[fp] = counts.get(fp, 0) + 1
        return cls(counts=counts, entries=entries)

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        entries = [f.to_json() for f in findings]
        counts: dict[str, int] = {}
        for f in findings:
            counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
        return cls(counts=counts, entries=entries)

    def save(self, path: Path) -> None:
        payload = {
            "schema": "repro-analysis-baseline/1",
            "note": ("Grandfathered static-analysis findings. "
                     "Refresh with: python -m repro.analysis "
                     "--update-baseline.  New findings (not listed "
                     "here) fail the run."),
            "findings": sorted(self.entries,
                               key=lambda e: (e["path"], e["rule"],
                                              e["line"])),
        }
        path.write_text(json.dumps(payload, indent=2) + "\n")

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding]]:
        """(new, grandfathered) under multiset matching."""
        budget = dict(self.counts)
        new, old = [], []
        for f in findings:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
                old.append(f)
            else:
                new.append(f)
        return new, old


@dataclass
class AnalysisResult:
    files: list[SourceFile]
    findings: list[Finding]          # everything, sorted
    new: list[Finding]               # not covered by the baseline
    grandfathered: list[Finding]

    @property
    def ok(self) -> bool:
        return not self.new


def collect_files(paths: list[Path], root: Path) -> list[SourceFile]:
    """Expand files/directories into parsed ``SourceFile``s.  Files that
    fail to parse are skipped — syntax errors are the compiler's job,
    not this suite's (and CI's test job would already be red)."""
    seen: set[Path] = set()
    out: list[SourceFile] = []
    for p in paths:
        p = p.resolve()
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in candidates:
            if f in seen or f.suffix != ".py":
                continue
            seen.add(f)
            try:
                text = f.read_text()
                tree = ast.parse(text, filename=str(f))
            except (SyntaxError, UnicodeDecodeError, OSError):
                continue
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            out.append(SourceFile(path=f, rel=rel, text=text, tree=tree))
    return out


def run_analysis(paths: list[Path], *, root: Path,
                 baseline: Baseline | None = None,
                 passes: list[AnalysisPass] | None = None
                 ) -> AnalysisResult:
    files = collect_files(paths, root)
    findings: list[Finding] = []
    for ps in (passes if passes is not None else registered_passes()):
        findings.extend(ps.run(files))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    new, old = (baseline or Baseline()).split(findings)
    return AnalysisResult(files=files, findings=findings,
                          new=new, grandfathered=old)
