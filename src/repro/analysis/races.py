"""Shared-state race pass: Eraser-style lockset analysis for the
threaded runtime.

The lock pass (``locks.py``) checks lock *discipline* — ordering, the
TryLock never-block rule, the stats-lock family — but says nothing
about whether shared state is actually *protected*.  This pass closes
that gap with a static lockset analysis in the Eraser tradition
(Savage et al., SOSP '97), scoped to where Python threads actually
race: classes that spawn ``threading.Thread``s or hand methods to a
thread-running host (the ``Runtime(process=self._ingest, ...)`` shape).

Three rules:

  - **RACE001** — an attribute written by two different thread roles
    (or by two instances of one multiply-spawned thread body) whose
    write-site locksets have an empty intersection: no one lock
    protects every write, so updates interleave.
  - **RACE002** — an unsynchronized read-modify-write of shared state:
    ``self.x += 1`` (or ``self.x = f(self.x)``) with no lock held, or a
    check-then-act (``if self.flag: ... self.flag = ...``) whose test
    and write are not atomic.  Under the GIL a plain store is atomic
    but a load-op-store is not — this is the rule the PR-6
    stats-buffering bug class falls under.
  - **RACE003** — partially-constructed ``self`` escaping to a thread:
    a ``Thread(target=self.m).start()`` runs before a field that ``m``
    reads is assigned, so the thread can observe the attribute missing
    or half-initialized.

Thread entry points are discovered structurally:
``threading.Thread(target=...)`` sites (``self.method``, lambdas,
``functools.partial``, nested ``def``s), plus methods that *escape* as
call arguments (``Runtime(process=self._ingest)``) in classes that own
locks — those run on the host's poller threads.  A thread spawned
inside a loop or comprehension is *multiple* threads (one role, many
instances).  Function-scope spawns get the RMW check on closed-over
names.

Lifecycle methods (``__init__``/``start``/``stop``/``reset``/
``close``) are exempt from RACE001/002 as in LOCK003 — they run while
the threads are quiescent — but RACE003 looks precisely at them.

Like the rest of the suite the analysis is class-local and
intra-procedural on purpose: cross-object sharing (a ``BoundedQueue``
mutated by another class's threads) is the dynamic sanitizer's job
(``repro.analysis.sanitizer``), which confirms or refutes these
findings against real runs.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .core import ERROR, AnalysisPass, Finding, SourceFile, register
from .locks import _EXEMPT_METHODS, _MUTATORS, _dotted, _lock_key

__all__ = ["RacePass"]

# constructors whose product is a synchronization object, not data
_SYNC_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore", "Event", "Barrier", "TryLock", "local"}
_LOCKISH_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
                  "BoundedSemaphore", "TryLock"}
_CALLER_ROLE = "<caller>"
# deque/list mutators that are single-bytecode atomic under the GIL and
# therefore not a read-modify-write by themselves
_RMW_SAFE_MUTATORS = {"append", "appendleft", "popleft", "pop", "add"}


def _last_seg(dotted: str | None) -> str | None:
    return dotted.split(".")[-1] if dotted else None


def _ctor_name(value: ast.AST) -> str | None:
    """Constructor basename if ``value`` is a call like
    ``threading.Lock()`` / ``TryLock()``, else None."""
    if isinstance(value, ast.Call):
        return _last_seg(_dotted(value.func))
    return None


def _is_thread_ctor(call: ast.Call) -> bool:
    return _last_seg(_dotted(call.func)) == "Thread"


def _thread_target_expr(call: ast.Call) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == "target":
            return kw.value
    return None


@dataclass
class _Access:
    root: str                 # "self.stats"
    kind: str                 # "read" | "write" | "rmw"
    lockset: frozenset
    line: int
    col: int
    method: str               # lexical scope the access lives in
    rmw_kind: str = ""        # "augassign" | "reassign" | "cta"


@dataclass
class _Role:
    rid: str                  # entry method name, or _CALLER_ROLE
    methods: set
    multi: bool = False       # role runs as >= 2 OS threads


@dataclass
class _Spawn:
    """One resolved thread entry: which method body runs on the thread
    and whether the spawn site creates several threads."""
    entry: str
    multi: bool


def _self_aliases(fn) -> dict[str, str]:
    """Local name -> dotted self path (``st = self.stats``)."""
    out: dict[str, str] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            d = _dotted(node.value)
            if d and d.startswith("self."):
                out[node.targets[0].id] = d
    return out


class _AccessScanner:
    """Walk one function body tracking held locks and recording every
    read/write/RMW of a ``self.<attr>`` root (aliases resolved)."""

    def __init__(self, sf: SourceFile, method: str, aliases: dict[str, str],
                 skip_roots, method_names):
        self.sf = sf
        self.method = method
        self.aliases = aliases
        self.skip_roots = skip_roots        # sync/lock/thread-handle attrs
        self.method_names = method_names
        self.accesses: list[_Access] = []
        self.cta: list[_Access] = []

    # -- resolution ------------------------------------------------------------
    def _root(self, dotted: str | None) -> str | None:
        if not dotted:
            return None
        head, _, rest = dotted.partition(".")
        base = self.aliases.get(head, head)
        if base.startswith("self.") and head != "self":
            dotted = f"{base}.{rest}" if rest else base
        elif head != "self":
            return None
        parts = dotted.split(".")
        if len(parts) < 2:
            return None
        attr = parts[1]
        if attr == "[]" or attr in self.skip_roots:
            return None
        if attr in self.method_names:
            return None                    # bound-method reference, not data
        if "lock" in attr.lower() or "mutex" in attr.lower():
            return None
        return f"self.{attr}"

    def _record(self, root, kind, node, held, rmw_kind=""):
        if root is None:
            return
        self.accesses.append(_Access(
            root=root, kind=kind, lockset=frozenset(h for h in held),
            line=node.lineno, col=node.col_offset,
            method=self.method, rmw_kind=rmw_kind))

    # -- expression-level accesses ----------------------------------------------
    def _reads(self, expr: ast.AST, held) -> None:
        """Record reads of self-rooted names in ``expr`` (topmost
        attribute chains only)."""
        for root, node in self._read_roots(expr):
            self._record(root, "read", node, held)

    def _read_roots(self, expr: ast.AST):
        out = []

        def walk(n: ast.AST) -> None:
            if isinstance(n, (ast.Attribute, ast.Subscript)):
                d = _dotted(n)
                root = self._root(d)
                if root is not None:
                    out.append((root, n))
                    # don't descend into the chain itself, but do walk
                    # subscript slices and call args hanging off it
                    if isinstance(n, ast.Subscript):
                        walk(n.slice)
                    return
                for child in ast.iter_child_nodes(n):
                    walk(child)
                return
            if isinstance(n, ast.Name):
                root = self._root(n.id)
                if root is not None:
                    out.append((root, n))
                return
            for child in ast.iter_child_nodes(n):
                walk(child)

        walk(expr)
        return out

    def _expr_accesses(self, stmt: ast.stmt, held) -> None:
        """Accesses inside one simple statement."""
        if isinstance(stmt, ast.Assign):
            value_roots = {r for r, _ in self._read_roots(stmt.value)}
            self._reads(stmt.value, held)
            for tgt in stmt.targets:
                if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                    root = self._root(_dotted(tgt))
                    if root in value_roots:
                        self._record(root, "rmw", stmt, held,
                                     rmw_kind="reassign")
                    else:
                        self._record(root, "write", stmt, held)
                    if isinstance(tgt, ast.Subscript):
                        self._reads(tgt.slice, held)
            return
        if isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, (ast.Attribute, ast.Subscript)):
                root = self._root(_dotted(stmt.target))
                self._record(root, "rmw", stmt, held, rmw_kind="augassign")
                if isinstance(stmt.target, ast.Subscript):
                    self._reads(stmt.target.slice, held)
            self._reads(stmt.value, held)
            return
        # everything else: record mutator calls as writes, the rest as reads
        for node in ast.walk(stmt):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS):
                root = self._root(_dotted(node.func.value))
                if root is not None:
                    kind = ("write" if node.func.attr in _RMW_SAFE_MUTATORS
                            else "rmw")
                    rmw_kind = "" if kind == "write" else "augassign"
                    self._record(root, kind, node, held, rmw_kind=rmw_kind)
        self._reads(stmt, held)

    # -- statement walker with lock tracking -------------------------------------
    def scan(self, body: list) -> None:
        self._stmts(body, [])

    def scan_expr(self, expr: ast.AST) -> None:
        """For lambda bodies: expression-only scan, nothing held."""
        self._reads(expr, [])

    def _stmts(self, stmts: list, held: list) -> None:
        held = list(held)
        for st in stmts:
            if (isinstance(st, ast.Expr) and isinstance(st.value, ast.Call)
                    and isinstance(st.value.func, ast.Attribute)
                    and st.value.func.attr == "release"):
                key = _lock_key(st.value.func.value)
                if key:
                    held = [h for h in held if h != key]
                continue
            if isinstance(st, ast.With):
                inner = list(held)
                for item in st.items:
                    key = _lock_key(item.context_expr)
                    if key:
                        inner.append(key)
                self._stmts(st.body, inner)
                continue
            if isinstance(st, ast.If):
                key = self._try_acquire_test(st.test)
                if key:
                    self._stmts(st.body, held + [key])
                    self._stmts(st.orelse, held)
                    continue
                nkey = self._not_acquire_test(st.test)
                if nkey and st.body and isinstance(
                        st.body[-1], (ast.Return, ast.Raise,
                                      ast.Continue, ast.Break)):
                    self._stmts(st.body, held)
                    held.append(nkey)
                    continue
                self._reads(st.test, held)
                mark = len(self.accesses)
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                if not held:
                    self._check_then_act(st, mark)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor)):
                self._reads(st.iter, held)
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                continue
            if isinstance(st, ast.While):
                self._reads(st.test, held)
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                continue
            if isinstance(st, ast.Try):
                self._stmts(st.body, held)
                for h in st.handlers:
                    self._stmts(h.body, held)
                self._stmts(st.orelse, held)
                self._stmts(st.finalbody, held)
                continue
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue                    # nested defs scanned separately
            self._expr_accesses(st, held)
            # a blocking .acquire() in statement position starts a hold
            for node in ast.walk(st):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "acquire"
                        and not _call_is_nonblocking(node)):
                    key = _lock_key(node.func.value)
                    if key:
                        held.append(key)

    def _check_then_act(self, st: ast.If, mark: int) -> None:
        """Lock-free ``if <reads self.X>:`` whose body writes the same
        root lock-free: the test and the act are not atomic."""
        tested = {r for r, _ in self._read_roots(st.test)}
        if not tested:
            return
        for a in self.accesses[mark:]:
            if (a.root in tested and a.kind in ("write", "rmw")
                    and not a.lockset):
                self.cta.append(_Access(
                    root=a.root, kind="rmw", lockset=frozenset(),
                    line=st.lineno, col=st.col_offset,
                    method=self.method, rmw_kind="cta"))
                tested.discard(a.root)
                if not tested:
                    return

    @staticmethod
    def _try_acquire_test(test: ast.AST) -> str | None:
        if (isinstance(test, ast.Call)
                and isinstance(test.func, ast.Attribute)):
            if test.func.attr == "try_acquire":
                return _lock_key(test.func.value) or \
                    _last_seg(_dotted(test.func.value))
            if (test.func.attr == "acquire"
                    and _call_is_nonblocking(test)):
                return _lock_key(test.func.value)
        return None

    @staticmethod
    def _not_acquire_test(test: ast.AST) -> str | None:
        if (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
                and isinstance(test.operand, ast.Call)):
            call = test.operand
            if isinstance(call.func, ast.Attribute) and \
                    call.func.attr in ("acquire", "try_acquire"):
                return _lock_key(call.func.value)
        return None


def _call_is_nonblocking(call: ast.Call) -> bool:
    for kw in call.keywords:
        if (kw.arg == "blocking" and isinstance(kw.value, ast.Constant)
                and kw.value.value is False):
            return True
    return bool(call.args and isinstance(call.args[0], ast.Constant)
                and call.args[0].value is False)


@dataclass
class _ClassModel:
    node: ast.ClassDef
    methods: dict = field(default_factory=dict)     # name -> FunctionDef
    sync_attrs: set = field(default_factory=set)    # lock/event/thread attrs
    lockish: bool = False                           # owns an actual lock
    spawns: list = field(default_factory=list)      # list[_Spawn]
    nested_entries: dict = field(default_factory=dict)  # synthetic id -> node


def _in_multi_context(node: ast.AST, parents: dict) -> bool:
    """Is ``node`` under a loop or comprehension (several spawns)?"""
    cur = parents.get(node)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        if isinstance(cur, (ast.For, ast.While, ast.ListComp, ast.SetComp,
                            ast.GeneratorExp, ast.DictComp)):
            return True
        cur = parents.get(cur)
    return False


def _build_model(cls: ast.ClassDef, parents: dict) -> _ClassModel:
    model = _ClassModel(node=cls)
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            model.methods[node.name] = node

    local_defs: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.FunctionDef) and node.name not in model.methods:
            local_defs[node.name] = node

    # sync-object and thread-handle attributes are bookkeeping, not data
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign):
            ctor = _ctor_name(node.value)
            elt_ctor = None
            if isinstance(node.value, (ast.List, ast.ListComp)):
                elt = (node.value.elts[0] if isinstance(node.value, ast.List)
                       and node.value.elts else
                       node.value.elt if isinstance(node.value, ast.ListComp)
                       else None)
                if isinstance(elt, ast.Call):
                    elt_ctor = _last_seg(_dotted(elt.func))
            for tgt in node.targets:
                d = _dotted(tgt)
                if d and d.startswith("self.") and len(d.split(".")) == 2:
                    attr = d.split(".")[1]
                    if ctor in _SYNC_CTORS or ctor == "Thread" or \
                            elt_ctor == "Thread":
                        model.sync_attrs.add(attr)
                    if ctor in _LOCKISH_CTORS:
                        model.lockish = True

    def resolve_target(expr: ast.AST, aliases: dict[str, str],
                       multi: bool) -> None:
        if expr is None:
            return
        d = _dotted(expr)
        if d and d.startswith("self."):
            name = d.split(".")[1]
            if name in model.methods:
                model.spawns.append(_Spawn(entry=name, multi=multi))
            return
        if isinstance(expr, ast.Name):
            if expr.id in aliases:
                resolve_target(
                    ast.parse(aliases[expr.id], mode="eval").body,
                    aliases, multi)
                return
            fn = local_defs.get(expr.id)
            if fn is not None:
                sid = f"<def {fn.name}>"
                model.nested_entries[sid] = fn
                model.spawns.append(_Spawn(entry=sid, multi=multi))
            return
        if isinstance(expr, ast.Lambda):
            sid = f"<lambda L{expr.lineno}>"
            model.nested_entries[sid] = expr
            model.spawns.append(_Spawn(entry=sid, multi=multi))
            # calls to self.m inside the lambda are entries too
            for n in ast.walk(expr.body):
                if isinstance(n, ast.Call):
                    cd = _dotted(n.func)
                    if cd and cd.startswith("self."):
                        m = cd.split(".")[1]
                        if m in model.methods:
                            model.spawns.append(_Spawn(entry=m, multi=multi))
            return
        if isinstance(expr, ast.Call) and \
                _last_seg(_dotted(expr.func)) == "partial" and expr.args:
            resolve_target(expr.args[0], aliases, multi)

    # Thread(target=...) spawn sites
    for m in model.methods.values():
        aliases = _self_aliases(m)
        for node in ast.walk(m):
            if isinstance(node, ast.Call) and _is_thread_ctor(node):
                multi = _in_multi_context(node, parents)
                resolve_target(_thread_target_expr(node), aliases, multi)

    # escaped methods: `self.m` handed to some call as an argument
    # (Runtime(process=self._ingest, ...)): runs on the host's threads —
    # only meaningful in classes that own locks or spawn threads anyway
    for node in ast.walk(cls):
        if isinstance(node, ast.Call):
            arg_exprs = list(node.args) + [kw.value for kw in node.keywords
                                           if kw.arg != "target"]
            for a in arg_exprs:
                d = _dotted(a)
                if d and d.startswith("self.") and len(d.split(".")) == 2:
                    name = d.split(".")[1]
                    if name in model.methods:
                        model.spawns.append(_Spawn(entry=name, multi=True))
    return model


def _closure(model: _ClassModel, entry: str) -> set:
    """Method names reachable from ``entry`` via ``self.f()`` calls
    (lifecycle methods excluded: they run quiescent)."""
    out: set[str] = set()
    work = [entry]
    while work:
        cur = work.pop()
        if cur in out:
            continue
        out.add(cur)
        fn = model.methods.get(cur) or model.nested_entries.get(cur)
        if fn is None:
            continue
        walk_root = fn.body if not isinstance(fn, ast.Lambda) else fn.body
        nodes = (ast.walk(fn) if not isinstance(fn, ast.Lambda)
                 else ast.walk(walk_root))
        for node in nodes:
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d and d.startswith("self.") and len(d.split(".")) == 2:
                    m = d.split(".")[1]
                    if (m in model.methods and m not in out
                            and m not in _EXEMPT_METHODS):
                        work.append(m)
    return out


@register
class RacePass(AnalysisPass):
    name = "races"
    rules = {
        "RACE001": ("attribute written by two thread roles with an "
                    "empty common lockset (Eraser-style shared-state "
                    "race)"),
        "RACE002": ("unsynchronized read-modify-write or "
                    "check-then-act on shared state (lost-update "
                    "race)"),
        "RACE003": ("partially-constructed object escapes: a field the "
                    "spawned thread reads is assigned after "
                    "Thread.start()"),
    }

    def run(self, files: list[SourceFile]) -> list[Finding]:
        out: list[Finding] = []
        for sf in files:
            parents: dict[ast.AST, ast.AST] = {}
            for node in ast.walk(sf.tree):
                for child in ast.iter_child_nodes(node):
                    parents[child] = node
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ClassDef):
                    out.extend(_check_class(sf, node, parents))
            out.extend(_check_function_scope(sf, parents))
        return out


def _check_class(sf: SourceFile, cls: ast.ClassDef,
                 parents: dict) -> list[Finding]:
    model = _build_model(cls, parents)
    if not model.spawns and not model.lockish:
        return []

    # roles: one per distinct entry + the implicit caller role
    entries: dict[str, bool] = {}
    for sp in model.spawns:
        entries[sp.entry] = entries.get(sp.entry, False) or sp.multi
    if not entries:
        return []                 # lock-owning class but nothing concurrent

    roles: list[_Role] = []
    for entry, multi in sorted(entries.items()):
        roles.append(_Role(rid=entry, methods=_closure(model, entry),
                           multi=multi))
    caller_methods = {m for m in model.methods
                      if m not in entries and m not in _EXEMPT_METHODS}
    roles.append(_Role(rid=_CALLER_ROLE, methods=caller_methods))

    method_names = set(model.methods)
    skip_roots = set(model.sync_attrs)

    # scan every method + synthetic entry once
    accesses: list[_Access] = []
    cta: list[_Access] = []
    for name, fn in model.methods.items():
        sc = _AccessScanner(sf, name, _self_aliases(fn), skip_roots,
                            method_names)
        sc.scan(fn.body)
        accesses.extend(sc.accesses)
        cta.extend(sc.cta)
    for sid, fn in model.nested_entries.items():
        aliases = _self_aliases(fn) if not isinstance(fn, ast.Lambda) else {}
        sc = _AccessScanner(sf, sid, aliases, skip_roots, method_names)
        if isinstance(fn, ast.Lambda):
            sc.scan_expr(fn.body)
        else:
            sc.scan(fn.body)
        accesses.extend(sc.accesses)
        cta.extend(sc.cta)

    def roles_of(method: str) -> list[_Role]:
        return [r for r in roles if method in r.methods]

    # drop lifecycle-method accesses (threads quiescent there)
    live = [a for a in accesses if a.method not in _EXEMPT_METHODS]
    live_cta = [a for a in cta if a.method not in _EXEMPT_METHODS]

    findings: list[Finding] = []

    # -- RACE001/002: per-attribute write-lockset intersection ------------------
    # A race needs *write concurrency*: two distinct roles writing, or
    # one role spawned as several threads.  A lone writer's unguarded
    # ``+=`` can't lose updates (GIL stores are atomic), so it stays
    # silent — Eraser would flag it, Python need not.
    by_root: dict[str, list[_Access]] = {}
    for a in live:
        by_root.setdefault(a.root, []).append(a)
    cta_by_root: dict[str, list[_Access]] = {}
    for a in live_cta:
        cta_by_root.setdefault(a.root, []).append(a)
    for root in sorted(by_root):
        accs = by_root[root]
        writes = [a for a in accs if a.kind in ("write", "rmw")]
        if not writes:
            continue
        write_roles: set[str] = set()
        multi_writer = False
        for a in writes:
            for r in roles_of(a.method):
                write_roles.add(r.rid)
                multi_writer |= r.multi
        if _CALLER_ROLE in write_roles and len(write_roles) == 1:
            continue                      # only ever written by the caller
        concurrent = len(write_roles) >= 2 or multi_writer
        if not concurrent:
            continue
        common = frozenset.intersection(*[a.lockset for a in writes])
        if common:
            continue
        # precise diagnoses first: a check-then-act explains the whole
        # test+write shape in its method; a bare RMW is its own story
        ctas = sorted(cta_by_root.get(root, []),
                      key=lambda x: (x.line, x.col))
        for c in ctas:
            findings.append(_race002(sf, c))
        explained = {c.method for c in ctas}
        remaining = [a for a in writes
                     if not a.lockset and a.method not in explained]
        if remaining and all(a.kind == "rmw" for a in remaining):
            for a in sorted(remaining, key=lambda x: (x.line, x.col)):
                findings.append(_race002(sf, a))
            continue
        if not remaining and ctas:
            continue                      # fully explained by the CTAs
        first = min(remaining or writes, key=lambda a: (a.line, a.col))
        names = sorted(r for r in write_roles)
        findings.append(Finding(
            rule="RACE001", severity=ERROR, path=sf.rel,
            line=first.line, col=first.col,
            message=(f"'{root}' in class '{cls.name}' is written by "
                     f"thread roles {', '.join(names)} with no common "
                     "lock protecting the writes (empty lockset "
                     "intersection)")))

    findings.extend(_check_escape(sf, cls, model, roles))
    return findings


def _race002(sf: SourceFile, a: _Access) -> Finding:
    what = ("check-then-act" if a.rmw_kind == "cta"
            else "read-modify-write")
    return Finding(
        rule="RACE002", severity=ERROR, path=sf.rel,
        line=a.line, col=a.col,
        message=(f"unsynchronized {what} of '{a.root}' in "
                 f"'{a.method}': no lock held, concurrent threads "
                 "can lose updates"))


def _check_escape(sf: SourceFile, cls: ast.ClassDef, model: _ClassModel,
                  roles: list[_Role]) -> list[Finding]:
    """RACE003: a field the spawned thread's closure reads is assigned
    *after* the thread is started in the same method."""
    reads_of_entry: dict[str, set] = {}
    for r in roles:
        if r.rid == _CALLER_ROLE:
            continue
        roots: set[str] = set()
        for m in r.methods:
            fn = model.methods.get(m) or model.nested_entries.get(m)
            if fn is None:
                continue
            sc = _AccessScanner(sf, m, _self_aliases(fn)
                                if not isinstance(fn, ast.Lambda) else {},
                                model.sync_attrs, set(model.methods))
            if isinstance(fn, ast.Lambda):
                sc.scan_expr(fn.body)
            else:
                sc.scan(fn.body)
            roots |= {a.root for a in sc.accesses}
        reads_of_entry[r.rid] = roots

    out: list[Finding] = []
    for name, fn in model.methods.items():
        aliases = _self_aliases(fn)
        # bindings: local name / self attr -> entries its Thread targets
        bound: dict[str, set] = {}
        starts: list[tuple[int, str]] = []     # (line, entry)

        def note_binding(tgt_d: str | None, value: ast.AST,
                         local_bound: dict) -> None:
            if not tgt_d:
                return
            found: set[str] = set()
            for n in ast.walk(value):
                if isinstance(n, ast.Call) and _is_thread_ctor(n):
                    texpr = _thread_target_expr(n)
                    d = _dotted(texpr) if texpr is not None else None
                    if d and d.startswith("self."):
                        m = d.split(".")[1]
                        if m in model.methods:
                            found.add(m)
                    elif isinstance(texpr, ast.Name):
                        found.add(f"<def {texpr.id}>")
            if found:
                local_bound[tgt_d] = found

        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    note_binding(_dotted(tgt), node.value, bound)
            elif isinstance(node, ast.For):
                it = _dotted(node.iter)
                tgt = _dotted(node.target)
                if it in bound and tgt:
                    bound[tgt] = bound[it]
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "start"):
                base = _dotted(node.func.value)
                for entry in sorted(bound.get(base, ())):
                    starts.append((node.lineno, entry))
                # inline Thread(...).start()
                if isinstance(node.func.value, ast.Call) and \
                        _is_thread_ctor(node.func.value):
                    texpr = _thread_target_expr(node.func.value)
                    d = _dotted(texpr) if texpr is not None else None
                    if d and d.startswith("self."):
                        m = d.split(".")[1]
                        if m in model.methods:
                            starts.append((node.lineno, m))
        if not starts:
            continue
        first_start = min(line for line, _ in starts)
        started_reads: set[str] = set()
        for line, entry in starts:
            started_reads |= reads_of_entry.get(entry, set())
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and node.lineno > first_start:
                for tgt in node.targets:
                    d = _dotted(tgt)
                    root = None
                    if d and d.startswith("self.") and len(d.split(".")) >= 2:
                        root = "self." + d.split(".")[1]
                    if root and root in started_reads:
                        out.append(Finding(
                            rule="RACE003", severity=ERROR, path=sf.rel,
                            line=node.lineno, col=node.col_offset,
                            message=(f"'{root}' in class '{cls.name}' is "
                                     f"assigned in '{name}' after a "
                                     "thread that reads it has started: "
                                     "the thread can observe a "
                                     "partially-constructed object")))
    return out


def _check_function_scope(sf: SourceFile, parents: dict) -> list[Finding]:
    """Module/function-scope spawns: flag unguarded read-modify-writes
    on closed-over names inside thread-target nested functions or
    lambdas (the GIL makes plain stores atomic; += is not)."""
    out: list[Finding] = []
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # skip methods: the class analysis owns those
        if isinstance(parents.get(fn), ast.ClassDef):
            continue
        local_defs = {n.name: n for n in ast.walk(fn)
                      if isinstance(n, ast.FunctionDef) and n is not fn}
        spawned: list[tuple[ast.AST, bool]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_thread_ctor(node):
                texpr = _thread_target_expr(node)
                multi = _in_multi_context(node, parents)
                if isinstance(texpr, ast.Name) and texpr.id in local_defs:
                    spawned.append((local_defs[texpr.id], multi))
                elif isinstance(texpr, ast.Lambda):
                    spawned.append((texpr, multi))
        for worker, multi in spawned:
            if isinstance(worker, ast.Lambda):
                continue
            worker_locals = {n.id for n in ast.walk(worker)
                             if isinstance(n, ast.Name)
                             and isinstance(n.ctx, ast.Store)}
            worker_locals |= {a.arg for a in worker.args.args}
            nonlocals = {nm for n in ast.walk(worker)
                         if isinstance(n, ast.Nonlocal) for nm in n.names}
            for node in ast.walk(worker):
                if not isinstance(node, ast.AugAssign):
                    continue
                tgt = node.target
                nm = tgt.id if isinstance(tgt, ast.Name) else None
                if nm is None:
                    continue
                closed_over = nm in nonlocals or nm not in worker_locals
                if not closed_over and nm not in nonlocals:
                    continue
                if not multi:
                    continue
                if _under_lock(node, worker, parents):
                    continue
                out.append(Finding(
                    rule="RACE002", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"unsynchronized read-modify-write of "
                             f"closed-over '{nm}' in thread body "
                             f"'{worker.name}' spawned multiple times: "
                             "concurrent threads can lose updates")))
    return out


def _under_lock(node: ast.AST, top: ast.AST, parents: dict) -> bool:
    cur = parents.get(node)
    while cur is not None and cur is not top:
        if isinstance(cur, ast.With):
            for item in cur.items:
                if _lock_key(item.context_expr):
                    return True
        cur = parents.get(cur)
    return False
