"""CLI for the project static-analysis suite.

    python -m repro.analysis [--paths P ...] [--baseline FILE]
                             [--format text|json] [--update-baseline]
                             [--since REV | --changed-only]
                             [--list-rules]

Exit status: 0 when every finding is grandfathered by the baseline (or
there are none), 1 when new findings exist, 2 on usage errors.  Default
scope is ``src/repro`` plus ``benchmarks``; the baseline default is
``analysis_baseline.json`` next to the repo root (located by walking up
from this file), so the command works from any CWD.

``--since REV`` restricts the scan to Python files changed since the
given git revision (working tree included, untracked files too), and
``--changed-only`` is shorthand for ``--since HEAD`` — both keep the
pre-commit gate at seconds instead of a whole-tree pass.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from .core import Baseline, registered_passes, run_analysis

_EXIT_CODES = """\
exit codes:
  0   clean: no findings, or every finding grandfathered by the baseline
  1   gate failure: at least one finding not covered by the baseline
  2   usage error: missing path, unreadable baseline, bad git revision
"""


def _repo_root() -> Path:
    """The checkout root: the directory holding ``src/``."""
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "src" / "repro").is_dir():
            return parent
    return Path.cwd()


def _changed_files(root: Path, since: str) -> list[Path]:
    """Python files changed vs ``since``: committed-after, staged,
    working-tree, and untracked.  Raises CalledProcessError on a bad
    revision and FileNotFoundError when git is absent."""
    diff = subprocess.run(
        ["git", "diff", "--name-only", since, "--", "*.py"],
        cwd=root, capture_output=True, text=True, check=True)
    untracked = subprocess.run(
        ["git", "ls-files", "-o", "--exclude-standard", "--", "*.py"],
        cwd=root, capture_output=True, text=True, check=True)
    names = set(diff.stdout.splitlines()) | set(untracked.stdout.splitlines())
    return sorted(root / n for n in names if n)


def main(argv: list[str] | None = None) -> int:
    root = _repo_root()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="project-specific static analysis "
                    "(units / engine-parity / scan-purity / "
                    "lock-discipline / races)",
        epilog=_EXIT_CODES,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files or directories to scan "
                         "(default: src/repro and benchmarks)")
    ap.add_argument("--baseline", default=str(root /
                                              "analysis_baseline.json"),
                    help="grandfathered-findings JSON (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file to grandfather "
                         "every current finding, then exit 0")
    scope = ap.add_mutually_exclusive_group()
    scope.add_argument("--since", metavar="REV", default=None,
                       help="scan only Python files changed since this "
                            "git revision (working tree and untracked "
                            "files included)")
    scope.add_argument("--changed-only", action="store_true",
                       help="shorthand for --since HEAD: scan only "
                            "uncommitted/untracked Python files")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for ps in registered_passes():
            print(f"{ps.name}:")
            for rid, desc in ps.rules.items():
                print(f"  {rid}: {desc}")
        return 0

    scope_paths = ([Path(p).resolve() for p in args.paths] if args.paths
                   else [root / "src" / "repro", root / "benchmarks"])
    for p in scope_paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    since = "HEAD" if args.changed_only else args.since
    if since is not None:
        try:
            changed = _changed_files(root, since)
        except (subprocess.CalledProcessError, FileNotFoundError) as e:
            detail = getattr(e, "stderr", "") or str(e)
            print(f"error: git diff against {since!r} failed: "
                  f"{detail.strip()}", file=sys.stderr)
            return 2
        # diff scope ∩ requested scope: a changed test fixture should
        # not sneak into a src/repro-gated run
        paths = [f for f in changed if f.exists() and any(
            f == s or s in f.parents for s in scope_paths)]
    else:
        paths = scope_paths

    baseline_path = Path(args.baseline)
    try:
        baseline = Baseline.load(baseline_path)
    except (ValueError, KeyError, TypeError, OSError) as e:
        print(f"error: unreadable baseline {baseline_path}: {e}",
              file=sys.stderr)
        return 2
    result = run_analysis(paths, root=root, baseline=baseline)

    if args.update_baseline:
        Baseline.from_findings(result.findings).save(baseline_path)
        print(f"baseline updated: {len(result.findings)} finding(s) "
              f"grandfathered in {baseline_path}")
        return 0

    rule_counts: dict[str, int] = {}
    for f in result.findings:
        rule_counts[f.rule] = rule_counts.get(f.rule, 0) + 1

    if args.format == "json":
        print(json.dumps({
            "schema": "repro-analysis/1",
            "files_scanned": len(result.files),
            "rule_counts": dict(sorted(rule_counts.items())),
            "rules_known": sorted(rid for ps in registered_passes()
                                  for rid in ps.rules),
            "new": [f.to_json() for f in result.new],
            "grandfathered": [f.to_json()
                              for f in result.grandfathered],
            "ok": result.ok,
        }, indent=2))
    else:
        for f in result.new:
            print(f.format())
        for f in result.grandfathered:
            print(f"{f.format()}  [baselined]")
        print(f"{len(result.files)} file(s) scanned: "
              f"{len(result.new)} new finding(s), "
              f"{len(result.grandfathered)} baselined")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
