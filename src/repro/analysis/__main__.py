"""CLI for the project static-analysis suite.

    python -m repro.analysis [--paths P ...] [--baseline FILE]
                             [--format text|json] [--update-baseline]
                             [--list-rules]

Exit status: 0 when every finding is grandfathered by the baseline (or
there are none), 1 when new findings exist, 2 on usage errors.  Default
scope is ``src/repro``; the baseline default is
``analysis_baseline.json`` next to the repo root (located by walking up
from this file), so the command works from any CWD.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import Baseline, registered_passes, run_analysis


def _repo_root() -> Path:
    """The checkout root: the directory holding ``src/``."""
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "src" / "repro").is_dir():
            return parent
    return Path.cwd()


def main(argv: list[str] | None = None) -> int:
    root = _repo_root()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="project-specific static analysis "
                    "(units / engine-parity / scan-purity / "
                    "lock-discipline)")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files or directories to scan "
                         "(default: src/repro)")
    ap.add_argument("--baseline", default=str(root /
                                              "analysis_baseline.json"),
                    help="grandfathered-findings JSON (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file to grandfather "
                         "every current finding, then exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for ps in registered_passes():
            print(f"{ps.name}:")
            for rid, desc in ps.rules.items():
                print(f"  {rid}: {desc}")
        return 0

    paths = ([Path(p) for p in args.paths] if args.paths
             else [root / "src" / "repro"])
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    baseline_path = Path(args.baseline)
    try:
        baseline = Baseline.load(baseline_path)
    except (ValueError, KeyError, TypeError, OSError) as e:
        print(f"error: unreadable baseline {baseline_path}: {e}",
              file=sys.stderr)
        return 2
    result = run_analysis(paths, root=root, baseline=baseline)

    if args.update_baseline:
        Baseline.from_findings(result.findings).save(baseline_path)
        print(f"baseline updated: {len(result.findings)} finding(s) "
              f"grandfathered in {baseline_path}")
        return 0

    if args.format == "json":
        print(json.dumps({
            "schema": "repro-analysis/1",
            "files_scanned": len(result.files),
            "new": [f.to_json() for f in result.new],
            "grandfathered": [f.to_json()
                              for f in result.grandfathered],
            "ok": result.ok,
        }, indent=2))
    else:
        for f in result.new:
            print(f.format())
        for f in result.grandfathered:
            print(f"{f.format()}  [baselined]")
        print(f"{len(result.files)} file(s) scanned: "
              f"{len(result.new)} new finding(s), "
              f"{len(result.grandfathered)} baselined")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
