"""Units pass: microsecond/nanosecond naming discipline.

The whole codebase encodes time units in name suffixes (``_us``,
``_ns``, ``_ms``, ``_s``) and converts between them with explicit
power-of-1000 factors (``/ 1e3``, ``* 1_000`` ...).  Mixing suffixes
without such a factor is the classic silent 1000x bug:

  - **UNITS001** — arithmetic (``+``/``-``), comparison, assignment, or
    keyword-argument flow combines two expressions with *different*
    definite unit suffixes and no conversion factor anywhere in either
    operand.  Any ``* / 1e3``-family constant in a subtree marks it
    "converted" (unit intentionally changed) and suppresses the rule —
    the pass enforces that conversions are *written down*, not that
    they are correct to a power.
  - **UNITS002** — an unsuffixed literal-valued name (``t = 500``)
    flows into slots of two *different* units in one function (e.g.
    assigned to ``sleep_ns`` here and added to ``gap_us`` there).  A
    raw literal carries no unit; using one value in both a ``_us`` and
    a ``_ns`` position means at least one of them is off by 1000.

Unit inference is syntactic and deliberately conservative: only a
definite-vs-definite clash fires, unknown absorbs everything, and
dividing two same-unit expressions yields a unitless ratio.
"""

from __future__ import annotations

import ast
import re

from .core import ERROR, AnalysisPass, Finding, SourceFile, register

__all__ = ["UnitsPass"]

_SUFFIX_RE = re.compile(r"_(us|ns|ms|s)$")

# any power-of-1000 factor counts as an explicit conversion
_CONVERSION_FACTORS = {
    1e3, 1e6, 1e9, 1e-3, 1e-6, 1e-9,
    1000, 1000_000, 1000_000_000,
}

UNKNOWN = "?"          # explicitly converted / indeterminate: absorbs


def name_unit(name: str) -> str | None:
    m = _SUFFIX_RE.search(name)
    return m.group(1) if m else None


def _is_conversion_const(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and float(node.value) in _CONVERSION_FACTORS)


class _UnitInferrer:
    """Infer the unit of an expression: a suffix string, ``None``
    (unitless / no opinion), or ``UNKNOWN`` (converted; absorbs)."""

    def infer(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Name):
            return name_unit(node.id)
        if isinstance(node, ast.Attribute):
            return name_unit(node.attr)
        if isinstance(node, ast.Call):
            return self._infer_call(node)
        if isinstance(node, ast.Subscript):
            return self.infer(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.infer(node.operand)
        if isinstance(node, ast.IfExp):
            return self._combine(self.infer(node.body),
                                 self.infer(node.orelse))
        if isinstance(node, ast.BinOp):
            return self._infer_binop(node)
        return None

    def _infer_call(self, node: ast.Call) -> str | None:
        fname = ""
        if isinstance(node.func, ast.Name):
            fname = node.func.id
        elif isinstance(node.func, ast.Attribute):
            fname = node.func.attr
        # int()/float()/abs()/max()/min() are unit-transparent
        if fname in ("int", "float", "abs", "max", "min", "round"):
            units = [self.infer(a) for a in node.args]
            out: str | None = None
            for u in units:
                out = self._combine(out, u)
            return out
        # time.monotonic_ns() and friends carry their unit in the name
        return name_unit(fname)

    def _infer_binop(self, node: ast.BinOp) -> str | None:
        if isinstance(node.op, (ast.Mult, ast.Div)):
            # an explicit power-of-1000 factor converts: unit unknown
            if (_is_conversion_const(node.left)
                    or _is_conversion_const(node.right)):
                return UNKNOWN
            lu, ru = self.infer(node.left), self.infer(node.right)
            if UNKNOWN in (lu, ru):
                return UNKNOWN
            if isinstance(node.op, ast.Div):
                if lu and ru and lu == ru:
                    return None          # same-unit ratio: unitless
                return lu if ru is None else UNKNOWN
            # Mult: unit * unitless keeps the unit; unit * unit is a
            # rate-style product whose unit we don't model
            if lu and ru:
                return UNKNOWN
            return lu or ru
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return self._combine(self.infer(node.left),
                                 self.infer(node.right))
        if isinstance(node.op, ast.Mod):
            return self.infer(node.left)
        return None

    @staticmethod
    def _combine(a: str | None, b: str | None) -> str | None:
        if UNKNOWN in (a, b):
            return UNKNOWN
        if a and b and a != b:
            return UNKNOWN               # the clash is flagged elsewhere
        return a or b


@register
class UnitsPass(AnalysisPass):
    name = "units"
    rules = {
        "UNITS001": ("arithmetic/comparison/assignment mixes *_us and "
                     "*_ns (or other time-suffixed) names without an "
                     "explicit power-of-1000 conversion"),
        "UNITS002": ("an unsuffixed literal-valued name flows into "
                     "slots of two different time units in the same "
                     "function"),
    }

    def run(self, files: list[SourceFile]) -> list[Finding]:
        out: list[Finding] = []
        for sf in files:
            out.extend(_check_file(sf))
        return out


def _check_file(sf: SourceFile) -> list[Finding]:
    inf = _UnitInferrer()
    findings: list[Finding] = []

    def clash(a: str | None, b: str | None) -> bool:
        return bool(a and b and a != UNKNOWN and b != UNKNOWN and a != b)

    def flag(node: ast.AST, a: str, b: str, what: str) -> None:
        findings.append(Finding(
            rule="UNITS001", severity=ERROR, path=sf.rel,
            line=node.lineno, col=node.col_offset,
            message=f"{what} mixes {a} and {b} operands without an "
                    f"explicit conversion"))

    class V(ast.NodeVisitor):
        def visit_BinOp(self, node: ast.BinOp) -> None:
            if isinstance(node.op, (ast.Add, ast.Sub)):
                lu, ru = inf.infer(node.left), inf.infer(node.right)
                if clash(lu, ru):
                    flag(node, lu, ru, "arithmetic")
            self.generic_visit(node)

        def visit_Compare(self, node: ast.Compare) -> None:
            exprs = [node.left, *node.comparators]
            units = [inf.infer(e) for e in exprs]
            for a, b in zip(units, units[1:]):
                if clash(a, b):
                    flag(node, a, b, "comparison")
                    break
            self.generic_visit(node)

        def visit_Assign(self, node: ast.Assign) -> None:
            vu = inf.infer(node.value)
            for tgt in node.targets:
                tu = inf.infer(tgt)
                if clash(tu, vu):
                    flag(node, tu, vu, "assignment")
            self.generic_visit(node)

        def visit_AugAssign(self, node: ast.AugAssign) -> None:
            if isinstance(node.op, (ast.Add, ast.Sub)):
                tu, vu = inf.infer(node.target), inf.infer(node.value)
                if clash(tu, vu):
                    flag(node, tu, vu, "augmented assignment")
            self.generic_visit(node)

        def visit_Call(self, node: ast.Call) -> None:
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                ku = name_unit(kw.arg)
                vu = inf.infer(kw.value)
                if clash(ku, vu):
                    flag(kw.value, ku, vu, f"keyword '{kw.arg}'")
            self.generic_visit(node)

    V().visit(sf.tree)
    findings.extend(_check_literal_flow(sf, inf))
    return findings


def _check_literal_flow(sf: SourceFile, inf: _UnitInferrer
                        ) -> list[Finding]:
    """UNITS002: per function, names assigned only bare numeric literals
    (and carrying no suffix themselves) that are then used in positions
    implying two different units."""
    findings: list[Finding] = []
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        literal_names: set[str] = set()
        poisoned: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and not name_unit(tgt.id):
                        if (isinstance(node.value, ast.Constant)
                                and isinstance(node.value.value,
                                               (int, float))):
                            literal_names.add(tgt.id)
                        else:
                            poisoned.add(tgt.id)
            elif isinstance(node, (ast.AugAssign, ast.For)):
                tgt = node.target
                if isinstance(tgt, ast.Name):
                    poisoned.add(tgt.id)
        literal_names -= poisoned
        if not literal_names:
            continue
        # collect each literal name's unit contexts
        contexts: dict[str, dict[str, ast.AST]] = {}

        def saw(nm: str, unit: str | None, node: ast.AST) -> None:
            if unit and unit != UNKNOWN and nm in literal_names:
                contexts.setdefault(nm, {}).setdefault(unit, node)

        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if (isinstance(node.value, ast.Name)
                        and node.value.id in literal_names):
                    for tgt in node.targets:
                        saw(node.value.id, inf.infer(tgt), node)
            elif isinstance(node, ast.BinOp):
                if isinstance(node.op, (ast.Add, ast.Sub)):
                    for a, b in ((node.left, node.right),
                                 (node.right, node.left)):
                        if isinstance(a, ast.Name):
                            saw(a.id, inf.infer(b), node)
            elif isinstance(node, ast.Compare):
                exprs = [node.left, *node.comparators]
                for i, e in enumerate(exprs):
                    if isinstance(e, ast.Name):
                        for j, other in enumerate(exprs):
                            if j != i:
                                saw(e.id, inf.infer(other), node)
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if (kw.arg is not None
                            and isinstance(kw.value, ast.Name)):
                        saw(kw.value.id, name_unit(kw.arg), kw.value)
        for nm, units in sorted(contexts.items()):
            if len(units) >= 2:
                node = min(units.values(), key=lambda n: n.lineno)
                findings.append(Finding(
                    rule="UNITS002", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"literal-valued name '{nm}' is used in "
                             f"{' and '.join(sorted(units))} positions; "
                             "a bare literal cannot be both")))
    return findings
