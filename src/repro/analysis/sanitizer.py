"""Dynamic concurrency sanitizer: the runtime counterpart of the
static RACE pass.

The static pass (``repro.analysis.races``) proves what it can from the
AST; everything it reports is at best PLAUSIBLE — alias analysis is
approximate and cross-object sharing is invisible to it.  This module
closes the loop against *real* threaded runs:

  - ``TracedLock`` / ``TracedTryLock`` wrap ``threading.Lock`` and the
    project's ``TryLock``, maintaining a per-thread lockset plus
    acquisition, contention, wait-time and hold-time telemetry
    (log2-bucketed histograms);
  - ``Sanitizer.trace(obj)`` instruments ``type(obj)`` so every
    attribute read/write on the traced instance feeds an Eraser-style
    lockset state machine (virgin → exclusive → shared →
    shared-modified, candidate lockset intersected on each access);
  - ``Sanitizer.validate(findings)`` maps static findings onto the
    dynamic evidence: a finding whose (class, attribute) raced for real
    becomes **CONFIRMED**, one that stayed clean in the observed run is
    **UNOBSERVED** — never "refuted": dynamic analysis only sees the
    schedules that happened.

Two deliberate deviations from textbook Eraser, both to kill false
positives Python's lifecycle patterns would otherwise produce:

  - the candidate lockset is initialized at the first *second-thread*
    access, not the first access ever — init-then-spawn (``__init__``
    writes, worker reads) is the normal ownership transfer, not a race;
  - dead threads are pruned from each shadow's thread set, so a
    post-``join`` write by ``stop()`` (single live accessor again)
    resets the state to exclusive instead of reporting.

Stdlib-only (``threading``/``time``/``json``), like the rest of
``repro.analysis``.  Usage::

    with Sanitizer() as san:
        san.instrument_runtime(rt)
        rt.start(); ...; rt.stop()
    assert san.confirmed_races() == []
    san.save(Path("sanitizer_report.json"), static_findings)
"""

from __future__ import annotations

import json
import re
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["Sanitizer", "TracedLock", "TracedTryLock", "LockTelemetry"]

_QUOTED_SELF = re.compile(r"'self\.(\w+)'")
_QUOTED_CLASS = re.compile(r"class '(\w+)'")
_QUOTED_CLOSED = re.compile(r"closed-over '(\w+)'")


def _bucket_ns(ns: int) -> int:
    """Histogram bucket: floor(log2(ns)) — bucket b covers [2^b, 2^(b+1))."""
    return max(0, int(ns).bit_length() - 1)


@dataclass
class LockTelemetry:
    """Per-lock counters + log2(ns) histograms, JSON-serializable."""

    name: str
    acquisitions: int = 0
    contentions: int = 0          # acquired while held / failed try_acquire
    hold_ns_hist: dict = field(default_factory=dict)
    wait_ns_hist: dict = field(default_factory=dict)

    def record_wait(self, ns: int) -> None:
        b = _bucket_ns(ns)
        self.wait_ns_hist[b] = self.wait_ns_hist.get(b, 0) + 1

    def record_hold(self, ns: int) -> None:
        b = _bucket_ns(ns)
        self.hold_ns_hist[b] = self.hold_ns_hist.get(b, 0) + 1

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "acquisitions": self.acquisitions,
            "contentions": self.contentions,
            "hold_ns_hist": {str(k): v
                             for k, v in sorted(self.hold_ns_hist.items())},
            "wait_ns_hist": {str(k): v
                             for k, v in sorted(self.wait_ns_hist.items())},
        }


class TracedLock:
    """A ``threading.Lock`` stand-in that tells the sanitizer who holds
    what.  Supports the full surface the codebase uses: context
    manager, ``acquire(blocking=...)``, ``release``, ``locked``."""

    def __init__(self, inner, name: str, san: "Sanitizer"):
        self._inner = inner
        self._name = name
        self._san = san
        self._hold_t0: dict = {}            # thread ident -> acquire ns

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        t0 = time.monotonic_ns()
        contended = self._inner.locked()
        if timeout is not None and timeout >= 0:
            ok = self._inner.acquire(blocking, timeout)
        else:
            ok = self._inner.acquire(blocking)
        tele = self._san._telemetry(self._name)
        if ok:
            tele.acquisitions += 1
            if contended:
                tele.contentions += 1
            tele.record_wait(time.monotonic_ns() - t0)
            self._hold_t0[threading.get_ident()] = time.monotonic_ns()
            self._san._held().add(self._name)
        else:
            tele.contentions += 1
        return ok

    def release(self) -> None:
        ident = threading.get_ident()
        t0 = self._hold_t0.pop(ident, None)
        if t0 is not None:
            self._san._telemetry(self._name).record_hold(
                time.monotonic_ns() - t0)
        self._san._held().discard(self._name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


class TracedTryLock:
    """Wraps the project's ``TryLock``; unknown attributes (the
    ``busy_tries``/``acquisitions`` telemetry counters, ``reset_stats``)
    delegate to the wrapped lock so stats collection keeps working."""

    def __init__(self, inner, name: str, san: "Sanitizer"):
        # object.__setattr__ not needed: this class has a plain dict
        self._inner = inner
        self._name = name
        self._san = san
        self._hold_t0: dict = {}

    def try_acquire(self) -> bool:
        ok = self._inner.try_acquire()
        tele = self._san._telemetry(self._name)
        if ok:
            tele.acquisitions += 1
            self._hold_t0[threading.get_ident()] = time.monotonic_ns()
            self._san._held().add(self._name)
        else:
            tele.contentions += 1
        return ok

    def release(self) -> None:
        ident = threading.get_ident()
        t0 = self._hold_t0.pop(ident, None)
        if t0 is not None:
            self._san._telemetry(self._name).record_hold(
                time.monotonic_ns() - t0)
        self._san._held().discard(self._name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __getattr__(self, name):
        inner = self.__dict__.get("_inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)


_EXCLUSIVE = "exclusive"
_SHARED = "shared"


@dataclass
class _Shadow:
    """Eraser state for one (object, attribute)."""

    threads: set = field(default_factory=set)
    lockset: frozenset | None = None      # None until a 2nd thread appears
    written_shared: bool = False
    reported: bool = False

    @property
    def state(self) -> str:
        return _SHARED if len(self.threads) > 1 else _EXCLUSIVE


def _is_lock_like(value) -> bool:
    return (hasattr(value, "release")
            and (hasattr(value, "acquire") or hasattr(value, "try_acquire")))


class Sanitizer:
    """Instrument locks and attribute accesses, run the Eraser state
    machine, and validate static RACE findings against the evidence."""

    def __init__(self):
        self._meta = threading.Lock()       # leaf lock for sanitizer state
        self._tl = threading.local()
        self._locks: dict[str, LockTelemetry] = {}
        self._shadows: dict[tuple, _Shadow] = {}
        self._races: list[dict] = []
        self._traced_ids: set[int] = set()
        self._patched: dict[type, tuple] = {}   # cls -> (orig_set, orig_get)
        self._alive: set[int] = set()
        self._alive_stamp = 0.0

    # -- lifecycle --------------------------------------------------------------
    def __enter__(self) -> "Sanitizer":
        return self

    def __exit__(self, *exc) -> None:
        self.uninstrument()

    def uninstrument(self) -> None:
        """Restore every patched class.  Safe to call twice."""
        with self._meta:
            patched, self._patched = self._patched, {}
            self._traced_ids.clear()
        for cls, (orig_set, orig_get) in patched.items():
            cls.__setattr__ = orig_set
            cls.__getattribute__ = orig_get

    # -- per-thread state -------------------------------------------------------
    def _held(self) -> set:
        held = getattr(self._tl, "held", None)
        if held is None:
            held = self._tl.held = set()
        return held

    def _in_hook(self) -> bool:
        return getattr(self._tl, "busy", False)

    def _telemetry(self, name: str) -> LockTelemetry:
        tele = self._locks.get(name)
        if tele is None:
            with self._meta:
                tele = self._locks.setdefault(name, LockTelemetry(name))
        return tele

    def _alive_idents(self) -> set:
        # refreshing via threading.enumerate() on every access would
        # dominate the run; a 1 ms cache is far finer than any
        # spawn/join cadence that matters for liveness pruning
        now = time.monotonic()
        if now - self._alive_stamp > 1e-3:
            self._alive = {t.ident for t in threading.enumerate()}
            self._alive_stamp = now
        return self._alive

    # -- lock wrapping ----------------------------------------------------------
    def wrap_lock(self, lock, name: str):
        """Wrap a lock for tracing; picks the wrapper by duck type."""
        if isinstance(lock, (TracedLock, TracedTryLock)):
            return lock
        if hasattr(lock, "try_acquire"):
            return TracedTryLock(lock, name, self)
        return TracedLock(lock, name, self)

    # -- attribute tracing ------------------------------------------------------
    def trace(self, obj) -> None:
        """Record every attribute access on ``obj`` (patches
        ``type(obj)``; only traced instances report)."""
        cls = type(obj)
        with self._meta:
            self._traced_ids.add(id(obj))
            if cls in self._patched:
                return
            orig_set = cls.__setattr__
            orig_get = cls.__getattribute__
            self._patched[cls] = (orig_set, orig_get)
        san = self

        def traced_setattr(inst, name, value):
            orig_set(inst, name, value)
            if san._in_hook() or name.startswith("__"):
                return
            san._tl.busy = True
            try:
                if id(inst) in san._traced_ids and not _is_lock_like(value):
                    san._record(inst, name, is_write=True)
            finally:
                san._tl.busy = False

        def traced_getattribute(inst, name):
            value = orig_get(inst, name)
            if san._in_hook() or name.startswith("__"):
                return value
            san._tl.busy = True
            try:
                if (id(inst) in san._traced_ids and not callable(value)
                        and not _is_lock_like(value)):
                    san._record(inst, name, is_write=False)
            finally:
                san._tl.busy = False
            return value

        cls.__setattr__ = traced_setattr
        cls.__getattribute__ = traced_getattribute

    # -- the Eraser state machine -----------------------------------------------
    def _record(self, obj, attr: str, *, is_write: bool) -> None:
        ident = threading.get_ident()
        held = frozenset(self._held())
        key = (id(obj), attr)
        cls_name = type(obj).__name__
        with self._meta:
            sh = self._shadows.get(key)
            if sh is None:
                sh = self._shadows[key] = _Shadow(threads={ident})
                return
            if ident not in sh.threads:
                sh.threads.add(ident)
            if len(sh.threads) > 1:
                alive = self._alive_idents()
                sh.threads = {t for t in sh.threads
                              if t == ident or t in alive}
            if len(sh.threads) == 1:
                # exclusive (possibly re-acquired after old owners died):
                # no candidate lockset yet
                sh.lockset = None
                sh.written_shared = False
                return
            if sh.lockset is None:
                sh.lockset = held
            else:
                sh.lockset = sh.lockset & held
            if is_write:
                sh.written_shared = True
            if sh.written_shared and not sh.lockset and not sh.reported:
                # the cheap alive-cache (1 ms) can hold just-joined
                # threads; a report is rare enough to afford an exact
                # re-check, which kills the read-after-join FP
                self._alive = {t.ident for t in threading.enumerate()}
                self._alive_stamp = time.monotonic()
                sh.threads = {t for t in sh.threads
                              if t == ident or t in self._alive}
                if len(sh.threads) <= 1:
                    sh.lockset = None
                    sh.written_shared = False
                    return
                sh.reported = True
                self._races.append({
                    "class": cls_name,
                    "attr": attr,
                    "kind": "write" if is_write else "read",
                    "threads": len(sh.threads),
                    "thread": threading.current_thread().name,
                })

    # -- convenience instrumentation --------------------------------------------
    def instrument_runtime(self, rt) -> None:
        """Swap the Runtime's stats lock and every queue TryLock for
        traced wrappers and trace the shared objects themselves."""
        rt._stats_lock = self.wrap_lock(rt._stats_lock, "_stats_lock")
        for i, q in enumerate(getattr(rt, "queues", [])):
            q.lock = self.wrap_lock(q.lock, "queue.lock")
            self.trace(q)
        self.trace(rt)
        stats = getattr(rt, "stats", None)
        if stats is not None:
            self.trace(stats)

    def instrument_server(self, server) -> None:
        server._submit_lock = self.wrap_lock(server._submit_lock,
                                             "_submit_lock")
        server._engine_lock = self.wrap_lock(server._engine_lock,
                                             "_engine_lock")
        self.trace(server)
        self.instrument_runtime(server._runtime)

    # -- results ----------------------------------------------------------------
    def races(self) -> list[dict]:
        with self._meta:
            return list(self._races)

    def confirmed_races(self) -> list[dict]:
        """Deduplicated by (class, attr) — the assertion surface."""
        seen, out = set(), []
        for r in self.races():
            k = (r["class"], r["attr"])
            if k not in seen:
                seen.add(k)
                out.append(r)
        return out

    def lock_report(self) -> dict:
        with self._meta:
            return {name: tele.to_json()
                    for name, tele in sorted(self._locks.items())}

    def validate(self, findings) -> list[dict]:
        """Static findings -> CONFIRMED / UNOBSERVED.

        Accepts ``Finding`` objects or their ``to_json`` dicts; matches
        on the attribute (and class, when the message names one) that
        the static message quotes."""
        raced = {(r["class"], r["attr"]) for r in self.races()}
        raced_attrs = {a for _, a in raced}
        out = []
        for f in findings:
            d = f if isinstance(f, dict) else f.to_json()
            msg = d.get("message", "")
            attrs = _QUOTED_SELF.findall(msg) + _QUOTED_CLOSED.findall(msg)
            classes = _QUOTED_CLASS.findall(msg)
            if classes and attrs:
                hit = any((c, a) in raced for c in classes for a in attrs)
            else:
                hit = any(a in raced_attrs for a in attrs)
            out.append({
                "rule": d.get("rule"),
                "fingerprint": d.get("fingerprint"),
                "path": d.get("path"),
                "attrs": attrs,
                "status": "CONFIRMED" if hit else "UNOBSERVED",
            })
        return out

    def report(self, static_findings=None) -> dict:
        payload = {
            "schema": "repro-sanitizer/1",
            "races": self.confirmed_races(),
            "locks": self.lock_report(),
        }
        if static_findings is not None:
            payload["validated"] = self.validate(static_findings)
        return payload

    def save(self, path: Path, static_findings=None) -> None:
        path.write_text(json.dumps(self.report(static_findings), indent=2)
                        + "\n")
