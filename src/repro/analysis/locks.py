"""Lock-discipline pass: the threaded runtime's TryLock/Lock rules.

The paper's retrieval loop is built on a *non-blocking* queue ownership
lock (``TryLock``) plus a short-critical-section ``threading.Lock`` for
shared stats.  Three machine-checkable rules keep that structure honest
as the runtime grows:

  - **LOCK001** — a cycle in the lock-acquisition graph: lock B is
    blocking-acquired while holding A in one place and A while holding
    B in another (including the self-loop: re-acquiring a held
    non-reentrant lock).  Edges are collected across *all* scanned
    files, so the graph spans ``runtime.py`` / ``queues.py`` /
    ``assignment.py`` / ``core/trylock.py`` and whatever else acquires
    locks.
  - **LOCK002** — a *blocking* acquisition (``with lock:`` or
    ``lock.acquire()``) while holding a ``TryLock``: the entire point
    of try-lock retrieval is that a poller never blocks while it owns a
    queue — a blocked owner stalls every producer and defeats the
    paper's Listing-2 loop shape.
  - **LOCK003** — a write to stats state outside its guard lock.  The
    protected set is *derived*, not declared: any object mutated inside
    a ``with self._stats_lock:`` block anywhere in the class (through
    aliases like ``st = self.stats``) is stats-family; mutating it
    elsewhere without the guard races the poller threads.  Lifecycle
    methods (``__init__``/``start``/``stop``/``reset``/``close``) are
    exempt — they run while the threads are quiescent.

The analysis is intra-procedural by design: cross-function holds (e.g.
a callback invoked under a lock) are invisible to it.  Locks are
identified by their attribute name (``q.lock`` and ``self.lock`` are
one graph node, ``lock``), which matches how this codebase names its
locks one-class-per-role.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass

from .core import ERROR, AnalysisPass, Finding, SourceFile, register

__all__ = ["LockDisciplinePass"]

_EXEMPT_METHODS = {"__init__", "start", "stop", "reset", "close",
                   "__enter__", "__exit__"}
_MUTATORS = {"append", "extend", "insert", "add", "update", "pop",
             "popitem", "remove", "discard", "clear", "setdefault",
             "sort", "reverse"}


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            parts.append("[]")
            node = node.value
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        else:
            return None


def _lock_key(expr: ast.AST) -> str | None:
    """Graph-node name for a lock expression: its last attribute
    segment, if it smells like a lock."""
    d = _dotted(expr)
    if d is None:
        return None
    last = d.split(".")[-1]
    if last == "[]" and len(d.split(".")) >= 2:
        last = d.split(".")[-2]
    low = last.lower()
    if "lock" in low or "mutex" in low:
        return last
    return None


@dataclass(frozen=True)
class _Edge:
    held: str
    acquired: str
    path: str
    line: int


@dataclass(frozen=True)
class _Held:
    key: str
    blocking: bool       # False: TryLock / acquire(blocking=False)


def _is_blocking_acquire(call: ast.Call) -> str | None:
    """Lock key if ``call`` is a blocking ``<lock>.acquire(...)``."""
    if not (isinstance(call.func, ast.Attribute)
            and call.func.attr == "acquire"):
        return None
    for kw in call.keywords:
        if (kw.arg == "blocking"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is False):
            return None
    if call.args and isinstance(call.args[0], ast.Constant) \
            and call.args[0].value is False:
        return None
    return _lock_key(call.func.value)


def _is_try_acquire(call: ast.Call) -> str | None:
    if isinstance(call.func, ast.Attribute):
        if call.func.attr == "try_acquire":
            return _lock_key(call.func.value) or \
                _dotted(call.func.value).split(".")[-1]
        if call.func.attr == "acquire":
            key = _lock_key(call.func.value)
            if key and _is_blocking_acquire(call) is None:
                return key
    return None


class _FunctionScanner:
    """Walk one function's statements tracking held locks, emitting
    acquisition edges and LOCK002 violations."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.edges: list[_Edge] = []
        self.findings: list[Finding] = []
        # (key, line) of every blocking acquisition, for LOCK003 reuse
        self.with_regions: list[tuple[str, ast.With]] = []

    def scan(self, fn: ast.FunctionDef) -> None:
        self._stmts(fn.body, [])

    # -- helpers ---------------------------------------------------------------
    def _acquire(self, key: str, blocking: bool, node: ast.AST,
                 held: list[_Held]) -> None:
        for h in held:
            if blocking:
                self.edges.append(_Edge(h.key, key, self.sf.rel,
                                        node.lineno))
            if blocking and not h.blocking:
                self.findings.append(Finding(
                    rule="LOCK002", severity=ERROR, path=self.sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"blocking acquisition of '{key}' while "
                             f"holding TryLock '{h.key}': a queue "
                             "owner must never block")))

    def _stmts(self, stmts: list[ast.stmt], held: list[_Held]) -> None:
        held = list(held)
        for st in stmts:
            # release() of a held lock ends its hold for what follows
            if (isinstance(st, ast.Expr)
                    and isinstance(st.value, ast.Call)
                    and isinstance(st.value.func, ast.Attribute)
                    and st.value.func.attr == "release"):
                key = _lock_key(st.value.func.value)
                if key:
                    held = [h for h in held if h.key != key]
                continue
            if isinstance(st, ast.With):
                inner = list(held)
                for item in st.items:
                    key = _lock_key(item.context_expr)
                    if key:
                        self._acquire(key, True, st, inner)
                        inner.append(_Held(key, True))
                        self.with_regions.append((key, st))
                self._stmts(st.body, inner)
                continue
            if isinstance(st, ast.If):
                key = self._try_acquire_test(st.test)
                if key:
                    self._stmts(st.body, held + [_Held(key, False)])
                    self._stmts(st.orelse, held)
                    continue
                nkey = self._not_try_acquire_test(st.test)
                if nkey and st.body and isinstance(
                        st.body[-1], (ast.Return, ast.Raise,
                                      ast.Continue, ast.Break)):
                    # `if not lock.acquire(blocking=False): return`
                    # guards the rest of the block: held from here on
                    self._stmts(st.body, held)
                    held.append(_Held(nkey, False))
                    continue
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor)):
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                continue
            if isinstance(st, ast.While):
                self._stmts(st.body, held)
                self._stmts(st.orelse, held)
                continue
            if isinstance(st, ast.Try):
                self._stmts(st.body, held)
                for h in st.handlers:
                    self._stmts(h.body, held)
                self._stmts(st.orelse, held)
                self._stmts(st.finalbody, held)
                continue
            # plain statement: blocking .acquire() starts a hold for
            # the remainder of this block
            for node in ast.walk(st):
                if isinstance(node, ast.Call):
                    bkey = _is_blocking_acquire(node)
                    if bkey:
                        self._acquire(bkey, True, node, held)
                        held.append(_Held(bkey, True))

    @staticmethod
    def _try_acquire_test(test: ast.AST) -> str | None:
        if isinstance(test, ast.Call):
            return _is_try_acquire(test)
        return None

    @staticmethod
    def _not_try_acquire_test(test: ast.AST) -> str | None:
        if (isinstance(test, ast.UnaryOp)
                and isinstance(test.op, ast.Not)
                and isinstance(test.operand, ast.Call)):
            call = test.operand
            if _is_try_acquire(call):
                return _is_try_acquire(call)
            if (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "acquire"
                    and _is_blocking_acquire(call) is None):
                return _lock_key(call.func.value)
        return None


@register
class LockDisciplinePass(AnalysisPass):
    name = "lock-discipline"
    rules = {
        "LOCK001": ("cycle in the lock-acquisition graph (potential "
                    "deadlock)"),
        "LOCK002": ("blocking lock acquisition while holding a "
                    "TryLock"),
        "LOCK003": ("write to stats-family state outside its "
                    "_stats_lock guard"),
    }

    def run(self, files: list[SourceFile]) -> list[Finding]:
        findings: list[Finding] = []
        edges: list[_Edge] = []
        for sf in files:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.FunctionDef):
                    sc = _FunctionScanner(sf)
                    sc.scan(node)
                    findings.extend(sc.findings)
                    edges.extend(sc.edges)
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ClassDef):
                    findings.extend(_check_stats_guard(sf, node))
        findings.extend(_find_cycles(edges))
        return findings


def _find_cycles(edges: list[_Edge]) -> list[Finding]:
    graph: dict[str, dict[str, _Edge]] = {}
    for e in edges:
        graph.setdefault(e.held, {}).setdefault(e.acquired, e)
    out: list[Finding] = []
    reported: set[frozenset] = set()
    for start in sorted(graph):
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in sorted(graph.get(node, {})):
                if nxt == start:
                    cyc = frozenset(path)
                    if cyc in reported:
                        continue
                    reported.add(cyc)
                    e = graph[node][nxt]
                    chain = " -> ".join(path + [start])
                    out.append(Finding(
                        rule="LOCK001", severity=ERROR, path=e.path,
                        line=e.line, col=0,
                        message=(f"lock-acquisition cycle: {chain} "
                                 "(deadlock when the acquisitions "
                                 "interleave)")))
                elif nxt not in path:
                    stack.append((nxt, path + [nxt]))
    return out


def _guard_lock_name(cls: ast.ClassDef) -> str | None:
    for node in ast.walk(cls):
        if isinstance(node, ast.Attribute) and "stats_lock" in node.attr:
            return node.attr
    return None


def _check_stats_guard(sf: SourceFile, cls: ast.ClassDef
                       ) -> list[Finding]:
    guard = _guard_lock_name(cls)
    if guard is None:
        return []

    def resolve(path: str | None, aliases: dict[str, str]) -> str | None:
        if path is None:
            return None
        head, _, rest = path.partition(".")
        head = aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    def method_aliases(fn: ast.FunctionDef) -> dict[str, str]:
        """Local name -> dotted self-path (``st = self.stats``)."""
        out: dict[str, str] = {}
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                d = _dotted(node.value)
                if d and d.startswith("self."):
                    out[node.targets[0].id] = d
        return out

    def mutations(region: ast.AST):
        """(object-path, node) pairs mutated in ``region``: attribute /
        subscript writes and mutating method calls."""
        for node in ast.walk(region):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        yield _dotted(tgt.value), node
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in _MUTATORS):
                yield _dotted(node.func.value), node

    # pass 1: derive the protected roots from guarded regions
    protected: set[str] = set()
    guarded_nodes: set[int] = set()
    methods = [n for n in cls.body if isinstance(n, ast.FunctionDef)]
    for fn in methods:
        aliases = method_aliases(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.With) and any(
                    (_lock_key(i.context_expr) or "") == guard
                    for i in node.items):
                for sub in ast.walk(node):
                    guarded_nodes.add(id(sub))
                for path, mnode in mutations(node):
                    r = resolve(path, aliases)
                    if r and r.startswith("self."):
                        protected.add(".".join(r.split(".")[:2]))
    if not protected:
        return []

    # pass 2: mutations of protected roots outside guarded regions
    out: list[Finding] = []
    for fn in methods:
        if fn.name in _EXEMPT_METHODS:
            continue
        aliases = method_aliases(fn)
        for path, node in mutations(fn):
            if id(node) in guarded_nodes:
                continue
            r = resolve(path, aliases)
            if r is None:
                continue
            root = ".".join(r.split(".")[:2])
            if root in protected:
                out.append(Finding(
                    rule="LOCK003", severity=ERROR, path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=(f"write to stats-family '{root}' outside "
                             f"'with self.{guard}' in method "
                             f"'{fn.name}' races the poller threads")))
    return out
