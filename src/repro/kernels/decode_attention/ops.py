"""jit'd public wrapper for decode attention."""

from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_pallas
from .ref import reference_decode_attention


@functools.partial(jax.jit, static_argnames=(
    "softcap", "window", "use_kernel", "block_k", "interpret"))
def decode_attention(q, k, v, pos, *, softcap: float = 0.0, window: int = 0,
                     use_kernel: bool = True, block_k: int = 1024,
                     interpret: bool = True):
    """q: (B, H, hd); k, v cache: (B, T, KV, hd); pos: (B,) -> (B, H, hd)."""
    if use_kernel:
        return decode_attention_pallas(q, k, v, pos, softcap=softcap,
                                       window=window, block_k=block_k,
                                       interpret=interpret)
    return reference_decode_attention(q, k, v, pos, softcap=softcap,
                                      window=window)
