"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def reference_decode_attention(q, k, v, pos, *, softcap: float = 0.0,
                               window: int = 0, scale: float | None = None):
    """q: (B, H, hd); k, v: (B, T, KV, hd); pos: (B,) -> (B, H, hd)."""
    b, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(b, kv, group, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= pos[:, None]
    if window:
        mask &= kpos > (pos[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
