from .ops import decode_attention  # noqa: F401
from .ref import reference_decode_attention  # noqa: F401
