"""Single-token GQA decode attention over a long KV cache (Pallas TPU).

The serve_step hot loop: one query token per sequence against a KV cache
of up to 524288 positions.  Memory-bound by construction (every KV byte is
read once), so the kernel's job is streaming the cache through VMEM in
(bk, hd) tiles at full HBM bandwidth while accumulating the online softmax.

  grid = (batch, q_head, T/bk); kv-block innermost/sequential.
  Per-sequence valid length arrives via scalar prefetch (SMEM) — tokens
  beyond `pos` are masked, so ragged continuous-batching batches work.

Validated against ref.reference_decode_attention in interpret mode
(tests/test_kernels_decode.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, softcap: float, window: int,
                   bk: int, kv_blocks: int):
    b = pl.program_id(0)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (1, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)        # (1, bk)

    pos = pos_ref[b]
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = kpos <= pos
    if window:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        l = l_ref[0, 0]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, pos, *, softcap: float = 0.0,
                            window: int = 0, scale: float | None = None,
                            block_k: int = 1024, interpret: bool = True):
    """q: (B, H, hd); k, v: (B, T, KV, hd); pos: (B,) int32.

    Returns (B, H, hd).  KV layout is the cache layout (seq-major) — the
    kernel transposes per-tile via the index map, not in HBM.
    """
    b, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    bk = min(block_k, t)
    assert t % bk == 0, (t, bk)
    kv_blocks = t // bk
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=softcap, window=window,
        bk=bk, kv_blocks=kv_blocks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b_, h_, j, pos_: (b_, h_, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b_, h_, j, pos_, g=group: (b_, j, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b_, h_, j, pos_, g=group: (b_, j, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b_, h_, j, pos_: (b_, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(pos, q, k, v)
    return out
