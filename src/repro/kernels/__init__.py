"""Pallas TPU kernels for the serving/training compute hot spots.

Each kernel ships as <name>/{kernel.py, ops.py, ref.py}: the pallas_call +
BlockSpec tiling, the jit'd public wrapper, and the pure-jnp oracle it is
validated against (interpret mode on CPU; the TPU target is declared in
the BlockSpecs).  The paper's own contribution is host-side control
(DESIGN.md) — these kernels serve the model substrate it feeds.
"""

from .decode_attention import decode_attention, reference_decode_attention  # noqa: F401
from .flash_attention import flash_attention, reference_attention  # noqa: F401
from .ssd_scan import reference_ssd_scan, ssd_scan  # noqa: F401
