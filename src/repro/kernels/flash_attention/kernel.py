"""Flash-attention Pallas TPU kernel (prefill/train path).

Blocked online-softmax attention with explicit BlockSpec VMEM tiling:
  grid = (batch, q_head, S/bq, T/bk), kv-block innermost & sequential;
  running (m, l, acc) state lives in VMEM scratch and is re-initialized at
  kv-block 0, finalized (acc / l) at the last kv block.

Supports GQA (q-head -> kv-head via integer division in the k/v index
maps), causal and local-window masking (gemma2), attention-logit softcap,
and fp32 accumulation regardless of input dtype.

Block shapes: (bq, head_dim) q tiles and (bk, head_dim) k/v tiles — the
working set per grid step is bq*hd + 2*bk*hd + bq*bk floats; with
bq = bk = 512, hd = 128 that is ~0.9 MB fp32, comfortably inside the
~16 MB/core VMEM with double buffering.  MXU alignment: hd is a multiple
of 128 for every assigned arch except whisper (64).

Validated against ref.reference_attention in interpret mode
(tests/test_kernels_flash.py) over shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                  # rows with no valid kv
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = True):
    """q: (B, H, S, hd); k, v: (B, KV, T, hd).  Returns (B, H, S, hd)."""
    b, h, s, hd = q.shape
    _, kv, t, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    kv_blocks = t // bk
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_blocks=kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=(b, h, s // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
