"""jit'd public wrapper for flash attention (kernel or oracle path).

``flash_attention`` takes model-layout tensors (B, S, H, hd) / (B, T, KV,
hd) like models/attention.py produces, transposes to the kernel layout,
and dispatches to the Pallas kernel (interpret mode off-TPU) or the
reference oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import reference_attention


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "use_kernel", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, use_kernel: bool = True,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) -> (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if use_kernel:
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, interpret=interpret)
    else:
        out = reference_attention(qt, kt, vt, causal=causal, window=window,
                                  softcap=softcap)
    return jnp.swapaxes(out, 1, 2)
