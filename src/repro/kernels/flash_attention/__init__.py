from .ops import flash_attention  # noqa: F401
from .ref import reference_attention  # noqa: F401
