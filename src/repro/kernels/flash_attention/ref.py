"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def reference_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float | None = None):
    """q: (B, H, S, hd); k, v: (B, KV, T, hd).  Returns (B, H, S, hd)."""
    b, h, s, hd = q.shape
    kv, t = k.shape[1], k.shape[2]
    group = h // kv
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(b, kv, group, s, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)
