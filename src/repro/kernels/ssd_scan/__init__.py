from .ops import ssd_scan  # noqa: F401
from .ref import reference_ssd_scan  # noqa: F401
