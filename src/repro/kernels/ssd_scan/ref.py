"""Pure-jnp oracle for the SSD chunk-scan kernel.

Delegates to models.mamba2.ssd_chunked (the reference implementation the
model uses), adapting the (BH, NC, Q, ...) kernel layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def reference_ssd_scan(x, da, dt, bmat, cmat):
    """Same signature as kernel.ssd_scan_pallas; head-count 1 per row."""
    bh, nc, q, hd = x.shape
    n = bmat.shape[-1]
    length = nc * q
    # ssd_chunked wants (B, L, nh, hd) with a (nh,) decay rate; we fold the
    # per-step decay into dt by using a = -1 and dt_decay = -da.
    xs = x.reshape(bh, length, 1, hd)
    dts = dt.reshape(bh, length, 1)
    das = da.reshape(bh, length, 1)
    bs = bmat.reshape(bh, length, n)
    cs = cmat.reshape(bh, length, n)
    # ssd_chunked computes decay = dt * a; pass a = -1, dt_for_decay = -da;
    # but dt also scales B x.  Trick: call with dt' = dt and a' = da/dt.
    # Simpler: re-derive with a = -1 and feed da directly by scaling.
    y, h = _ssd_direct(xs, dts, das, bs, cs, q)
    return y.reshape(bh, nc, q, hd), h.reshape(bh, hd, n)


def _ssd_direct(x, dt, da, bmat, cmat, chunk):
    """Sequential O(L) reference recurrence (independent of chunking)."""
    import jax

    bsz, length, nh, hd = x.shape
    n = bmat.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xt, dtt, dat, bt, ct = inp
        h = jnp.exp(dat)[..., None, None] * h + \
            dtt[..., None, None] * (xt[..., :, None] * bt[:, None, None, :])
        y = jnp.einsum("bn,bhdn->bhd", ct, h)
        return h, y

    h0 = jnp.zeros((bsz, nh, hd, n), f32)
    xs = (jnp.moveaxis(x.astype(f32), 1, 0),
          jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(da.astype(f32), 1, 0),
          jnp.moveaxis(bmat.astype(f32), 1, 0),
          jnp.moveaxis(cmat.astype(f32), 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (B, L, nh, hd)
    return y, h[:, 0]                            # nh = 1 rows
