"""jit'd public wrapper for the SSD chunk scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_pallas
from .ref import reference_ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 256,
             use_kernel: bool = True, interpret: bool = True):
    """Model-layout SSD scan (drop-in for models.mamba2.ssd_chunked).

    x: (B, L, nh, hd); dt: (B, L, nh); a: (nh,); bmat/cmat: (B, L, N).
    Returns (y: (B, L, nh, hd), h_final: (B, nh, hd, N)).
    """
    b, length, nh, hd = x.shape
    n = bmat.shape[-1]
    assert length % chunk == 0
    nc = length // chunk
    da = dt * a[None, None, :]                              # (B, L, nh)
    # fold heads into rows: (B*nh, NC, Q, ...)
    xk = x.transpose(0, 2, 1, 3).reshape(b * nh, nc, chunk, hd)
    dak = da.transpose(0, 2, 1).reshape(b * nh, nc, chunk)
    dtk = dt.transpose(0, 2, 1).reshape(b * nh, nc, chunk)
    bk = jnp.broadcast_to(bmat[:, None], (b, nh, length, n)).reshape(
        b * nh, nc, chunk, n)
    ck = jnp.broadcast_to(cmat[:, None], (b, nh, length, n)).reshape(
        b * nh, nc, chunk, n)
    if use_kernel:
        y, h = ssd_scan_pallas(xk, dak, dtk, bk, ck, interpret=interpret)
    else:
        y, h = reference_ssd_scan(xk, dak, dtk, bk, ck)
    y = y.reshape(b, nh, length, hd).transpose(0, 2, 1, 3)
    h = h.reshape(b, nh, hd, n)
    return y.astype(x.dtype), h
