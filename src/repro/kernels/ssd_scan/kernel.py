"""Mamba2 SSD chunk-scan Pallas TPU kernel.

TPU-native formulation of the SSD (state-space duality) forward: the
sequence is pre-chunked (B, NC, Q, ...); the grid walks (batch*head,
chunk) with the chunk axis innermost and *sequential*, carrying the
running (hd, N) recurrent state in VMEM scratch across grid steps — the
standard TPU trick for inter-block recurrences (cf. flash attention's
running softmax).  Per grid step the kernel computes, entirely in VMEM:

  intra-chunk (MXU):  y += ((C B^T) .* decay .* dt) @ x      (Q x Q dots)
  inter-chunk (MXU):  y += exp(cum) .* (C @ h_prev^T)
  state update:       h  = exp(cum_last) h_prev + (decay_out dt B)^T x

Working set per step: Q*(hd + 2N) + Q*Q + hd*N floats — with Q = 256,
hd = 64, N = 128 that's ~0.4 MB fp32, VMEM-friendly with double buffering.

Validated against models/mamba2.ssd_chunked (the pure-jnp oracle, re-used
as ref) in tests/test_kernels_ssd.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, q: int, nc: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, hd)
    da = da_ref[0, 0].astype(jnp.float32)        # (Q,)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    cum = jnp.cumsum(da)                         # inclusive in-chunk decay
    # intra-chunk: decay[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = ii >= jj
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    att = scores * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state h_prev (hd, N)
    h_prev = h_ref[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = exp(cum_last) h_prev + sum_j w_j x_j B_j^T
    decay_out = jnp.exp(cum[-1] - cum) * dt      # (Q,)
    s_chunk = jax.lax.dot_general(x * decay_out[:, None], bmat,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(cum[-1]) * h_prev + s_chunk  # (hd, N)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(cj == nc - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(x, da, dt, bmat, cmat, *, interpret: bool = True):
    """Chunked SSD scan.

    x:    (BH, NC, Q, hd)  per-(batch*head) chunked inputs
    da:   (BH, NC, Q)      log-decay  dt*A  (negative)
    dt:   (BH, NC, Q)      step sizes
    bmat: (BH, NC, Q, N)   input projections  (already head-broadcast)
    cmat: (BH, NC, Q, N)   output projections
    Returns (y: (BH, NC, Q, hd), h_final: (BH, hd, N)), fp32.
    """
    bh, nc, q, hd = x.shape
    n = bmat.shape[-1]
    kernel = functools.partial(_ssd_kernel, q=q, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, hd, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, q, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(x, da, dt, bmat, cmat)
