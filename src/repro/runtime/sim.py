"""Event-driven simulation engine: any policy × any workload.

Generalizes the paper-specific renewal simulator (Sec 4/5 apparatus) into
an engine that executes an arbitrary ``RetrievalPolicy`` against an
arbitrary ``Workload`` over one or more Rx queues: a ``Dispatcher``
splits arrivals across queues (RSS emulation), an ``Assignment`` decides
which threads sweep which queues, a waking poller races each queue's
lock, the winner drains at deterministic rate mu (busy-period recursion,
arrivals drawn from the workload meanwhile), losers re-sleep whatever
the policy tells them.  Sleep overshoot follows a measured-from-the-
paper affine model (Table 1) so "what if this policy ran on nanosleep?"
is answerable without kernel patches.

The environment model (``SimRunConfig``, ``SleepModel``) and run-setup
normalization live in ``repro.runtime.simcore``, shared with the batched
JAX engine (``repro.runtime.batched``) — this module is the *exact,
serial* engine of the pair: it walks the event sequence one wake at a
time and explores one configuration per call.

With ``n_queues=1`` and the default round-robin dispatcher the engine
reduces *exactly* to the original single-queue event sequence — same
seed, same wakeups/cycles/drops — which the regression tests pin down.

Aggregate-exact accounting: arrivals are *counts per window*
(``workload.counts_in``), never per-packet events, so a 10s line-rate
simulation costs O(#cycles) not O(#packets).

Spinning policies (``policy.spin``) switch to an analytic fluid model —
a per-wake event loop for a policy that never sleeps would cost O(time /
poll granularity) for information a closed form already gives.
"""

from __future__ import annotations

import numpy as np

from .policy import WakeContext
from .simcore import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimRunConfig,
    SleepModel,
    WindowAccum,
    prepare_run,
    queue_reservoirs,
    scheduled_workload,
)
from .stats import QueueStats, Reservoir, RunStats

__all__ = [
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimRunConfig",
    "simulate_run",
    "simulate_fleet_run",
    "fleet_tail_reference",
]


def simulate_run(policy, workload, cfg: SimRunConfig | None = None, *,
                 dispatcher=None, assignment=None) -> RunStats:
    """Execute ``policy`` against ``workload`` in simulated time.

    ``dispatcher`` (default ``RoundRobinDispatch``) splits arrivals
    across ``cfg.n_queues`` Rx queues; ``assignment`` (default
    ``SharedAssignment``) maps poller threads to queues.  Spinning
    policies use the analytic fluid model and ignore both (a sweeping
    core sees the union of all rings).
    """
    cfg = cfg or SimRunConfig()
    if getattr(policy, "spin", False):
        return _simulate_spin(policy, workload, cfg)

    base_wl = getattr(workload, "base", workload)   # unwrap pre-scheduled
    workload_label = getattr(base_wl, "name", type(base_wl).__name__)
    setup = prepare_run(policy, workload, cfg, dispatcher=dispatcher,
                        assignment=assignment)
    workload = setup.workload      # schedule-wrapped when cfg.schedule set
    rng = setup.rng
    nq = setup.n_queues
    dispatcher = setup.dispatcher
    slots = setup.slots
    pols = setup.policies
    m = len(slots)
    mu = cfg.service_rate_mpps

    # Threads are launched actively (paper Sec 5): first wakes land within
    # one short timeout, not spread over T_L (that would fabricate a startup
    # backlog transient the real system does not have).
    wake_at = np.empty(m)
    for p in pols:
        idxs = [i for i, s in enumerate(slots) if s.policy is p]
        t_s0 = p.on_wake(WakeContext(primary=True)) / 1e3
        wake_at[idxs] = rng.uniform(0.0, max(t_s0, 1e-3), size=len(idxs))

    backlog = np.zeros(nq)
    last_advanced = 0.0      # arrivals accounted up to here
    busy_until = np.zeros(nq)         # each lock held until this time
    last_busy_end = np.zeros(nq)

    offered = dropped = serviced = busy_tries = wakeups = 0
    truncations = 0
    offered_q = np.zeros(nq, dtype=np.int64)
    dropped_q = np.zeros(nq, dtype=np.int64)
    serviced_q = np.zeros(nq, dtype=np.int64)
    busy_tries_q = np.zeros(nq, dtype=np.int64)
    cycles_q = np.zeros(nq, dtype=np.int64)
    vac, bus, nvs = [], [], []
    # one latency reservoir per queue, decorrelated seeds (simcore)
    lat_q = queue_reservoirs(cfg, nq)
    awake_us = 0.0
    lat_area = 0.0           # queue-depth integral (packet*us), Little's law
    # EnergyModel accounting: active power on awake time, sleep +
    # transition charged per armed sleep at its programmed target (the
    # same arm-time convention as the batched kernels; the m initial
    # staggering sleeps are uncharged in every engine)
    em = cfg.energy_model
    energy_uj = 0.0

    nbins = int(cfg.duration_us / cfg.timeseries_bin_us) if cfg.timeseries_bin_us else 0
    b_rho = np.zeros(max(nbins, 1)); b_ts = np.zeros(max(nbins, 1))
    b_srv = np.zeros(max(nbins, 1)); b_off = np.zeros(max(nbins, 1))
    b_cnt = np.zeros(max(nbins, 1))
    wa = WindowAccum(cfg)        # no-op when cfg.window_us == 0

    def admit(q: int, n: int, at_t: float) -> None:
        """Room-clipped enqueue of ``n`` arrivals into queue ``q``; drops
        beyond queue capacity are counted (Rx-ring semantics)."""
        nonlocal offered, dropped
        offered += n
        offered_q[q] += n
        wa.add(at_t, offered=n)
        room = cfg.queue_capacity - backlog[q]
        if n > room:
            d = int(n - max(room, 0))
            dropped += d
            dropped_q[q] += d
            n = int(max(room, 0))
        backlog[q] += n
        if nbins:
            b = min(int(at_t / cfg.timeseries_bin_us), nbins - 1)
            b_off[b] += n + 0.0

    def advance_arrivals(to_t: float) -> None:
        """Accumulate workload arrivals on [last_advanced, to_t) and
        dispatch them across the queues."""
        nonlocal last_advanced
        if to_t <= last_advanced:
            return
        n = workload.counts_in(last_advanced, to_t)
        if nq == 1:
            admit(0, n, last_advanced)
        elif n > 0:
            parts = dispatcher.split(int(n), backlog)
            for q in range(nq):
                if parts[q]:
                    admit(q, int(parts[q]), last_advanced)
        last_advanced = to_t

    def drain(q: int, t_start: float) -> tuple[float, int]:
        """Busy-period recursion on queue ``q``: serve its backlog at rate
        mu, dispatch workload arrivals meanwhile (this queue's share
        continues the recursion, other queues just accumulate), repeat
        until empty (round-capped so saturated runs still terminate;
        leftovers stay queued and the truncation is counted)."""
        nonlocal offered, dropped, last_advanced, truncations, lat_area
        total_t = 0.0
        served = 0.0
        cursor = t_start
        rounds = 0
        while backlog[q] >= 1.0 and rounds < 64:
            dt = backlog[q] / mu
            b_r = float(backlog[q])
            served += float(backlog[q])
            total_t += dt
            n = workload.counts_in(cursor, cursor + dt)
            cursor += dt
            if nq == 1:
                own = int(n)
            else:
                own = 0
                if n > 0:
                    parts = dispatcher.split(int(n), backlog)
                    own = int(parts[q])
                    for j in range(nq):
                        if j != q and parts[j]:
                            admit(j, int(parts[j]), cursor)
            offered += own
            offered_q[q] += own
            if own > cfg.queue_capacity:
                d = own - cfg.queue_capacity
                dropped += d
                dropped_q[q] += d
                own = cfg.queue_capacity
            backlog[q] = float(own)
            # Little integral, drain round r: the b_r being served decline
            # linearly to 0 over dt while the next round's own arrivals
            # accumulate linearly to `own`
            lat_area += dt * (b_r + own) / 2.0
            wa.add(cursor, offered=own, served=b_r,
                   lat_area=dt * (b_r + own) / 2.0)
            if nbins:
                # bin the drained queue's own busy-period arrivals too, so
                # sum(offered_series * bin) tracks RunStats.offered
                b = min(int(cursor / cfg.timeseries_bin_us), nbins - 1)
                b_off[b] += own + 0.0
            rounds += 1
        if backlog[q] >= 1.0:
            truncations += 1
        last_advanced = max(last_advanced, cursor)
        return total_t, int(served)

    # correlated stall windows (lazy Poisson process)
    next_stall = (rng.exponential(1.0 / cfg.stall_rate_per_us)
                  if cfg.stall_rate_per_us else np.inf)
    stall_end = -1.0

    while True:
        i = int(np.argmin(wake_at))
        t = float(wake_at[i])
        if t >= cfg.duration_us:
            break
        if cfg.stall_rate_per_us:
            while next_stall <= t:
                stall_end = max(stall_end,
                                next_stall + rng.exponential(cfg.stall_mean_us))
                next_stall += rng.exponential(1.0 / cfg.stall_rate_per_us)
            if t < stall_end:
                wake_at[i] = stall_end + rng.uniform(0.0, 1.0)
                continue
        wakeups += 1
        awake_us += cfg.wake_cost_us
        e_wake = em.active_power_w * cfg.wake_cost_us
        energy_uj += e_wake
        wa.add(t, awake=cfg.wake_cost_us, energy_uj=e_wake)
        advance_arrivals(t)

        slot = slots[i]
        pol = slot.policy
        lock_taken = False
        t_cursor = t
        srv_total = 0
        targets = list(slot.queues)
        visited = set(targets)
        si = 0
        while si < len(targets):
            q = targets[si]
            si += 1
            if t_cursor < busy_until[q]:
                # trylock failed: another poller is draining this queue.
                busy_tries += 1
                busy_tries_q[q] += 1
            else:
                # trylock won: primary for this queue. Vacation ended now.
                lock_taken = True
                v = t_cursor - float(last_busy_end[q])
                n_v = float(backlog[q])
                # Little integral, vacation phase: the n_v packets found
                # at busy start arrived ~uniformly over the vacation
                lat_area += n_v * max(v, 0.0) / 2.0
                wa.add(t_cursor, lat_area=n_v * max(v, 0.0) / 2.0)
                b_time, srv = drain(q, t_cursor)
                serviced += srv
                serviced_q[q] += srv
                srv_total += srv
                cycles_q[q] += 1
                busy_until[q] = t_cursor + b_time
                last_busy_end[q] = busy_until[q]
                awake_us += b_time
                e_busy = em.active_power_w * b_time
                energy_uj += e_busy
                wa.add(t_cursor, awake=b_time, energy_uj=e_busy)

                vac.append(v); bus.append(b_time); nvs.append(n_v)
                # Latency: packets found at busy start waited (uniform
                # arrival in V) V/2 on average + their drain position.
                # Sample a handful per cycle for percentiles.
                if n_v >= 1:
                    k = min(int(n_v), 8)
                    arr = rng.uniform(0.0, max(v, 1e-9), size=k)      # age
                    pos = np.sort(rng.uniform(0.0, n_v, size=k)) / mu
                    samp = (max(v, 1e-9) - arr + pos).tolist()
                    lat_q[q].extend(samp)
                    wa.latency_samples(t_cursor, samp)

                pol.on_cycle_end(b_time, max(v, 1e-9))
                t_cursor = float(busy_until[q])
            if si == len(targets) and slot.steal:
                # own queues done: steal from the longest unvisited backlog
                cand, best = -1, 1.0
                for j in range(nq):
                    if j not in visited and backlog[j] >= best:
                        cand, best = j, float(backlog[j])
                if cand >= 0:
                    targets.append(cand)
                    visited.add(cand)

        if not lock_taken:
            # every ring contended: backup role (unless this thread is its
            # ring's only home poller, in which case it keeps its cadence)
            t_b = pol.on_wake(WakeContext(primary=not slot.demote_on_miss,
                                          now_ns=int(t * 1e3))) / 1e3
            e_arm = em.arm_energy_uj(t_b)
            energy_uj += e_arm
            wa.add(t, energy_uj=e_arm)
            delay = float(cfg.sleep_model.sample(t_b, rng))
            if cfg.interference_prob and rng.random() < cfg.interference_prob:
                delay += rng.exponential(cfg.interference_mean_us)
            wake_at[i] = t + delay
            continue

        t_s = pol.on_wake(WakeContext(primary=True,
                                      now_ns=int(t_cursor * 1e3))) / 1e3
        e_arm = em.arm_energy_uj(t_s)
        energy_uj += e_arm
        wa.add(t_cursor, energy_uj=e_arm)
        wa.control(t, float(getattr(pol, "rho", np.nan)), t_s)
        if nbins:
            b = min(int(t / cfg.timeseries_bin_us), nbins - 1)
            b_rho[b] += getattr(pol, "rho", np.nan)
            b_ts[b] += t_s; b_srv[b] += srv_total; b_cnt[b] += 1

        delay = float(cfg.sleep_model.sample(t_s, rng))
        if cfg.interference_prob and rng.random() < cfg.interference_prob:
            delay += rng.exponential(cfg.interference_mean_us)
        wake_at[i] = t_cursor + delay

    cnt = np.maximum(b_cnt, 1)
    nbins_eff = max(nbins, 1)
    # run-level latency = weighted union of the per-queue reservoirs
    # (a fresh object even for one queue: RunStats.merge pools the
    # run-level and per-queue reservoirs independently, so they must
    # never alias)
    lat = Reservoir(cfg.latency_reservoir, seed=cfg.seed)
    for r in lat_q:
        lat.merge(r)
    sched = cfg.schedule or getattr(workload, "schedule", None)
    return RunStats(
        backend="sim",
        policy=getattr(policy, "name", type(policy).__name__),
        workload=workload_label,
        schedule=sched.descriptor() if sched is not None else "",
        wakeups=wakeups, cycles=len(bus), busy_tries=busy_tries,
        items=serviced, offered=offered, dropped=dropped,
        awake_ns=round(awake_us * 1e3), started_ns=0,
        stopped_ns=round(cfg.duration_us * 1e3),
        latency_us=lat,
        latency_area_us=lat_area,
        energy_uj=energy_uj,
        windows=wa.series(cfg),
        per_queue=[QueueStats(queue=q,
                              offered=int(offered_q[q]),
                              dropped=int(dropped_q[q]),
                              serviced=int(serviced_q[q]),
                              busy_tries=int(busy_tries_q[q]),
                              cycles=int(cycles_q[q]),
                              latency_us=lat_q[q])
                   for q in range(nq)],
        drain_truncations=truncations,
        vacations_us=np.asarray(vac),
        busies_us=np.asarray(bus),
        n_v=np.asarray(nvs),
        rho_series=b_rho / cnt if nbins else np.empty(0),
        ts_series=b_ts / cnt if nbins else np.empty(0),
        tput_series_mpps=(b_srv / cfg.timeseries_bin_us) if nbins else np.empty(0),
        offered_series_mpps=(b_off / cfg.timeseries_bin_us) if nbins else np.empty(0),
        series_t_us=(np.arange(nbins_eff) * cfg.timeseries_bin_us) if nbins
        else np.empty(0),
    )


def _simulate_spin(policy, workload, cfg: SimRunConfig) -> RunStats:
    """Analytic fluid model for spinning policies (paper Listing 1).

    One dedicated core polls continuously; CPU is 100% by construction;
    latency is just the drain position (no vacations); loss only beyond
    saturation.  A spinning sweep sees the union of all Rx rings, so
    multi-queue runs aggregate to one fluid queue of total capacity.

    Correlated stall windows (``cfg.stall_rate_per_us`` /
    ``stall_mean_us``) deschedule even a spinning core — on a shared
    host CFS alternates the always-runnable spinner with competing
    threads — so the fluid model serves *nothing* while a window is
    open: arrivals pile into the ring and overflow it exactly as they
    would on real co-located hardware.  Per-wake interference
    (``interference_prob``) does not apply: a spinner never sleeps, so
    there is no wake to delay.
    """
    rng = np.random.default_rng(cfg.seed)
    base_wl = getattr(workload, "base", workload)   # unwrap pre-scheduled
    workload_label = getattr(base_wl, "name", type(base_wl).__name__)
    workload = scheduled_workload(workload, cfg)
    workload.reset(rng)
    policy.reset()
    q_cap = cfg.queue_capacity * max(int(cfg.n_queues), 1)
    n_threads = max(policy.threads, 1)
    # a spinner never sleeps: flat active burn at the DVFS busy scale
    # (a pinned-turbo core), no C-state or transition component at all
    em = cfg.energy_model
    spin_power_w = float(em.active_energy_uj(1.0, spin=True)) * n_threads
    step = 10.0
    t = 0.0
    offered = dropped = serviced = 0
    backlog = 0.0
    lat_num = 0.0
    wa = WindowAccum(cfg)        # no-op when cfg.window_us == 0
    # lazy Poisson stall process, windows merged via max (the same
    # semantics as the sleep&wake event loop above)
    next_stall = (rng.exponential(1.0 / cfg.stall_rate_per_us)
                  if cfg.stall_rate_per_us else np.inf)
    stall_end = -1.0
    while t < cfg.duration_us:
        n = workload.counts_in(t, t + step)
        offered += n
        stalled = 0.0
        if cfg.stall_rate_per_us:
            # carry-over from windows still open at the step boundary
            if stall_end > t:
                stalled += min(stall_end, t + step) - t
            while next_stall <= t + step:
                # windows merge via max: only the segment not already
                # covered counts, from its true start (not the step's)
                w_start = max(next_stall, stall_end)
                w_end = next_stall + rng.exponential(cfg.stall_mean_us)
                if w_end > w_start:
                    seg0 = min(max(w_start, t), t + step)
                    seg1 = min(max(w_end, t), t + step)
                    stalled += max(seg1 - seg0, 0.0)
                    stall_end = max(stall_end, w_end)
                next_stall += rng.exponential(1.0 / cfg.stall_rate_per_us)
            stalled = min(stalled, step)
        cap = cfg.service_rate_mpps * (step - stalled)
        do = min(backlog + n, cap)
        serviced += int(do)
        backlog = backlog + n - do
        if backlog > q_cap:
            dropped += int(backlog - q_cap)
            backlog = float(q_cap)
        lat_num += backlog * step        # area under queue curve (Little)
        # windowed series: a spinner's CPU is one full core per thread in
        # EVERY window by construction (the flat-burn signature the
        # adaptation benchmark's busy-poll verdict asserts); latency area
        # includes the drain position like the aggregate override
        wa.add(t, offered=n, served=do, awake=step * n_threads,
               lat_area=backlog * step + do / cfg.service_rate_mpps,
               energy_uj=spin_power_w * step)
        t += step
    mean_lat = lat_num / max(serviced, 1)
    sched = cfg.schedule or getattr(workload, "schedule", None)
    return RunStats(
        backend="sim",
        policy=getattr(policy, "name", type(policy).__name__),
        workload=workload_label,
        schedule=sched.descriptor() if sched is not None else "",
        wakeups=0, cycles=1, busy_tries=0,
        items=serviced, offered=offered, dropped=dropped,
        # every spinning thread burns its whole core
        awake_ns=round(cfg.duration_us * 1e3) * n_threads,
        started_ns=0,
        stopped_ns=round(cfg.duration_us * 1e3),
        latency_us=Reservoir(4, seed=cfg.seed),
        latency_area_us=lat_num + serviced / cfg.service_rate_mpps,
        energy_uj=spin_power_w * cfg.duration_us,
        windows=wa.series(cfg),
        latency_override={
            "mean": float(mean_lat + 1.0 / cfg.service_rate_mpps),
            "p99": float(mean_lat * 3 + 1.0 / cfg.service_rate_mpps),
            "worst": float(q_cap / cfg.service_rate_mpps),
        },
        vacations_us=np.zeros(1), busies_us=np.asarray([cfg.duration_us]),
        n_v=np.zeros(1),
    )


def simulate_fleet_run(policy_factory, rate_mpps: float,
                       cfg: SimRunConfig, fleet, *,
                       workload_factory=None) -> list[RunStats]:
    """Exact event-engine reference for a fleet: one ``simulate_run``
    per host at that host's *static* LB share of the fleet-aggregate
    Poisson stream (``FleetConfig.shares()`` — Poisson thinning is
    exact for uniform/weighted splits; ``least-loaded`` is a batched-
    engine-only dynamic policy and uses its uniform long-run share
    here).  Host ``h`` runs with seed ``cfg.seed + h``, matching the
    fleet kernel's per-host key contract, so a fleet row and this
    reference draw host-equivalent randomness.

    ``policy_factory(h)`` must return a FRESH policy object per host
    (policies are stateful); ``workload_factory(host_rate_mpps)``
    defaults to ``PoissonWorkload``.  Returns the per-host ``RunStats``
    list — roll it up with ``RunStats.merge_all``, or feed it to
    ``fleet_tail_reference`` for the exact hedged-tail distribution.
    """
    from dataclasses import replace as _replace

    from .workload import PoissonWorkload

    fleet.validate()
    if workload_factory is None:
        workload_factory = PoissonWorkload
    shares = fleet.shares()
    out = []
    for h in range(fleet.n_hosts):
        cfg_h = _replace(cfg, seed=cfg.seed + h)
        out.append(simulate_run(policy_factory(h),
                                workload_factory(rate_mpps * shares[h]),
                                cfg_h))
    return out


def fleet_tail_reference(host_stats, fleet, hedge_deadline_us: float, *,
                         n_samples: int = 200_000,
                         seed: int = 0) -> np.ndarray:
    """Exact first-completion-wins hedging over measured per-host
    latency samples — the reference the fluid/closed-form hedged-tail
    model is parity-pinned against.

    Per simulated request: pick a host by served share, draw a base
    latency from that host's empirical reservoir
    (``host_stats[h].latency_us``), add its topology delay (rack cost
    plus, for far hosts, an Exp-distributed share of the bottleneck-link
    M/M/1 wait at the measured far-rack offered rate).  If the total
    exceeds the hedge deadline D, duplicate to a second host drawn from
    the other replicas and finish at ``min(original, D + partner's full
    latency)`` — first completion wins, exactly.  ``D <= 0`` disables
    hedging.  Returns the ``n_samples`` end-to-end latencies; quantile
    them directly.
    """
    fleet.validate()
    if len(host_stats) != fleet.n_hosts:
        raise ValueError("need one RunStats per host")
    rng = np.random.default_rng(seed)
    pools = [np.asarray(rs.latency_us, dtype=np.float64)
             for rs in host_stats]
    if any(p.size == 0 for p in pools):
        raise ValueError("every host needs latency samples "
                         "(run the event engine, not a spin override)")
    served = np.asarray([max(rs.items, 1) for rs in host_stats],
                        dtype=np.float64)
    weight = served / served.sum()
    far = fleet.far_mask()
    duration_us = host_stats[0].duration_ns / 1e3
    far_rate = float(sum(rs.offered for rs, f in zip(host_stats, far)
                         if f)) / duration_us
    link_wait_us = fleet.link_wait_us(far_rate)
    cost = fleet.host_cost_us()

    def draw(hosts: np.ndarray) -> np.ndarray:
        """End-to-end latency samples for the given host choices."""
        base = np.empty(hosts.size)
        for h in range(fleet.n_hosts):
            m = hosts == h
            if m.any():
                base[m] = rng.choice(pools[h], size=int(m.sum()))
        topo = cost[hosts].astype(np.float64)
        if link_wait_us > 0.0:
            f = far[hosts]
            topo[f] += rng.exponential(link_wait_us, size=int(f.sum()))
        return base + topo

    hosts = rng.choice(fleet.n_hosts, size=n_samples, p=weight)
    lat = draw(hosts)
    d = float(hedge_deadline_us)
    if d > 0.0 and fleet.n_hosts > 1:
        slow = lat > d
        n_slow = int(slow.sum())
        if n_slow:
            # partner: an independent draw from the OTHER replicas,
            # renormalized served-share weights
            pw = np.tile(weight, (n_slow, 1))
            pw[np.arange(n_slow), hosts[slow]] = 0.0
            pw /= pw.sum(axis=1, keepdims=True)
            cum = np.cumsum(pw, axis=1)
            u = rng.random(n_slow)
            partners = (u[:, None] > cum).sum(axis=1).astype(np.int64)
            lat[slow] = np.minimum(lat[slow], d + draw(partners))
    return lat
