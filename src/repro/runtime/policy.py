"""Retrieval policies — *when* to wake up and poll the queue.

A ``RetrievalPolicy`` answers one question per wakeup: how long should
this thread sleep before its next poll?  The same policy object runs
unmodified in the discrete-event simulator (``repro.runtime.sim``), the
real-thread ``Runtime``, and the serving server — validate analytically,
simulate, then deploy, without rewriting the control law three times.

Contract:
  - ``threads``          how many pollers this policy deploys (paper M);
  - ``reset()``          re-arm internal state at run start (in place, so
                         held references like ``policy.controller`` stay
                         valid across runs);
  - ``on_wake(ctx)``     -> nanoseconds to sleep before the next poll; 0
                         means "don't sleep at all" (busy polling).  Must
                         be side-effect free: backends may call it to
                         probe the current timeout;
  - ``on_cycle_end(busy_us, vacation_us)``  one renewal-cycle observation
                         (paper Fig 3/4), fed by whichever thread won the
                         lock and finished draining.

Implementations:
  - ``BusyPollPolicy``      classic DPDK Listing-1 spinning baseline;
  - ``MetronomePolicy``     the paper's adaptive sleep&wake (Eq 10/12);
  - ``FixedPeriodPolicy``   constant-period retrieval (interrupt
                            coalescing-style timer, no role split);
  - ``EqualTimeoutsPolicy`` T_L = T_S (paper Fig 5/7 scenarios).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro.core.controller import MetronomeConfig, MetronomeController

__all__ = [
    "WakeContext",
    "RetrievalPolicy",
    "BusyPollPolicy",
    "MetronomePolicy",
    "FixedPeriodPolicy",
    "EqualTimeoutsPolicy",
]


@dataclass(frozen=True)
class WakeContext:
    """What a poller knows when it decides its next sleep."""

    primary: bool = True        # did this wake win the queue lock?
    items: int = 0              # items retrieved during the busy period
    backlog: int = 0            # queue depth left behind (usually 0)
    now_ns: int = 0             # ns since run start (same clock on every backend)


@runtime_checkable
class RetrievalPolicy(Protocol):
    name: str

    @property
    def threads(self) -> int: ...

    def reset(self) -> None: ...

    def on_wake(self, ctx: WakeContext) -> int: ...

    def on_cycle_end(self, busy_us: float, vacation_us: float) -> None: ...


class BusyPollPolicy:
    """Paper Listing 1: one dedicated thread, never sleeps.

    ``spin = True`` tells backends to use their spinning fast path (the
    simulator switches to an analytic fluid model; the threaded runtime
    pins CPU accounting at a full core, the baseline's defining cost).
    """

    name = "busy-poll"
    spin = True

    def __init__(self, threads: int = 1):
        self._threads = threads

    @property
    def threads(self) -> int:
        return self._threads

    def reset(self) -> None:
        pass

    def on_wake(self, ctx: WakeContext) -> int:
        return 0

    def on_cycle_end(self, busy_us: float, vacation_us: float) -> None:
        pass


class MetronomePolicy:
    """The paper's adaptive sleep&wake retrieval (Listing 2 + Eq 10/12).

    Wraps one shared ``MetronomeController``: primaries sleep the adaptive
    T_S, backups sleep T_L.  ``adaptive=False`` freezes T_S at the
    vacation target (the paper's static-configuration ablations).
    ``operating_table`` installs a calibrated feed-forward term (an
    ``repro.runtime.calibrate.OperatingTable`` or anything with
    ``timeouts_us(rho)``): the Eq 10 EWMA keeps estimating rho, and the
    table maps that estimate to a pre-validated (T_S, T_L) operating
    point, blended with Eq 12 by ``cfg.feedforward_weight``.
    """

    name = "metronome"
    spin = False

    def __init__(self, cfg: MetronomeConfig | None = None, *,
                 adaptive: bool = True, operating_table=None):
        self.cfg = cfg or MetronomeConfig()
        self.adaptive = adaptive
        self.controller = MetronomeController(self.cfg,
                                              feedforward=operating_table)
        self.reset()

    @property
    def threads(self) -> int:
        return self.cfg.m

    @property
    def rho(self) -> float:
        return self.controller.rho

    @property
    def t_short_us(self) -> float:
        return self.controller.t_short_us

    @property
    def trajectory(self) -> list:
        """The controller's recorded (cycle, rho, T_S, T_L) trace —
        empty unless ``cfg.record_trajectory`` is on."""
        return self.controller.trajectory

    def reset(self) -> None:
        # re-arm in place: callers hold references to self.controller
        self.controller.__post_init__()
        if not self.adaptive:
            self.controller.t_short_us = self.cfg.v_target_us

    def on_wake(self, ctx: WakeContext) -> int:
        return self.controller.timeout_ns(primary=ctx.primary)

    def on_cycle_end(self, busy_us: float, vacation_us: float) -> None:
        if self.adaptive:
            self.controller.on_cycle_end(busy_us, vacation_us)

    def __repr__(self) -> str:
        return (f"MetronomePolicy(m={self.cfg.m}, "
                f"v_target_us={self.cfg.v_target_us}, "
                f"t_long_us={self.cfg.t_long_us}, adaptive={self.adaptive})")


class FixedPeriodPolicy:
    """Constant-period retrieval: every thread sleeps ``period_us`` no
    matter what happened — the timer-driven middle ground between busy
    polling and Metronome (think NIC interrupt coalescing)."""

    name = "fixed-period"
    spin = False

    def __init__(self, period_us: float = 50.0, threads: int = 1):
        self.period_us = float(period_us)
        self._threads = threads

    @property
    def threads(self) -> int:
        return self._threads

    def reset(self) -> None:
        pass

    def on_wake(self, ctx: WakeContext) -> int:
        return int(self.period_us * 1_000)

    def on_cycle_end(self, busy_us: float, vacation_us: float) -> None:
        pass

    def __repr__(self) -> str:
        return f"FixedPeriodPolicy({self.period_us}us x{self._threads})"


class EqualTimeoutsPolicy(MetronomePolicy):
    """T_L := T_S — no backup role (paper Fig 5/7).

    Every wake sleeps the primary timeout, so all M threads keep probing
    at the short cadence; the paper uses this to expose the busy-try
    cost that the long backup timeout exists to avoid.
    """

    name = "equal-timeouts"

    def __init__(self, cfg: MetronomeConfig | None = None, *,
                 adaptive: bool = False):
        super().__init__(cfg, adaptive=adaptive)

    def on_wake(self, ctx: WakeContext) -> int:
        return self.controller.timeout_ns(primary=True)
