"""Batched fixed-slot JAX simulation engine: thousands of configs per call.

The event engine (``repro.runtime.sim``) is exact but serial — one
(policy, workload, environment) point per call, at interpreter speed.
This engine trades per-event exactness for *throughput*: it discretizes
time into fixed slots (``lax.scan``), advances every poller thread and
Rx queue one slot at a time with pure array ops, and ``vmap``s the whole
run over a ``SweepGrid`` of operating points — (T_S, T_L, M, n_queues,
offered load, seed) — so a dense parameter sweep is one JIT-compiled
call instead of thousands of Python simulations.

Model (per grid point, per slot of ``slot_us``):

  1. arrivals per queue follow a residual-carried Gaussian fluid
     approximation of Poisson(lambda/n_queues * dt): each slot draws
     ``mu + sqrt(mu)*z`` packets (continuous), negative excursions are
     carried forward as a deficit instead of clipped, so both the total
     count and its variance at vacation scale match the Poisson process
     (an exact per-slot Poisson sampler costs O(lambda*dt) rejection
     iterations *inside* the scan and dominated the runtime by ~50x).
     Arrivals are admitted up to ``queue_capacity`` (drops counted —
     Rx-ring semantics);
  2. sleeping threads count down; threads whose timer expires wake
     (wake cost charged).  Each woken thread, in index order, claims the
     free (unlocked) queue with the longest backlog: a claim ends that
     queue's vacation and starts a busy period; a wake that finds a free
     but empty queue is an "empty win" (primary re-sleeps T_S, like the
     event engine's zero-backlog lock win); a wake with every queue
     locked is a busy try (backup re-sleeps T_L);
  3. each locked queue drains at mu for the slot (CPU charged as
     served/mu, exactly the event engine's accounting);
  4. queues drained to zero release their thread, which re-sleeps a
     fresh T_S sample;
  5. the queue-depth integral accumulates: mean latency is recovered by
     Little's law (area under the backlog curve / packets served), the
     true all-packet mean sojourn.

Sleep overshoot uses the same ``SleepModel`` affine-plus-noise form as
the event engine.  Wake-timer quantization is bias-corrected by carrying
the (negative) residual of each expired timer into the next sleep, so
wakeup *rates* are unbiased even though individual wakes land on slot
boundaries.

CPU-sharing environments are modeled with the event engine's semantics
(paper Sec 5.6 — co-located CPU-intensive applications):

  - *per-wake OS interference*: every re-sleep is lengthened by
    Exp(``interference_mean_us``) with probability
    ``interference_prob`` — an independent Bernoulli x Exp draw per
    thread per slot, charged only on slots where that thread actually
    re-arms its timer (exactly the event engine's per-sleep draw);
  - *correlated stall windows*: a Poisson process (rate
    ``stall_rate_per_us``, Bernoulli per slot with the exact
    ``1 - exp(-rate*dt)`` hit probability) opens system-wide freeze
    windows of Exp(``stall_mean_us``) length; any timer that expires
    inside an open window is deferred to the window's end (+U(0,1)us,
    the event engine's re-arm jitter) without being counted as a wake.
    Overlapping windows extend (``max``), matching the event engine's
    lazy merge.

Approximations vs the event engine (documented tolerances; pinned in
tests/test_batched_engine.py):

  - timeouts are static per point — the grid *is* the adaptation space
    (the calibration layer, not the engine, closes the loop);
  - arrivals are Poisson only (the workload protocol's generality stays
    with the event engine), but the rate may be *nonstationary*: a
    ``repro.runtime.schedule.LoadSchedule`` — per point via
    ``SweepGrid.schedules`` or batch-wide via ``cfg.schedule`` — is
    evaluated as a piecewise-constant multiplier per slot, and
    ``cfg.window_us > 0`` emits the same per-window
    offered/served/latency/CPU accumulators the event engine keeps
    (``BatchStats.windows(i)`` / ``.tracking(i, ...)``);
  - busy-period boundaries are quantized to ``slot_us`` (keep
    ``slot_us`` a few times smaller than T_S and 1/mu ≪ slot);
  - multi-queue sweeps release a thread after its one claimed queue
    drains instead of continuing the sweep (single-queue runs have no
    such gap, and parity is pinned at ``n_queues=1``);
  - stall-window starts/ends are quantized to ``slot_us`` and at most
    one window opens per slot (exact-probability Bernoulli), so keep
    ``stall_rate_per_us * slot_us`` well below 1.

Documented parity tolerance at ``n_queues=1``, stable region (rho ≤
0.85, T_S ≥ 8·slot_us): all-packet mean sojourn (Little's law, the
event engine's ``RunStats.mean_sojourn_us``) within max(1.5us, 12%) and
CPU fraction within 0.02 + 5% of the event engine — pinned for 24
random configurations in tests/test_batched_engine.py (typical observed
agreement is ~2% / ~0.005).  Under interference (``interference_prob >
0`` *and* ``stall_rate_per_us > 0``) the band widens — heavy-tailed
stall windows leave finite-sample noise in both engines' means — to
mean sojourn within max(4.5us, 22%), CPU within 0.025 + 6%, and loss
fraction within 0.03 absolute — pinned for 16 random noisy-host
configurations in the same test module.

Stepping modes.  ``simulate_batch(..., stepping="fixed")`` (the
default) is the kernel described above; every quantization caveat in
this docstring is a statement about its *per-slot* update at
``slot_us`` resolution, with two scan-shape refinements:

  - the scan length is the slot count rounded *up* a geometric ladder
    (``bucket_steps``) and the run duration is a traced input, so
    nearby durations share one compiled kernel (slots past a point's
    duration are carry-preserving no-ops) — numerics are unchanged,
    only recompile churn is;
  - wake-timer / busy-period / stall-window quantization is always
    ``slot_us`` regardless of the padded scan length.

``stepping="adaptive"`` dispatches to the event-jump kernel in
``batched_adaptive.py``: variable ``dt`` per scan step (next wake /
drain-out / fill / schedule-segment / window / stall-start boundary),
closed-form multi-slot aggregates, scan length O(#events) instead of
O(duration/slot_us) — load-proportional simulation, ~10x+ fewer steps
at low load.  Its approximation surface (what stays exact, what moves)
is documented in that module; both modes hold the parity bands above.
"""

from __future__ import annotations

import logging
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .simcore import SimRunConfig
from .stats import Reservoir, RunStats, WindowedSeries

__all__ = ["SweepGrid", "BatchStats", "simulate_batch", "bucket_steps",
           "unsupported_config_fields", "validate_batched_config",
           "CompileCache", "compile_cache_stats"]

_log = logging.getLogger(__name__)


class _CacheInfo(NamedTuple):
    hits: int
    misses: int
    maxsize: int
    currsize: int
    evictions: int


class CompileCache:
    """LRU cache for jitted kernel builders, keyed on static shapes.

    ``functools.lru_cache`` is silent: when single-host and fleet shapes
    alternate past the bound, every call re-traces and the only symptom
    is a mysteriously slow process.  This cache (a) has a bound sized
    for fleet + single-host sweeps coexisting, (b) exposes hit / miss /
    eviction counters (``cache_info()``, surfaced by
    ``benchmarks/run.py --json``), and (c) logs every eviction with the
    evicted key, so a retrace storm is visible in logs instead of
    silent.  Every instance self-registers for ``compile_cache_stats``.
    """

    _registry: list["CompileCache"] = []

    def __init__(self, build, *, maxsize: int = 64, name: str = ""):
        self._build = build
        self.maxsize = int(maxsize)
        self.name = name or getattr(build, "__name__", "kernel")
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        CompileCache._registry.append(self)

    def __call__(self, *key):
        try:
            fn = self._entries[key]
        except KeyError:
            self.misses += 1
            fn = self._build(*key)
            self._entries[key] = fn
            if len(self._entries) > self.maxsize:
                evicted, _ = self._entries.popitem(last=False)
                self.evictions += 1
                _log.warning(
                    "%s: evicting compiled kernel for static key %r "
                    "(cache full at %d entries, %d evictions so far) — "
                    "alternating shapes will re-trace every call",
                    self.name, evicted, self.maxsize, self.evictions)
            return fn
        self.hits += 1
        self._entries.move_to_end(key)
        return fn

    def cache_info(self) -> _CacheInfo:
        return _CacheInfo(self.hits, self.misses, self.maxsize,
                          len(self._entries), self.evictions)

    def cache_clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict:
        return {"name": self.name, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "currsize": len(self._entries), "maxsize": self.maxsize}


def compile_cache_stats() -> list[dict]:
    """Hit/miss/eviction counters of every registered kernel cache (the
    batched sweep kernel, the fleet kernel) — one dict per cache, in
    registration order.  Benchmarks surface these in their JSON rows so
    retrace behavior is part of the tracked perf trajectory."""
    return [c.stats() for c in CompileCache._registry]


def bucket_steps(n: int, *, base: int = 64, ratio: float = 1.25) -> int:
    """Round a scan length up to a small geometric ladder.

    Both engines key their ``CompileCache`` on the scan length; keying
    on the *exact* slot count means every distinct ``duration_us``
    recompiles (a multi-second retrace to save a padded no-op tail).
    Rounding up to ``base * ratio**k`` collapses all nearby durations
    onto one compiled kernel at the cost of at most ``ratio - 1``
    (25%) extra carry-preserving no-op steps — the run's true duration
    is a traced input, so results are unchanged."""
    n = max(int(n), 1)
    v = base
    # rungs are the iterates v -> ceil(v * ratio), which makes every
    # rung a fixed point: bucket_steps(bucket_steps(n)) == bucket_steps(n)
    while v < n:
        v = int(math.ceil(v * ratio))
    return v


_DIMS = ("t_s_us", "t_l_us", "m", "n_queues", "rate_mpps", "seed")

# fixed-width piecewise-constant schedule rows the kernel consumes; a
# schedule denser than this is resampled to its per-segment means
_MAX_SCHED_SEGMENTS = 256


@dataclass(frozen=True)
class SweepGrid:
    """A flat batch of operating points, one simulated run per row.

    ``product(...)`` builds the dense cartesian grid (and remembers its
    logical ``shape`` so results can be reshaped per axis);
    ``of_points(...)`` wraps an arbitrary list of points (parity tests,
    spot checks).  All arrays share one length ``len(grid)``.

    ``schedules`` (optional) carries one ``LoadSchedule`` — or ``None``
    for stationary — per point: the batched engine evaluates each row's
    schedule as a piecewise-constant rate multiplier inside the slot
    loop, so a single vmapped call can sweep step/ramp/sinusoid/MMPP
    trajectories next to stationary points.  ``product`` grows the
    logical ``shape`` by a trailing schedules axis only when a
    ``schedules`` axis is passed (existing stationary grids keep their
    shape contract).
    """

    t_s_us: np.ndarray
    t_l_us: np.ndarray
    m: np.ndarray
    n_queues: np.ndarray
    rate_mpps: np.ndarray
    seed: np.ndarray
    shape: tuple = ()            # cartesian shape in _DIMS order ("" = flat)
    dims: tuple = _DIMS
    schedules: tuple = ()        # per-point LoadSchedule | None ("" = none)

    @classmethod
    def product(cls, *, t_s_us, t_l_us, rate_mpps, m=(3,), n_queues=(1,),
                seeds=(0,), schedules=None) -> "SweepGrid":
        axes = [np.atleast_1d(np.asarray(a)) for a in
                (t_s_us, t_l_us, m, n_queues, rate_mpps, seeds)]
        sched_axis = (np.arange(len(schedules))
                      if schedules is not None else np.zeros(1, np.int64))
        mesh = np.meshgrid(*axes, sched_axis, indexing="ij")
        shape = tuple(a.size for a in axes)
        if schedules is not None:
            shape = shape + (len(schedules),)
        vals = [g.ravel() for g in mesh]
        scheds = (tuple(schedules[i] for i in vals[6])
                  if schedules is not None else ())
        return cls(t_s_us=vals[0].astype(np.float64),
                   t_l_us=vals[1].astype(np.float64),
                   m=vals[2].astype(np.int32),
                   n_queues=vals[3].astype(np.int32),
                   rate_mpps=vals[4].astype(np.float64),
                   seed=vals[5].astype(np.int64),
                   shape=shape,
                   schedules=scheds)

    @classmethod
    def of_points(cls, points) -> "SweepGrid":
        """``points``: iterable of dicts with keys from ``SweepGrid.dims``
        (missing keys take m=3, n_queues=1, seed=0) plus an optional
        ``schedule`` (a ``LoadSchedule``) per point."""
        pts = list(points)
        get = lambda k, d: np.asarray([p.get(k, d) for p in pts])  # noqa: E731
        scheds = tuple(p.get("schedule") for p in pts)
        return cls(t_s_us=get("t_s_us", 10.0).astype(np.float64),
                   t_l_us=get("t_l_us", 500.0).astype(np.float64),
                   m=get("m", 3).astype(np.int32),
                   n_queues=get("n_queues", 1).astype(np.int32),
                   rate_mpps=get("rate_mpps", 14.88).astype(np.float64),
                   seed=get("seed", 0).astype(np.int64),
                   shape=(len(pts),),
                   schedules=(scheds if any(s is not None for s in scheds)
                              else ()))

    def __len__(self) -> int:
        return int(self.t_s_us.size)

    def point(self, i: int) -> dict:
        d = {k: getattr(self, k)[i].item() for k in self.dims}
        if self.schedules:
            d["schedule"] = self.schedules[i]
        return d


class _SlotStats(NamedTuple):
    offered: jnp.ndarray
    dropped: jnp.ndarray
    serviced: jnp.ndarray
    wakeups: jnp.ndarray
    busy_tries: jnp.ndarray
    cycles: jnp.ndarray
    awake_us: jnp.ndarray
    lat_area: jnp.ndarray
    vac_sum: jnp.ndarray
    nv_sum: jnp.ndarray
    ts_arms: jnp.ndarray       # T_S-class sleeps armed (empty + release)
    energy_uj: jnp.ndarray     # EnergyModel charge (active + arms)


def energy_arm_cost(target_us, sleep_states):
    """Per-arm sleep + transition energy (uJ) of a traced sleep target:
    the deepest C-state whose minimum residency fits pays
    ``power_w * target + transition_uj`` (the next-timer-event governor
    approximation — see ``simcore.EnergyModel``).  ``sleep_states`` is
    the static shallow-to-deep tuple from ``EnergyModel.params()``."""
    p_w = jnp.float32(sleep_states[0][0])
    t_uj = jnp.float32(sleep_states[0][1])
    for pw, tuj, thr_us in sleep_states[1:]:
        fits = target_us >= thr_us
        p_w = jnp.where(fits, jnp.float32(pw), p_w)
        t_uj = jnp.where(fits, jnp.float32(tuj), t_uj)
    return p_w * target_us + t_uj


@dataclass
class BatchStats:
    """Array-shaped results, one entry per ``SweepGrid`` row.

    Everything is a float64 numpy array of shape ``(len(grid),)``;
    derived metrics are properties.  ``reshaped(name)`` folds a metric
    back to the grid's cartesian ``shape``; ``to_run_stats(i)`` converts
    one point into the unified ``RunStats`` (latency beyond the mean is
    an analytic estimate — the batched engine does not keep samples).
    """

    grid: SweepGrid
    cfg: SimRunConfig
    slot_us: float
    offered: np.ndarray = field(default_factory=lambda: np.empty(0))
    dropped: np.ndarray = field(default_factory=lambda: np.empty(0))
    serviced: np.ndarray = field(default_factory=lambda: np.empty(0))
    wakeups: np.ndarray = field(default_factory=lambda: np.empty(0))
    busy_tries: np.ndarray = field(default_factory=lambda: np.empty(0))
    cycles: np.ndarray = field(default_factory=lambda: np.empty(0))
    awake_us: np.ndarray = field(default_factory=lambda: np.empty(0))
    lat_area: np.ndarray = field(default_factory=lambda: np.empty(0))
    vac_sum: np.ndarray = field(default_factory=lambda: np.empty(0))
    nv_sum: np.ndarray = field(default_factory=lambda: np.empty(0))
    # energy accounting (cfg.energy_model): T_S-class arm count and the
    # total charge — active_power*awake + per-arm C-state residency +
    # transition energy (T_L-class arms == busy_tries)
    ts_arms: np.ndarray = field(default_factory=lambda: np.empty(0))
    energy_uj: np.ndarray = field(default_factory=lambda: np.empty(0))
    # cfg.window_us > 0: per-point windowed accumulators of shape
    # (len(grid), n_windows, 5) — [offered, served, lat_area, awake,
    # energy] — the same raw sums the event engine's WindowAccum keeps
    win: np.ndarray = field(default_factory=lambda: np.empty(0))
    # stepping diagnostics: which kernel produced this batch, its
    # compiled scan length, and per-point live-step / forced-step
    # counts, exact simulated time, and end-of-run total backlog (the
    # missing term of the offered = served + dropped + backlog law)
    stepping: str = "fixed"
    scan_len: int = 0
    n_steps: np.ndarray = field(default_factory=lambda: np.empty(0))
    forced_steps: np.ndarray = field(default_factory=lambda: np.empty(0))
    sim_time_us: np.ndarray = field(default_factory=lambda: np.empty(0))
    final_backlog: np.ndarray = field(default_factory=lambda: np.empty(0))

    # -- derived ---------------------------------------------------------------
    @property
    def cpu_fraction(self) -> np.ndarray:
        return self.awake_us / self.cfg.duration_us

    @property
    def loss_fraction(self) -> np.ndarray:
        return self.dropped / np.maximum(self.offered, 1.0)

    @property
    def mean_latency_us(self) -> np.ndarray:
        """Little's-law mean sojourn: queue-depth integral over departures."""
        return self.lat_area / np.maximum(self.serviced, 1.0)

    @property
    def mean_vacation_us(self) -> np.ndarray:
        return self.vac_sum / np.maximum(self.cycles, 1.0)

    @property
    def mean_nv(self) -> np.ndarray:
        return self.nv_sum / np.maximum(self.cycles, 1.0)

    @property
    def energy_per_packet_nj(self) -> np.ndarray:
        return 1e3 * self.energy_uj / np.maximum(self.serviced, 1.0)

    @property
    def mean_power_w(self) -> np.ndarray:
        return self.energy_uj / self.cfg.duration_us

    @property
    def rho(self) -> np.ndarray:
        return self.grid.rate_mpps / self.cfg.service_rate_mpps

    def reshaped(self, name: str) -> np.ndarray:
        val = getattr(self, name)
        return np.asarray(val).reshape(self.grid.shape)

    def _schedule(self, i: int):
        # mirror _schedule_rows' precedence exactly: a None row inside a
        # scheduled grid falls back to the batch-wide config schedule
        # (which is what the kernel simulated for that row)
        if self.grid.schedules and self.grid.schedules[i] is not None:
            return self.grid.schedules[i]
        return self.cfg.schedule

    def windows(self, i: int) -> WindowedSeries | None:
        """Point ``i``'s windowed series — the same accumulator
        convention (and therefore the same derived-metric /
        ``TrackingStats`` code path) as the event engine's.  ``None``
        when the run was not windowed (``cfg.window_us == 0``).  The
        slot engine keeps no latency samples, so per-window p99 is NaN
        and there is no controller estimate (static timeouts: the grid,
        not a controller, is the adaptation space)."""
        if self.win.size == 0:
            return None
        w = self.win[i]
        return WindowedSeries(
            window_us=float(self.cfg.window_us),
            service_rate_mpps=self.cfg.service_rate_mpps,
            offered=w[:, 0].copy(), served=w[:, 1].copy(),
            lat_area_us=w[:, 2].copy(), awake_us=w[:, 3].copy(),
            energy_uj=w[:, 4].copy())

    def tracking(self, i: int, target_latency_us: float, **kw):
        """``TrackingStats`` for point ``i`` against its schedule's
        transitions — identical computation to the event engine's
        ``stats.windows.tracking(...)``."""
        ws = self.windows(i)
        if ws is None:
            raise ValueError("run was not windowed: set cfg.window_us")
        sched = self._schedule(i)
        trans = (sched.transitions(self.cfg.duration_us)
                 if sched is not None else ())
        return ws.tracking(trans, target_latency_us, **kw)

    def to_run_stats(self, i: int) -> RunStats:
        p = self.grid.point(i)
        mean = float(self.mean_latency_us[i])
        cap = self.cfg.queue_capacity * max(int(p["n_queues"]), 1)
        sched = self._schedule(i)
        return RunStats(
            backend="batched",
            policy=(f"sleepwake(t_s={p['t_s_us']:g},t_l={p['t_l_us']:g},"
                    f"m={p['m']})"),
            workload=f"poisson({p['rate_mpps']:g})",
            schedule=sched.descriptor() if sched is not None else "",
            wakeups=int(self.wakeups[i]), cycles=int(self.cycles[i]),
            busy_tries=int(self.busy_tries[i]),
            items=int(self.serviced[i]), offered=int(self.offered[i]),
            dropped=int(self.dropped[i]),
            awake_ns=round(self.awake_us[i] * 1e3), started_ns=0,
            stopped_ns=round(self.cfg.duration_us * 1e3),
            latency_us=Reservoir(4, seed=int(p["seed"])),
            latency_area_us=float(self.lat_area[i]),
            energy_uj=float(self.energy_uj[i]),
            # no per-packet samples in the slot engine: mean is measured
            # (Little), p99/worst are coarse analytic estimates
            latency_override={
                "mean": mean,
                "p99": mean * 3.0,
                "worst": float(cap / self.cfg.service_rate_mpps
                               + p["t_l_us"]),
            },
            # no per-queue counter breakdown in the slot engine's
            # aggregate stats: leave per_queue empty rather than emit
            # all-zero slices that would break the sums-to-total law
            per_queue=[],
            windows=self.windows(i),
            vacations_us=np.asarray([self.mean_vacation_us[i]]),
            busies_us=np.asarray([self.serviced[i]
                                  / self.cfg.service_rate_mpps
                                  / max(self.cycles[i], 1.0)]),
            n_v=np.asarray([self.mean_nv[i]]),
        )

    def __len__(self) -> int:
        return len(self.grid)


def _build_sweep(n_slots: int, slot_us: float, m_max: int, q_max: int,
                 mu: float, capacity: float, wake_cost_us: float,
                 sleep_params: tuple, interference_params: tuple,
                 energy_params: tuple,
                 n_seg: int = 0, n_windows: int = 0,
                 window_us: float = 0.0):
    """Build + jit the vmapped fixed-slot kernel for one static shape.

    ``n_seg > 0`` compiles the nonstationary variant: each point carries
    a piecewise-constant load schedule as ``(edges, scales)`` rows of
    width ``n_seg``, looked up per slot (the arrival rate becomes
    ``lam * scale(now)``).  ``n_windows > 0`` additionally accumulates
    the per-window [offered, served, lat_area, awake, energy] sums the
    adaptation-tracking layer consumes (same convention as the event
    engine's ``WindowAccum``).  ``energy_params`` is the static
    ``EnergyModel.params()`` tuple; per-arm C-state charges are
    closed-form per point (the targets T_S/T_L are per-point traced
    scalars), so the energy column costs one fused multiply-add per
    slot."""
    base_us, slope, sigma_us, tail_prob, tail_mean_us = sleep_params
    intf_prob, intf_mean_us, stall_rate, stall_mean_us = interference_params
    active_power_w, _dvfs_scale, e_states = energy_params
    # exact per-slot hit probability of the Poisson stall-start process
    stall_p = 1.0 - math.exp(-stall_rate * slot_us) if stall_rate else 0.0
    dt = slot_us
    t_idx = jnp.arange(m_max)
    q_idx = jnp.arange(q_max)

    def one_point(t_s, t_l, m, nq, lam, seed_lo, seed_hi, duration,
                  sched_edges, sched_scales):
        tmask = t_idx < m
        qmask = q_idx < nq
        lam_q = jnp.where(qmask, lam / nq, 0.0)
        # per-arm energy of the point's two sleep classes — the C-state
        # follows the programmed target (event-engine convention), so
        # both charges are point constants hoisted out of the scan
        e_arm_s = energy_arm_cost(t_s, e_states)
        e_arm_l = energy_arm_cost(t_l, e_states)

        # both 32-bit halves of the 64-bit seed are folded in, so seeds
        # differing only in their high bits stay independent
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), seed_lo), seed_hi)
        key, k0 = jax.random.split(key)
        # active launch (event-engine convention): first wakes land
        # uniformly inside one primary timeout, not spread over T_L
        sleep0 = jax.random.uniform(k0, (m_max,)) * t_s
        sleep0 = jnp.where(tmask, jnp.maximum(sleep0, dt), jnp.inf)

        def step(carry, t):
            prev = carry
            (sleep_rem, attached, backlog, vac_timer, arr_res, stall_end,
             S, win_acc) = carry
            now = t.astype(jnp.float32) * dt
            # slots at/past the traced duration are carry-preserving
            # no-ops: the scan length is bucketed (bucket_steps), so one
            # compiled kernel serves every nearby duration
            live = now < duration
            kt_step = jax.random.fold_in(key, t)
            if tail_prob > 0.0:
                kt_step, kp, ku = jax.random.split(kt_step, 3)
            if intf_prob > 0.0:
                kt_step, kip, kie = jax.random.split(kt_step, 3)
            if stall_p > 0.0:
                kt_step, ksp, kse, ksu = jax.random.split(kt_step, 4)
            # one fused normal draw covers arrivals + sleep noise
            zs = jax.random.normal(kt_step, (q_max + m_max,))

            # correlated stall windows: Bernoulli(1-exp(-rate*dt)) opens
            # an Exp(stall_mean)-long system-wide freeze; overlapping
            # windows extend (max), like the event engine's lazy merge
            if stall_p > 0.0:
                hit_s = jax.random.uniform(ksp, ()) < stall_p
                win = now + stall_mean_us * jax.random.exponential(kse, ())
                stall_end = jnp.where(hit_s,
                                      jnp.maximum(stall_end, win), stall_end)

            # 1. arrivals: residual-carried Gaussian fluid ~ Poisson,
            # rate modulated by the point's load schedule when one is
            # compiled in (piecewise-constant scale looked up per slot)
            if n_seg > 0:
                si = jnp.clip(
                    jnp.searchsorted(sched_edges, now, side="right") - 1,
                    0, n_seg - 1)
                mu_a = lam_q * sched_scales[si] * dt
            else:
                mu_a = lam_q * dt
            raw = arr_res + mu_a + jnp.sqrt(mu_a) * zs[:q_max]
            a = jnp.maximum(raw, 0.0)
            arr_res = jnp.minimum(raw, 0.0)      # deficit carried forward
            room = jnp.maximum(capacity - backlog, 0.0)
            adm = jnp.minimum(a, room)
            backlog = backlog + adm
            offered = a.sum()
            dropped = (a - adm).sum()

            # sleep overshoot draws for this slot (one per thread;
            # static zeros when the model is exact)
            over = jnp.full((m_max,), base_us)
            if sigma_us > 0.0:
                over = over + sigma_us * jnp.abs(zs[q_max:])
            if tail_prob > 0.0:
                hit = jax.random.uniform(kp, (m_max,)) < tail_prob
                over = over + hit * tail_mean_us * jax.random.exponential(
                    ku, (m_max,))
            # per-wake OS interference (paper Sec 5.6): each re-sleep is
            # lengthened by Exp(mean) w.p. q — one independent draw per
            # thread per slot, charged only on the slots where a thread
            # actually re-arms (the same per-sleep draw the event engine
            # makes after sampling the sleep model)
            if intf_prob > 0.0:
                ihit = jax.random.uniform(kip, (m_max,)) < intf_prob
                over = over + ihit * intf_mean_us * jax.random.exponential(
                    kie, (m_max,))
            slp_s = t_s * (1.0 + slope) + over
            slp_l = t_l * (1.0 + slope) + over

            # 2. countdown + wake + claim (threads in index order)
            sleeping = tmask & (attached < 0)
            sleep_rem = jnp.where(sleeping, sleep_rem - dt, sleep_rem)
            woken = sleeping & (sleep_rem <= 0.0)
            if stall_p > 0.0:
                # timers expiring inside an open stall window defer to its
                # end (+U(0,1)us re-arm jitter) and are NOT counted as
                # wakes — the event engine's deferred-wake semantics
                push = woken & (now < stall_end)
                woken = woken & ~push
                sleep_rem = jnp.where(
                    push,
                    stall_end - now + jax.random.uniform(ksu, (m_max,)),
                    sleep_rem)
            n_wake = woken.sum().astype(jnp.float32)

            occ = (jax.nn.one_hot(attached, q_max).sum(axis=0) > 0)
            busy_tries = jnp.float32(0.0)
            cycles = jnp.float32(0.0)
            vac_sum = jnp.float32(0.0)
            nv_sum = jnp.float32(0.0)
            ts_arm = jnp.float32(0.0)
            for i in range(m_max):            # static unroll, m_max small
                w = woken[i]
                free_q = qmask & ~occ
                claimable = free_q & (backlog >= 1.0)
                qi = jnp.argmax(jnp.where(claimable, backlog, -1.0))
                do_attach = w & claimable.any()
                empty_claim = w & ~claimable.any() & free_q.any()
                eqi = jnp.argmax(free_q)      # first free (empty) queue
                blocked = w & ~free_q.any()

                claim_hot = do_attach & (q_idx == qi)
                claim_any = claim_hot | (empty_claim & (q_idx == eqi))
                vac_sum = vac_sum + (vac_timer * claim_any).sum()
                nv_sum = nv_sum + jnp.where(do_attach, backlog[qi], 0.0)
                vac_timer = jnp.where(claim_any, 0.0, vac_timer)
                cycles = cycles + (do_attach | empty_claim)
                busy_tries = busy_tries + blocked
                ts_arm = ts_arm + empty_claim
                attached = attached.at[i].set(
                    jnp.where(do_attach, qi, attached[i]))
                occ = occ | claim_hot
                # re-sleep adds onto the (negative) expired-timer
                # residual: removes the slot-quantization wake-rate bias
                sleep_rem = sleep_rem.at[i].add(
                    jnp.where(empty_claim, slp_s[i],
                              jnp.where(blocked, slp_l[i], 0.0)))

            # 3. locked queues drain at mu for the slot
            serve = jnp.where(occ, jnp.minimum(backlog, mu * dt), 0.0)
            backlog = backlog - serve
            served = serve.sum()

            # 4. emptied queues release their thread (fresh T_S sleep)
            q_done = occ & (backlog <= 1e-6)
            att_q = jnp.clip(attached, 0, q_max - 1)
            t_done = (attached >= 0) & q_done[att_q]
            ts_arm = ts_arm + t_done.sum()
            sleep_rem = jnp.where(t_done, slp_s, sleep_rem)
            attached = jnp.where(t_done, -1, attached)
            occ = occ & ~q_done

            # 5. vacations tick on unlocked queues; 6. Little integral
            vac_timer = vac_timer + jnp.where(qmask & ~occ, dt, 0.0)
            lat_area = backlog.sum() * dt

            # energy: active power over the slot's awake time plus the
            # per-arm C-state charges (blocked wakes re-arm T_L)
            awake_step = n_wake * wake_cost_us + served / mu
            energy_step = (active_power_w * awake_step
                           + ts_arm * e_arm_s + busy_tries * e_arm_l)

            S = _SlotStats(
                offered=S.offered + offered,
                dropped=S.dropped + dropped,
                serviced=S.serviced + served,
                wakeups=S.wakeups + n_wake,
                busy_tries=S.busy_tries + busy_tries,
                cycles=S.cycles + cycles,
                awake_us=S.awake_us + awake_step,
                lat_area=S.lat_area + lat_area,
                vac_sum=S.vac_sum + vac_sum,
                nv_sum=S.nv_sum + nv_sum,
                ts_arms=S.ts_arms + ts_arm,
                energy_uj=S.energy_uj + energy_step,
            )
            if n_windows > 0:
                # the event engine's WindowAccum convention: raw
                # [offered, served, lat_area, awake, energy] per window
                w = jnp.minimum((now / window_us).astype(jnp.int32),
                                n_windows - 1)
                win_acc = win_acc.at[w].add(jnp.stack([
                    offered, served, lat_area, awake_step, energy_step]))
            nxt = (sleep_rem, attached, backlog, vac_timer, arr_res,
                   stall_end, S, win_acc)
            gated = jax.tree_util.tree_map(
                lambda new, old: jnp.where(live, new, old), nxt, prev)
            return gated, None

        z0 = jnp.float32(0.0)
        init = (sleep0,
                jnp.full((m_max,), -1, jnp.int32),
                jnp.zeros(q_max, jnp.float32),
                jnp.zeros(q_max, jnp.float32),
                jnp.zeros(q_max, jnp.float32),
                jnp.float32(-1.0),          # stall_end: no window open
                _SlotStats(z0, z0, z0, z0, z0, z0, z0, z0, z0, z0, z0, z0),
                jnp.zeros((max(n_windows, 1), 5), jnp.float32))
        (_, _, backlog_f, _, _, _, S, win_acc), _ = jax.lax.scan(
            step, init, jnp.arange(n_slots, dtype=jnp.int32))
        return S, win_acc, backlog_f.sum()

    return jax.jit(jax.vmap(one_point))


_compiled_sweep = CompileCache(_build_sweep, maxsize=64,
                               name="batched._compiled_sweep")


_EVENT_ENGINE_ONLY_FIELDS = ("timeseries_bin_us",)
# SimRunConfig fields this engine deliberately does NOT read, named so
# the engine-parity static check (repro.analysis, PARITY001/002) can
# prove the drift guard complete instead of trusting it:
#   - grid-supplied: seed and n_queues come per-point from the
#     SweepGrid row (the grid axis IS the sweep surface; cfg.seed /
#     cfg.n_queues are event-engine inputs only);
#   - sample-path detail: the fixed-slot engine keeps no latency
#     reservoir, so its size knob has no fixed-slot meaning.
_GRID_SUPPLIED_FIELDS = ("seed", "n_queues")
_NO_SAMPLE_PATH_FIELDS = ("latency_reservoir",)


def unsupported_config_fields(cfg: SimRunConfig) -> list[str]:
    """``SimRunConfig`` fields set to values the batched engine cannot
    honor.  Empty list = the config is fully batched-simulable."""
    return [f for f in _EVENT_ENGINE_ONLY_FIELDS if getattr(cfg, f)]


def validate_batched_config(cfg: SimRunConfig) -> None:
    """Raise eagerly — before any compilation or sweep work — if ``cfg``
    sets fields only the event engine honors, naming each offending
    field (so config errors surface at construction sites such as
    ``build_operating_table``, not as a generic mid-run failure)."""
    bad = unsupported_config_fields(cfg)
    if bad:
        raise ValueError(
            "SimRunConfig field(s) not supported by the batched engine: "
            + ", ".join(f"{f}={getattr(cfg, f)!r}" for f in bad)
            + "; use repro.runtime.sim.simulate_run for those studies")


def _schedule_rows(grid: SweepGrid, cfg: SimRunConfig
                   ) -> tuple[int, np.ndarray, np.ndarray]:
    """Compile the batch's load schedules to fixed-width
    ``(edges, scales)`` rows.  Per-point grid schedules win over the
    config-wide one; ``(0, trivial, trivial)`` when every point is
    stationary (the kernel then skips the lookup entirely)."""
    n = len(grid)
    if grid.schedules:
        scheds = list(grid.schedules)
        if cfg.schedule is not None:
            scheds = [s if s is not None else cfg.schedule for s in scheds]
    elif cfg.schedule is not None:
        scheds = [cfg.schedule] * n
    else:
        return 0, np.zeros((n, 1)), np.ones((n, 1))
    n_seg = 1
    for s in scheds:
        if s is not None:
            n_seg = max(n_seg, len(s.segments(cfg.duration_us)[0]))
    n_seg = min(n_seg, _MAX_SCHED_SEGMENTS)
    edges = np.zeros((n, n_seg))
    scales = np.ones((n, n_seg))
    for i, s in enumerate(scheds):
        if s is None:       # stationary row inside a scheduled batch
            edges[i] = np.concatenate(
                [[0.0], cfg.duration_us + 1.0 + np.arange(n_seg - 1)])
        else:
            edges[i], scales[i] = s.compiled(cfg.duration_us, n_seg)
    return n_seg, edges, scales


def simulate_batch(grid: SweepGrid, cfg: SimRunConfig | None = None, *,
                   slot_us: float = 0.5,
                   stepping: str = "fixed") -> BatchStats:
    """Simulate every operating point in ``grid`` — one JIT-compiled,
    vmapped call over the whole batch.

    ``cfg`` supplies the environment (duration, mu, per-queue capacity,
    sleep model, wake cost, OS interference / correlated stalls, load
    schedule, window size); per-point knobs (T_S, T_L, M, n_queues,
    offered Poisson rate, seed, schedule) come from the grid and
    override the config's.  ``cfg.window_us > 0`` turns on the windowed
    adaptation series (``BatchStats.windows(i)``).  Binned time series
    remain event-engine-only and raise (``validate_batched_config``).

    ``stepping`` selects the kernel: ``"fixed"`` (default) scans
    uniform ``slot_us`` slots; ``"adaptive"`` scans event-jump
    macro-slots (see ``batched_adaptive``) — same statistics, same
    parity bands, scan length proportional to event count instead of
    simulated time.
    """
    cfg = cfg or SimRunConfig()
    validate_batched_config(cfg)
    if stepping not in ("fixed", "adaptive"):
        raise ValueError(
            f"stepping must be 'fixed' or 'adaptive', got {stepping!r}")
    n = len(grid)
    n_windows = (int(math.ceil(cfg.duration_us / cfg.window_us))
                 if cfg.window_us > 0 else 0)
    if stepping == "adaptive":
        from .batched_adaptive import adaptive_sweep_arrays
        vals, win_np, back_f, simt, scan_len = adaptive_sweep_arrays(
            grid, cfg, float(slot_us))
        return BatchStats(
            grid=grid, cfg=cfg, slot_us=float(slot_us),
            offered=vals["offered"], dropped=vals["dropped"],
            serviced=vals["serviced"], wakeups=vals["wakeups"],
            busy_tries=vals["busy_tries"], cycles=vals["cycles"],
            awake_us=vals["awake_us"], lat_area=vals["lat_area"],
            vac_sum=vals["vac_sum"], nv_sum=vals["nv_sum"],
            ts_arms=vals["ts_arms"], energy_uj=vals["energy_uj"],
            win=win_np, stepping="adaptive", scan_len=int(scan_len),
            n_steps=vals["n_steps"], forced_steps=vals["forced_steps"],
            sim_time_us=simt, final_backlog=back_f)
    n_slots_true = max(int(math.ceil(cfg.duration_us / slot_us)), 1)
    n_slots = bucket_steps(n_slots_true)
    n_win_pad = bucket_steps(n_windows, base=8) if n_windows else 0
    m_max = int(grid.m.max())
    q_max = int(grid.n_queues.max())
    n_seg, sched_edges, sched_scales = _schedule_rows(grid, cfg)
    sm = cfg.sleep_model
    fn = _compiled_sweep(
        n_slots, float(slot_us), m_max, q_max,
        float(cfg.service_rate_mpps), float(cfg.queue_capacity),
        float(cfg.wake_cost_us),
        (float(sm.base_us), float(sm.slope), float(sm.sigma_us),
         float(sm.tail_prob), float(sm.tail_mean_us)),
        (float(cfg.interference_prob), float(cfg.interference_mean_us),
         float(cfg.stall_rate_per_us), float(cfg.stall_mean_us)),
        cfg.energy_model.params(),
        n_seg, n_win_pad, float(cfg.window_us))
    seed64 = np.asarray(grid.seed, dtype=np.uint64)
    out, win, back_f = fn(
        jnp.asarray(grid.t_s_us, jnp.float32),
        jnp.asarray(grid.t_l_us, jnp.float32),
        jnp.asarray(grid.m, jnp.int32),
        jnp.asarray(grid.n_queues, jnp.int32),
        jnp.asarray(grid.rate_mpps, jnp.float32),
        jnp.asarray((seed64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        jnp.asarray((seed64 >> np.uint64(32)).astype(np.uint32)),
        jnp.full((n,), float(cfg.duration_us), jnp.float32),
        jnp.asarray(sched_edges, jnp.float32),
        jnp.asarray(sched_scales, jnp.float32))
    vals = {k: np.asarray(v, dtype=np.float64)
            for k, v in out._asdict().items()}
    return BatchStats(grid=grid, cfg=cfg, slot_us=float(slot_us),
                      offered=vals["offered"], dropped=vals["dropped"],
                      serviced=vals["serviced"], wakeups=vals["wakeups"],
                      busy_tries=vals["busy_tries"], cycles=vals["cycles"],
                      awake_us=vals["awake_us"], lat_area=vals["lat_area"],
                      vac_sum=vals["vac_sum"], nv_sum=vals["nv_sum"],
                      ts_arms=vals["ts_arms"], energy_uj=vals["energy_uj"],
                      win=(np.asarray(win, dtype=np.float64)[:, :n_windows]
                           if n_windows else np.empty(0)),
                      stepping="fixed", scan_len=n_slots,
                      n_steps=np.full(n, float(n_slots_true)),
                      forced_steps=np.zeros(n),
                      # fixed slots overshoot duration by the ceil slot
                      sim_time_us=np.full(n, n_slots_true * slot_us),
                      final_backlog=np.asarray(back_f, dtype=np.float64))
