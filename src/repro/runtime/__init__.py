"""repro.runtime — pluggable retrieval policies × workloads, sim/real parity.

The paper's contribution, factored into two orthogonal protocols:

  - ``RetrievalPolicy`` (policy.py): when to wake and poll — busy-poll,
    Metronome (adaptive Eq 10/12), fixed-period, equal-timeouts;
  - ``Workload`` (workload.py): what arrives — Poisson, CBR, on/off
    bursty, timestamped trace replay (speedup + jitter).

Two execution backends run *any* policy against *any* workload and
return one ``RunStats``:

  - ``simulate_run`` (sim.py): aggregate-exact discrete-event simulation;
  - ``Runtime`` (runtime.py): real OS threads draining real queues.

Multi-queue (RSS) ingress is first-class: a ``Dispatcher``
(dispatch.py) splits arrivals across N queues — uniform round-robin,
Zipf flow-hash skew, or idealized least-loaded — and an ``Assignment``
(assignment.py) maps poller threads to queues — shared sweep, dedicated
per-queue poller sets, or work stealing.  ``RunStats.per_queue`` breaks
every counter down by queue.

A third execution surface scales *exploration*: ``simulate_batch``
(batched.py) runs a whole ``SweepGrid`` of static operating points —
(T_S, T_L, M, n_queues, load, seed) — through a fixed-slot JAX engine in
one JIT-compiled call, and ``build_operating_table`` (calibrate.py)
distills such sweeps into an ``OperatingTable`` the controller consumes
as a calibrated feed-forward term.  Shared environment config
(``SimRunConfig``, ``SleepModel``) lives in simcore.py.

CPU sharing is first-class (apps.py): an ``AppLoad`` — duty-cycle CPU
burner, jitted JAX matmul tenant — co-runs with the pollers on the
threaded ``Runtime``/``Server`` (progress lands in
``RunStats.app_ops``/``app_cpu_ns``), and ``co_run_config`` maps an app
demand to the ``SimRunConfig`` interference model so both simulation
engines sweep co-location scenarios deterministically.

Nonstationary traffic is first-class (schedule.py): a ``LoadSchedule``
— step / ramp / sinusoid / MMPP-modulated / ``from_trace`` — modulates
any workload's rate over time (``ScheduledWorkload`` time-warps the
base process; the batched engine evaluates the schedule per slot), and
``SimRunConfig.window_us`` makes both simulation engines emit the same
windowed adaptation series (``RunStats.windows``, a ``WindowedSeries``)
from which ``TrackingStats`` — convergence time after each load
transition, overshoot, latency-target violation fraction, rho tracking
error — is computed by one shared code path.

Adding a retrieval strategy or a traffic scenario is a one-file change:
implement the protocol, and every backend, benchmark, and the serving
server can use it.
"""

from .assignment import (
    Assignment,
    DedicatedAssignment,
    SharedAssignment,
    StealingAssignment,
    ThreadSlot,
    clone_policy,
)
# The batched engine (and the calibration layer on top of it) are the
# only jax-dependent pieces of repro.runtime; load them lazily so the
# numpy-only event sim / threaded / serving paths neither require jax
# nor pay its import cost.
_LAZY_SUBMODULE = {
    "SweepGrid": "batched",
    "BatchStats": "batched",
    "simulate_batch": "batched",
    "unsupported_config_fields": "batched",
    "validate_batched_config": "batched",
    "OperatingPoint": "calibrate",
    "OperatingTable": "calibrate",
    "CalibrationMismatch": "calibrate",
    "build_operating_table": "calibrate",
    "schedule_spot_check": "calibrate",
    "CompileCache": "batched",
    "compile_cache_stats": "batched",
    "FleetGrid": "fleet",
    "FleetStats": "fleet",
    "simulate_fleet": "fleet",
}


def __getattr__(name: str):
    submodule = _LAZY_SUBMODULE.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module
    value = getattr(import_module(f".{submodule}", __name__), name)
    globals()[name] = value          # cache: next access skips this hook
    return value
from .apps import (
    AppLoad,
    DutyCycleBurner,
    MatmulAppLoad,
    co_run_config,
)
from .dispatch import (
    Dispatcher,
    FlowHashDispatch,
    LeastLoadedDispatch,
    RoundRobinDispatch,
    StaleLeastLoadedDispatch,
    WeightedDispatch,
)
from .policy import (
    BusyPollPolicy,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    MetronomePolicy,
    RetrievalPolicy,
    WakeContext,
)
from .queues import BoundedQueue
from .runtime import Runtime
from .schedule import (
    LoadSchedule,
    MMPPSchedule,
    RampSchedule,
    SinusoidSchedule,
    StepSchedule,
    from_trace,
)
from .sim import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimRunConfig,
    SleepModel,
    fleet_tail_reference,
    simulate_fleet_run,
    simulate_run,
)
from .simcore import (
    DEEP_CSTATE_ENERGY_MODEL,
    DEFAULT_ENERGY_MODEL,
    EnergyModel,
    FleetConfig,
)
from .stats import (
    QueueStats,
    Reservoir,
    RunStats,
    TrackingStats,
    WindowedSeries,
    hedged_latency_quantile,
)
from .workload import (
    CBRWorkload,
    OnOffBurstyWorkload,
    PoissonWorkload,
    ScheduledWorkload,
    TraceReplayWorkload,
    Workload,
)

__all__ = [
    "RetrievalPolicy",
    "WakeContext",
    "BusyPollPolicy",
    "MetronomePolicy",
    "FixedPeriodPolicy",
    "EqualTimeoutsPolicy",
    "Workload",
    "PoissonWorkload",
    "CBRWorkload",
    "OnOffBurstyWorkload",
    "TraceReplayWorkload",
    "ScheduledWorkload",
    "LoadSchedule",
    "StepSchedule",
    "RampSchedule",
    "SinusoidSchedule",
    "MMPPSchedule",
    "from_trace",
    "Dispatcher",
    "RoundRobinDispatch",
    "FlowHashDispatch",
    "LeastLoadedDispatch",
    "WeightedDispatch",
    "StaleLeastLoadedDispatch",
    "Assignment",
    "ThreadSlot",
    "SharedAssignment",
    "DedicatedAssignment",
    "StealingAssignment",
    "clone_policy",
    "BoundedQueue",
    "Runtime",
    "RunStats",
    "QueueStats",
    "Reservoir",
    "WindowedSeries",
    "TrackingStats",
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimRunConfig",
    "EnergyModel",
    "DEFAULT_ENERGY_MODEL",
    "DEEP_CSTATE_ENERGY_MODEL",
    "simulate_run",
    "FleetConfig",
    "simulate_fleet_run",
    "fleet_tail_reference",
    "hedged_latency_quantile",
    "FleetGrid",
    "FleetStats",
    "simulate_fleet",
    "CompileCache",
    "compile_cache_stats",
    "SweepGrid",
    "BatchStats",
    "simulate_batch",
    "unsupported_config_fields",
    "validate_batched_config",
    "OperatingPoint",
    "OperatingTable",
    "CalibrationMismatch",
    "build_operating_table",
    "schedule_spot_check",
    "AppLoad",
    "DutyCycleBurner",
    "MatmulAppLoad",
    "co_run_config",
]
