"""repro.runtime — pluggable retrieval policies × workloads, sim/real parity.

The paper's contribution, factored into two orthogonal protocols:

  - ``RetrievalPolicy`` (policy.py): when to wake and poll — busy-poll,
    Metronome (adaptive Eq 10/12), fixed-period, equal-timeouts;
  - ``Workload`` (workload.py): what arrives — Poisson, CBR, on/off
    bursty, timestamped trace replay (speedup + jitter).

Two execution backends run *any* policy against *any* workload and
return one ``RunStats``:

  - ``simulate_run`` (sim.py): aggregate-exact discrete-event simulation;
  - ``Runtime`` (runtime.py): real OS threads draining real queues.

Adding a retrieval strategy or a traffic scenario is a one-file change:
implement the protocol, and every backend, benchmark, and the serving
server can use it.
"""

from .policy import (
    BusyPollPolicy,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    MetronomePolicy,
    RetrievalPolicy,
    WakeContext,
)
from .queues import BoundedQueue
from .runtime import Runtime
from .sim import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimRunConfig,
    SleepModel,
    simulate_run,
)
from .stats import Reservoir, RunStats
from .workload import (
    CBRWorkload,
    OnOffBurstyWorkload,
    PoissonWorkload,
    TraceReplayWorkload,
    Workload,
)

__all__ = [
    "RetrievalPolicy",
    "WakeContext",
    "BusyPollPolicy",
    "MetronomePolicy",
    "FixedPeriodPolicy",
    "EqualTimeoutsPolicy",
    "Workload",
    "PoissonWorkload",
    "CBRWorkload",
    "OnOffBurstyWorkload",
    "TraceReplayWorkload",
    "BoundedQueue",
    "Runtime",
    "RunStats",
    "Reservoir",
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimRunConfig",
    "simulate_run",
]
