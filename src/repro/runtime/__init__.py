"""repro.runtime — pluggable retrieval policies × workloads, sim/real parity.

The paper's contribution, factored into two orthogonal protocols:

  - ``RetrievalPolicy`` (policy.py): when to wake and poll — busy-poll,
    Metronome (adaptive Eq 10/12), fixed-period, equal-timeouts;
  - ``Workload`` (workload.py): what arrives — Poisson, CBR, on/off
    bursty, timestamped trace replay (speedup + jitter).

Two execution backends run *any* policy against *any* workload and
return one ``RunStats``:

  - ``simulate_run`` (sim.py): aggregate-exact discrete-event simulation;
  - ``Runtime`` (runtime.py): real OS threads draining real queues.

Multi-queue (RSS) ingress is first-class: a ``Dispatcher``
(dispatch.py) splits arrivals across N queues — uniform round-robin,
Zipf flow-hash skew, or idealized least-loaded — and an ``Assignment``
(assignment.py) maps poller threads to queues — shared sweep, dedicated
per-queue poller sets, or work stealing.  ``RunStats.per_queue`` breaks
every counter down by queue.

Adding a retrieval strategy or a traffic scenario is a one-file change:
implement the protocol, and every backend, benchmark, and the serving
server can use it.
"""

from .assignment import (
    Assignment,
    DedicatedAssignment,
    SharedAssignment,
    StealingAssignment,
    ThreadSlot,
    clone_policy,
)
from .dispatch import (
    Dispatcher,
    FlowHashDispatch,
    LeastLoadedDispatch,
    RoundRobinDispatch,
)
from .policy import (
    BusyPollPolicy,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    MetronomePolicy,
    RetrievalPolicy,
    WakeContext,
)
from .queues import BoundedQueue
from .runtime import Runtime
from .sim import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimRunConfig,
    SleepModel,
    simulate_run,
)
from .stats import QueueStats, Reservoir, RunStats
from .workload import (
    CBRWorkload,
    OnOffBurstyWorkload,
    PoissonWorkload,
    TraceReplayWorkload,
    Workload,
)

__all__ = [
    "RetrievalPolicy",
    "WakeContext",
    "BusyPollPolicy",
    "MetronomePolicy",
    "FixedPeriodPolicy",
    "EqualTimeoutsPolicy",
    "Workload",
    "PoissonWorkload",
    "CBRWorkload",
    "OnOffBurstyWorkload",
    "TraceReplayWorkload",
    "Dispatcher",
    "RoundRobinDispatch",
    "FlowHashDispatch",
    "LeastLoadedDispatch",
    "Assignment",
    "ThreadSlot",
    "SharedAssignment",
    "DedicatedAssignment",
    "StealingAssignment",
    "clone_policy",
    "BoundedQueue",
    "Runtime",
    "RunStats",
    "QueueStats",
    "Reservoir",
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimRunConfig",
    "simulate_run",
]
