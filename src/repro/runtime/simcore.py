"""Backend-agnostic simulation core shared by every simulation engine.

The environment model (``SimRunConfig``), the timer-quality model
(``SleepModel`` and its paper-fitted instances), and the run-setup
normalization (dispatcher/assignment resolution, per-queue latency
reservoir construction) live here so that the two simulation engines —
the event-driven ``repro.runtime.sim.simulate_run`` and the batched JAX
``repro.runtime.batched.simulate_batch`` — share one config surface and
one stats-assembly convention instead of drifting apart.

Engines differ only in *how* they execute the renewal system:

  - the event engine walks wake events one at a time (exact, serial,
    one config per call);
  - the batched engine steps fixed time slots under ``jax.lax.scan``
    and ``vmap``s over a whole grid of configs (approximate, massively
    parallel).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .assignment import SharedAssignment
from .dispatch import RoundRobinDispatch
from .stats import Reservoir, WindowedSeries

__all__ = [
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "EnergyModel",
    "DEFAULT_ENERGY_MODEL",
    "DEEP_CSTATE_ENERGY_MODEL",
    "SimRunConfig",
    "FleetConfig",
    "EngineSetup",
    "WindowAccum",
    "prepare_run",
    "queue_reservoirs",
]


@dataclass(frozen=True)
class SleepModel:
    """actual = target + base + slope*target + |N(0, sigma)|
              + Exp(tail_mean) w.p. tail_prob            (us units).

    Fitted to paper Table 1 (mean/p99):
      hr_sleep :  base ~ 2.8us, slope ~ 0.027, sigma ~ 0.5   (mean +3.5..8.4)
      nanosleep:  base ~ 57.5us, slope ~ 0.003, sigma ~ 3.0  (mean +58 flat)
    The nanosleep arm additionally carries a heavy preemption tail —
    without it the simulator under-loses vs the paper's Table 3 (a +58us
    mean backlogs < 1024 descriptors; the paper still lost 3.9% at a 4096
    ring, implying rare multi-hundred-us pile-ups).  Tail parameters chosen
    so the q=1024..4096 loss ladder brackets the paper's.

    Energy accounting (``EnergyModel``) deliberately ignores this model's
    overshoot: the C-state and charged residency come from the *target*
    (the programmed timer — what a next-timer-event cpuidle governor
    sees), so timer noise is unpaid time in the already-chosen state, a
    second-order correction folded into the model error.
    """

    base_us: float
    slope: float
    sigma_us: float
    tail_prob: float = 0.0
    tail_mean_us: float = 0.0

    def sample(self, target_us: np.ndarray | float, rng: np.random.Generator):
        t = np.asarray(target_us, dtype=np.float64)
        noise = np.abs(rng.normal(0.0, self.sigma_us, size=t.shape))
        out = t + self.base_us + self.slope * t + noise
        if self.tail_prob:
            hit = rng.random(size=t.shape) < self.tail_prob
            out = out + hit * rng.exponential(self.tail_mean_us, size=t.shape)
        return out


HR_SLEEP_MODEL = SleepModel(base_us=2.8, slope=0.027, sigma_us=0.5)
NANOSLEEP_MODEL = SleepModel(base_us=57.5, slope=0.003, sigma_us=3.0,
                             tail_prob=0.01, tail_mean_us=400.0)
PERFECT_SLEEP_MODEL = SleepModel(base_us=0.0, slope=0.0, sigma_us=0.0)


@dataclass(frozen=True)
class EnergyModel:
    """C-state/DVFS package power model (W x us = uJ), charged
    identically by every execution layer.

    Three components:

      - **active**: ``active_power_w`` per awake microsecond (wake cost
        plus service time).  The busy-poll spin model additionally
        multiplies by ``dvfs_busy_scale`` — a spinning core pins its
        turbo frequency while a duty-cycled Metronome core can downclock
        between bursts.
      - **sleep**: every time a thread arms a sleep with target ``T``
        the core enters the *deepest* C-state whose
        ``min_residency_us <= T`` and is charged that state's
        ``power_w * T``.  This is the next-timer-event governor
        approximation (Linux cpuidle menu/teo): the state is picked from
        the *programmed* timer (T_S or T_L), not the realized residency,
        so timer overshoot (``SleepModel``) is unpaid noise in the
        already-chosen state.  Short targets stay in a shallow state —
        the minimum-residency thresholds are what make rapid polling
        energy-expensive even when its CPU looks cheap.
      - **transition**: each arm additionally pays the chosen state's
        ``transition_uj`` (entry + exit energy of one wake cycle).

    ``sleep_states`` holds ``(power_w, transition_uj, min_residency_us)``
    tuples; they are normalized shallow-to-deep at construction and the
    shallowest must have threshold 0 so every target lands somewhere.
    Deep states trade a lower power floor for higher per-wake transition
    energy and a residency floor — which is why the energy-optimal
    (T_S, T_L) sits at *longer* sleeps than the CPU-optimal point (see
    ``build_operating_table(objective="energy")``): per-thread sleep
    power scales with ``m * P(T_S)`` while CPU's wake overhead scales
    with ``m / T_S``, so the two objectives rank operating points
    differently.

    Accounting convention shared by the engines: energy is charged at
    arm time (a sleep still pending at run end was charged when armed),
    T_S-class arms are empty claims plus drain-end releases, T_L-class
    arms are blocked wakes (``busy_tries``).
    """

    active_power_w: float = 10.0
    # (power_w, transition_uj, min_residency_us), shallow -> deep
    sleep_states: tuple = ((1.5, 0.5, 0.0),
                           (0.6, 4.0, 30.0),
                           (0.25, 15.0, 300.0))
    dvfs_busy_scale: float = 1.0

    def __post_init__(self):
        states = tuple(sorted(
            (tuple(float(x) for x in s) for s in self.sleep_states),
            key=lambda s: s[2]))
        if not states or states[0][2] > 0.0:
            raise ValueError(
                "EnergyModel.sleep_states needs a shallow state with "
                "min_residency_us == 0 so every sleep target lands "
                "somewhere")
        if any(len(s) != 3 for s in states):
            raise ValueError("sleep_states entries must be "
                             "(power_w, transition_uj, min_residency_us)")
        object.__setattr__(self, "sleep_states", states)

    def params(self) -> tuple:
        """Hashable static parameters for the jit-compiled kernels."""
        return (float(self.active_power_w), float(self.dvfs_busy_scale),
                self.sleep_states)

    def select(self, target_us: float) -> tuple:
        """``(power_w, transition_uj)`` of the deepest C-state whose
        minimum residency fits the programmed sleep target."""
        p_w, t_uj = self.sleep_states[0][0], self.sleep_states[0][1]
        for pw, tuj, thr_us in self.sleep_states[1:]:
            if target_us >= thr_us:
                p_w, t_uj = pw, tuj
        return p_w, t_uj

    def arm_energy_uj(self, target_us: float) -> float:
        """Sleep + transition energy of ONE armed sleep of the given
        target: deepest-fitting state's power x target + its
        transition."""
        p_w, t_uj = self.select(float(target_us))
        return p_w * float(target_us) + t_uj

    def active_energy_uj(self, awake_us, *, spin: bool = False):
        """Energy of awake time; ``spin=True`` applies the DVFS busy
        scale (busy-poll cores pin their max frequency)."""
        scale = self.dvfs_busy_scale if spin else 1.0
        return self.active_power_w * scale * np.asarray(
            awake_us, dtype=np.float64)


DEFAULT_ENERGY_MODEL = EnergyModel()
# Aggressive deep-sleep part: much lower floor power behind much larger
# transition costs and residency thresholds — the regime where the
# energy-optimal (T_S, T_L) visibly diverges from the CPU-optimal point
# (benchmarks/power.py pins that divergence).
DEEP_CSTATE_ENERGY_MODEL = EnergyModel(
    active_power_w=10.0,
    sleep_states=((2.0, 0.2, 0.0),
                  (0.3, 10.0, 40.0),
                  (0.12, 30.0, 400.0)),
    dvfs_busy_scale=1.25)


@dataclass(frozen=True)
class SimRunConfig:
    """Environment knobs — everything that is *not* the policy or the
    workload: service rate, queue size, timer quality, OS interference."""

    duration_us: float = 1_000_000.0
    service_rate_mpps: float = 29.76          # mu (packets / us)
    queue_capacity: int = 1024                # Rx descriptors *per queue*
    n_queues: int = 1                         # Rx queues (RSS rings)
    sleep_model: SleepModel = HR_SLEEP_MODEL
    wake_cost_us: float = 1.0                 # poll+return CPU cost per wake
    # OS interference (paper Sec 5.6): each wake delayed by Exp(mean) w.p. q.
    interference_prob: float = 0.0
    interference_mean_us: float = 0.0
    # Correlated stalls: Poisson system-wide freeze events delaying EVERY
    # wake that falls inside them (kernel timer-wheel/preemption pile-ups).
    # Needed for the paper's Table-3 weak queue-size dependence: backup
    # threads absorb uncorrelated per-thread tails, so only correlated
    # stalls overflow a 4096-descriptor ring.
    stall_rate_per_us: float = 0.0
    stall_mean_us: float = 0.0
    seed: int = 0
    timeseries_bin_us: float = 0.0            # >0: emit binned time series
    latency_reservoir: int = 262_144
    # Nonstationary traffic: a repro.runtime.schedule.LoadSchedule that
    # modulates the workload's rate over time.  The event engine wraps
    # the workload in a time-warping ScheduledWorkload; the batched
    # engine evaluates the schedule's piecewise-constant scale per slot.
    schedule: object | None = None
    # >0: both simulation engines emit RunStats.windows — per-window
    # offered/served/latency/CPU/rho accumulators (WindowedSeries), the
    # cross-backend adaptation-tracking surface (unlike
    # timeseries_bin_us, which stays event-engine-only).
    window_us: float = 0.0
    # C-state/DVFS power accounting, charged by every engine with the
    # same arm-time convention (see EnergyModel) and surfaced as
    # RunStats.energy_uj / energy_per_packet_nj.
    energy_model: EnergyModel = DEFAULT_ENERGY_MODEL

    @property
    def is_noisy(self) -> bool:
        """True when any CPU-sharing injection (per-wake interference or
        correlated stalls) is active — i.e. this is a shared-host, not a
        quiet-host, environment."""
        return bool(self.interference_prob or self.stall_rate_per_us)

    def interference_slack_us(self) -> float:
        """Expected mean-vacation shift of this environment's OS
        interference over the quiet-host closed forms, in us.

        Two additive terms:

          - per-wake: every re-sleep stretches by Exp(mean) w.p. q, so
            the wake ending a vacation arrives ``q * mean`` late in
            expectation;
          - correlated stalls: the wake ending a vacation lands inside
            an open Exp(s) window w.p. ~ the stalled time fraction
            ``rate * s`` and is deferred by the window's residual life
            (= s, memoryless), i.e. ``rate * s^2`` — the E[W^2]/2 tail
            of the Poisson window process (E[W^2] = 2 s^2).

        Calibration's analytic guard widens its quiet-host App-C
        tolerance by this slack so contention-honest sweeps are not
        rejected for disagreeing with a quiet-host prediction.
        """
        per_wake = self.interference_prob * self.interference_mean_us
        stall = self.stall_rate_per_us * self.stall_mean_us ** 2
        return per_wake + stall


_LB_POLICIES = ("uniform", "weighted", "least-loaded")

# M/M/1 link waits blow up as the far-rack rate approaches the link
# rate; the fluid model clamps the wait at utilization 98% (a 50x
# service time) so a momentarily oversubscribed link yields a large
# finite delay instead of a NaN that poisons the whole sweep point
_LINK_UTIL_CLAMP = 0.98


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-level environment: N replica hosts behind one load balancer.

    Everything *outside* a single host — how the shared arrival stream
    is split across replicas, and what the network between the balancer
    and each rack costs:

      - ``lb``: arrival-split policy.  ``uniform`` and ``weighted`` are
        static shares; ``least-loaded`` follows a softmin over a
        backlog snapshot that refreshes only every ``lb_stale_us``
        (a balancer polling replica queue depths at a finite rate —
        the stale-signal regime where load balancing misfires).
      - topology: the first ``round(far_fraction * n_hosts)`` hosts sit
        in a far rack.  Every packet pays its rack's constant cost
        (``near_cost_us`` / ``far_cost_us``); far packets additionally
        queue on a shared bottleneck link modeled M/M/1-style — wait
        ``1 / (link_rate_mpps - far_rate)``, clamped near saturation
        (``link_rate_mpps = 0`` means no bottleneck).  Network delay is
        charged to a separate per-host accumulator, not the host's
        queue-depth integral, so host-level parity vs the single-host
        engines is unaffected.

    Hedge deadlines are *operating-point* knobs, not environment: they
    live per sweep point on ``FleetGrid``, next to (T_S, T_L, M).
    """

    n_hosts: int = 1
    lb: str = "uniform"
    host_weights: tuple = ()           # traffic shares, lb="weighted" only
    lb_stale_us: float = 0.0           # least-loaded snapshot refresh lag
    lb_softness_pkts: float = 4.0      # softmin temperature (packets)
    far_fraction: float = 0.0
    near_cost_us: float = 0.0
    far_cost_us: float = 0.0
    link_rate_mpps: float = 0.0        # shared far-rack bottleneck (0 = none)

    def validate(self) -> "FleetConfig":
        if self.n_hosts < 1:
            raise ValueError("FleetConfig.n_hosts must be >= 1")
        if self.lb not in _LB_POLICIES:
            raise ValueError(f"FleetConfig.lb must be one of {_LB_POLICIES}")
        if self.lb == "weighted":
            if len(self.host_weights) != self.n_hosts:
                raise ValueError("host_weights must have one entry per host")
            if min(self.host_weights) <= 0:
                raise ValueError("host_weights must be positive")
        elif self.host_weights:
            raise ValueError("host_weights only apply to lb='weighted'")
        if not 0.0 <= self.far_fraction <= 1.0:
            raise ValueError("far_fraction must be in [0, 1]")
        if min(self.near_cost_us, self.far_cost_us,
               self.link_rate_mpps, self.lb_stale_us) < 0:
            raise ValueError("fleet costs/rates must be >= 0")
        if self.lb_softness_pkts <= 0:
            raise ValueError("lb_softness_pkts must be > 0")
        return self

    # -- static split ----------------------------------------------------------
    def shares(self) -> np.ndarray:
        """Static per-host traffic shares.  ``least-loaded`` has no
        static split (it reacts to backlog); its long-run share over
        identical hosts is uniform, which is what the exact event-engine
        reference path uses."""
        if self.lb == "weighted":
            w = np.asarray(self.host_weights, dtype=np.float64)
            return w / w.sum()
        return np.full(self.n_hosts, 1.0 / self.n_hosts)

    # -- topology --------------------------------------------------------------
    def far_hosts(self) -> int:
        return int(round(self.far_fraction * self.n_hosts))

    def far_mask(self) -> np.ndarray:
        """Host h is in the far rack iff h < far_hosts() — a fixed
        assignment shared by the batched kernel and the event reference."""
        return np.arange(self.n_hosts) < self.far_hosts()

    def host_cost_us(self) -> np.ndarray:
        return np.where(self.far_mask(), self.far_cost_us,
                        self.near_cost_us)

    def link_wait_us(self, far_rate_mpps: float) -> float:
        """M/M/1-style mean wait on the shared far-rack link at the given
        far-rack arrival rate, clamped near saturation."""
        if self.link_rate_mpps <= 0.0 or self.far_hosts() == 0:
            return 0.0
        gap = max(self.link_rate_mpps - far_rate_mpps,
                  (1.0 - _LINK_UTIL_CLAMP) * self.link_rate_mpps)
        return 1.0 / gap

    def mean_topo_delay_us(self, fleet_rate_mpps: float) -> float:
        """Traffic-weighted mean network delay per packet at the given
        fleet aggregate rate — the share of the latency budget the
        network consumes before any host-level tuning can help (used by
        calibration's fleet pass-through to shrink the host target)."""
        shares = self.shares()
        far = self.far_mask()
        far_rate = float(fleet_rate_mpps * shares[far].sum())
        per_host = self.host_cost_us() + far * self.link_wait_us(far_rate)
        return float((shares * per_host).sum())


@dataclass
class EngineSetup:
    """Normalized run inputs an engine starts from: seeded rng, resolved
    dispatcher/assignment, thread slots, the distinct policy objects
    behind them (already ``reset()``), and the workload — schedule-
    wrapped when the config carries a ``LoadSchedule``."""

    rng: np.random.Generator
    n_queues: int
    dispatcher: object
    assignment: object
    slots: list
    policies: list
    workload: object = None


def scheduled_workload(workload, cfg: SimRunConfig):
    """Apply ``cfg.schedule`` to ``workload`` (idempotent: a workload
    already wrapped with the *same* schedule passes through so callers
    can pre-wrap; a pre-wrap carrying a *different* schedule raises —
    silently running one schedule while stamping another on the stats
    would poison every tracking consumer downstream)."""
    from .workload import ScheduledWorkload

    if isinstance(workload, ScheduledWorkload):
        if cfg.schedule is not None and workload.schedule != cfg.schedule:
            raise ValueError(
                "workload is already wrapped with schedule "
                f"{workload.schedule.descriptor()!r} but cfg.schedule is "
                f"{cfg.schedule.descriptor()!r}; pass the bare workload "
                "or make the schedules match")
        return workload
    if cfg.schedule is None:
        return workload
    return ScheduledWorkload(workload, cfg.schedule)


def prepare_run(policy, workload, cfg: SimRunConfig, *,
                dispatcher=None, assignment=None) -> EngineSetup:
    """Resolve defaults and reset all run-scoped state, identically for
    every engine: apply the config's load schedule to the workload, seed
    the rng, reset the workload, resolve the dispatcher and assignment,
    expand the policy into thread slots, and reset each distinct policy
    object exactly once (shared slots alias one policy; dedicated slots
    carry per-queue clones)."""
    workload = scheduled_workload(workload, cfg)
    rng = np.random.default_rng(cfg.seed)
    workload.reset(rng)
    nq = max(int(cfg.n_queues), 1)
    dispatcher = dispatcher or RoundRobinDispatch()
    dispatcher.reset(nq, rng)
    assignment = assignment or SharedAssignment()
    slots = assignment.slots(policy, nq)
    policies, seen = [], set()
    for s in slots:
        if id(s.policy) not in seen:
            seen.add(id(s.policy))
            policies.append(s.policy)
    for p in policies:
        p.reset()
    return EngineSetup(rng=rng, n_queues=nq, dispatcher=dispatcher,
                       assignment=assignment, slots=slots,
                       policies=policies, workload=workload)


class WindowAccum:
    """Serial-engine side of the windowed adaptation series: raw
    per-window sums accumulated at event time, assembled into the same
    ``WindowedSeries`` the batched engine emits (so
    ``TrackingStats`` is one code path across backends).

    Inactive (every call a no-op) when ``cfg.window_us == 0`` — the
    engines call unconditionally and pay nothing on stationary runs.

    Contributions at event times past ``duration_us`` (the event
    engine's final-drain pass) go to the ``spill_*`` scalars, NOT the
    last window: the batched in-scan accumulator never runs past
    duration, so clamping drain events into the last window would skew
    windowed parity one-sidedly while silently dropping them would
    break the windows-sum-to-totals conservation law.
    """

    __slots__ = ("window_us", "duration_us", "n", "offered", "served",
                 "lat_area", "awake", "energy", "rho_sum", "rho_cnt",
                 "ts_sum", "samples", "spill_offered", "spill_served",
                 "spill_lat_area", "spill_awake", "spill_energy")

    def __init__(self, cfg: SimRunConfig):
        self.window_us = float(cfg.window_us)
        self.duration_us = float(cfg.duration_us)
        self.n = (int(np.ceil(cfg.duration_us / cfg.window_us))
                  if cfg.window_us > 0 else 0)
        n = max(self.n, 1)
        self.offered = np.zeros(n)
        self.served = np.zeros(n)
        self.lat_area = np.zeros(n)
        self.awake = np.zeros(n)
        self.energy = np.zeros(n)
        self.rho_sum = np.zeros(n)
        self.rho_cnt = np.zeros(n)
        self.ts_sum = np.zeros(n)
        self.samples: list[list[float]] = [[] for _ in range(n)]
        self.spill_offered = 0.0
        self.spill_served = 0.0
        self.spill_lat_area = 0.0
        self.spill_awake = 0.0
        self.spill_energy = 0.0

    def _idx(self, t_us: float) -> int:
        return min(max(int(t_us / self.window_us), 0), self.n - 1)

    def add(self, t_us: float, *, offered=0.0, served=0.0, lat_area=0.0,
            awake=0.0, energy_uj=0.0) -> None:
        if not self.n:
            return
        if t_us >= self.duration_us:
            self.spill_offered += offered
            self.spill_served += served
            self.spill_lat_area += lat_area
            self.spill_awake += awake
            self.spill_energy += energy_uj
            return
        i = self._idx(t_us)
        self.offered[i] += offered
        self.served[i] += served
        self.lat_area[i] += lat_area
        self.awake[i] += awake
        self.energy[i] += energy_uj

    def control(self, t_us: float, rho: float, ts_us: float) -> None:
        """One controller sample (rho estimate + current T_S) — call on
        each primary wake; NaN rho (no estimator) and post-duration
        (final-drain) samples are skipped."""
        if not self.n or not np.isfinite(rho) or t_us >= self.duration_us:
            return
        i = self._idx(t_us)
        self.rho_sum[i] += rho
        self.rho_cnt[i] += 1
        self.ts_sum[i] += ts_us

    def latency_samples(self, t_us: float, values) -> None:
        if not self.n or t_us >= self.duration_us:
            return
        self.samples[self._idx(t_us)].extend(values)

    def series(self, cfg: SimRunConfig) -> WindowedSeries | None:
        if not self.n:
            return None
        p99 = np.full(self.n, np.nan)
        for i, s in enumerate(self.samples):
            if s:
                p99[i] = float(np.percentile(np.asarray(s), 99))
        return WindowedSeries(
            window_us=self.window_us,
            service_rate_mpps=cfg.service_rate_mpps,
            offered=self.offered, served=self.served,
            lat_area_us=self.lat_area, awake_us=self.awake,
            energy_uj=self.energy,
            rho_sum=self.rho_sum, rho_cnt=self.rho_cnt,
            ts_sum=self.ts_sum, p99_latency_us=p99,
            spill_offered=self.spill_offered,
            spill_served=self.spill_served,
            spill_lat_area_us=self.spill_lat_area,
            spill_awake_us=self.spill_awake,
            spill_energy_uj=self.spill_energy)


def queue_reservoirs(cfg: SimRunConfig, n_queues: int) -> list[Reservoir]:
    """One latency reservoir per Rx queue, each with an independently
    derived seed (``SeedSequence.spawn``) so eviction choices are
    decorrelated across queues — seeding every queue's reservoir with the
    same default seed would correlate which samples survive once the
    reservoirs overflow."""
    seeds = np.random.SeedSequence(cfg.seed).spawn(n_queues)
    return [Reservoir(cfg.latency_reservoir,
                      seed=int(ss.generate_state(1)[0]))
            for ss in seeds]
