"""Fleet-scale batched simulation: N Metronome hosts in one jit call.

The batched engine (``repro.runtime.batched``) vmaps the fixed-slot
kernel over operating points; this module adds the *host* axis on top:
a ``FleetGrid`` stacks ``n_hosts`` replica hosts per sweep point and
runs the single-host slot dynamics under a second ``vmap``
(point x host), with three fleet-level stages around the per-host body:

  1. **Load balancer.**  Each slot, the shared (schedule-modulated)
     arrival stream splits across hosts by ``FleetConfig.lb``:
     ``uniform`` (1/H), ``weighted`` (static shares), or
     ``least-loaded`` — a softmin over a *stale* backlog snapshot that
     refreshes only every ``lb_stale_us`` (the finite-polling-rate
     balancer whose stale signal herds load onto a replica that *was*
     idle).
  2. **Topology.**  The first ``round(far_fraction*H)`` hosts sit in a
     far rack: every admitted packet pays its rack's constant cost, and
     far packets additionally queue on a shared bottleneck link modeled
     M/M/1-style (wait ``1/(link_rate - far_rate)``, clamped near
     saturation).  Network delay accumulates in a separate per-host
     ``topo_area`` — it is real end-to-end latency but NOT host queue
     depth, so host-level parity vs the single-host engines is
     untouched.
  3. **Hedged requests.**  A per-point hedge deadline D duplicates
     requests that are predicted to miss it: each slot, the fraction
     ``sigmoid((backlog/mu - D) / (D/4))`` of a host's admitted packets
     is re-injected into the currently least-loaded *other* host — a
     smooth fluid stand-in for "duplicate to a second replica after D;
     first completion wins".  Duplicates burn real CPU on the partner
     (cancellation is not modeled in-scan, so fleet CPU is a
     conservative upper bound) and are counted in ``hedge_dup``, not in
     ``offered``.  The *tail benefit* of hedging — both replicas must
     stall for a request to stay slow — is evaluated post-scan by the
     closed-form model ``repro.runtime.stats.hedged_latency_quantile``
     on the per-host measured means, and pinned against the exact
     first-completion-wins reference (``repro.runtime.sim.
     fleet_tail_reference``) in tests.

**Per-host parity contract.**  Host ``h`` of a fleet row with seed
``s`` draws exactly the PRNG stream of a single-host batched run seeded
``s + h`` (the per-host key is ``fold_in(fold_in(PRNGKey(0), lo + h),
hi)`` on the split 64-bit seed; the carry across the low word is
ignored, so keep fleet seeds below ``2**32 - n_hosts``).  Under uniform
round-robin with topology and hedging off, host ``h`` at fleet rate
``lam`` is the single-host kernel at rate ``lam/H`` — which is what the
fleet-vs-merged-single-host parity test pins against the *event*
engine within the existing quiet bands.

**Device sharding.**  ``simulate_fleet(..., shard=True)`` splits the
point axis across local devices via ``repro.compat.shard_map`` (each
device vmaps its slice of points over all hosts); ``shard=None`` auto-
enables when more than one device is visible, and ``shard=False``
forces the pure-vmap path.  CI exercises the sharded path with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  Both paths go
through one ``CompileCache``d jit per static shape — a 1000-host x
8-point sweep is ONE jit call, no Python loop over hosts.

Cluster rollups go through the existing ``RunStats`` machinery:
``FleetStats.host_run_stats(i)`` yields one ``RunStats`` per host and
``to_run_stats(i)`` n-way-merges them (``RunStats.merge_all``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .batched import (
    CompileCache,
    SweepGrid,
    _schedule_rows,
    bucket_steps,
    energy_arm_cost,
    validate_batched_config,
)
from .batched_adaptive import (
    _FILL_SLACK_PKTS,
    _RATE_EPS,
    _WAKE_EPS_US,
    estimate_adaptive_steps,
)
from .simcore import _LINK_UTIL_CLAMP, FleetConfig, SimRunConfig
from .stats import Reservoir, RunStats, hedged_latency_quantile

__all__ = ["FleetGrid", "FleetStats", "simulate_fleet"]

_LB_CODE = {"uniform": 0, "weighted": 1, "least-loaded": 2}


@dataclass(frozen=True)
class FleetGrid:
    """A flat batch of fleet operating points.

    ``grid`` holds the per-host knobs (T_S, T_L, M, n_queues, seed) and
    the FLEET-AGGREGATE offered rate per point (``rate_mpps`` is what
    the balancer receives; each host sees its share).  ``fleet`` is the
    shared environment (host count, LB policy, topology) and
    ``hedge_deadline_us`` is a per-point operating knob — it is a
    *traced* kernel input, so one compilation sweeps hedge deadlines
    next to (T_S, T_L, M) without re-tracing.
    """

    grid: SweepGrid
    fleet: FleetConfig
    hedge_deadline_us: np.ndarray     # (len(grid),); <= 0 disables
    shape: tuple = ()

    @classmethod
    def product(cls, *, fleet: FleetConfig, t_s_us, t_l_us, rate_mpps,
                m=(3,), n_queues=(1,), seeds=(0,),
                hedge_deadline_us=(0.0,), schedules=None) -> "FleetGrid":
        """Dense cartesian grid with a trailing hedge-deadline axis on
        top of ``SweepGrid.product``'s axes (``rate_mpps`` entries are
        fleet aggregates)."""
        fleet.validate()
        base = SweepGrid.product(t_s_us=t_s_us, t_l_us=t_l_us,
                                 rate_mpps=rate_mpps, m=m,
                                 n_queues=n_queues, seeds=seeds,
                                 schedules=schedules)
        hedge = np.atleast_1d(np.asarray(hedge_deadline_us,
                                         dtype=np.float64))
        nh = hedge.size
        shape = base.shape + (nh,)
        grid = SweepGrid(
            t_s_us=np.repeat(base.t_s_us, nh),
            t_l_us=np.repeat(base.t_l_us, nh),
            m=np.repeat(base.m, nh),
            n_queues=np.repeat(base.n_queues, nh),
            rate_mpps=np.repeat(base.rate_mpps, nh),
            seed=np.repeat(base.seed, nh),
            shape=shape,
            schedules=(tuple(s for s in base.schedules
                             for _ in range(nh))
                       if base.schedules else ()))
        return cls(grid=grid, fleet=fleet,
                   hedge_deadline_us=np.tile(hedge, len(base)),
                   shape=shape)

    @classmethod
    def of_points(cls, points, *, fleet: FleetConfig) -> "FleetGrid":
        """Arbitrary point list; each dict takes ``SweepGrid`` keys plus
        an optional ``hedge_deadline_us`` (default 0 = no hedging)."""
        fleet.validate()
        pts = list(points)
        base = SweepGrid.of_points(pts)
        hedge = np.asarray([p.get("hedge_deadline_us", 0.0) for p in pts],
                           dtype=np.float64)
        return cls(grid=base, fleet=fleet, hedge_deadline_us=hedge,
                   shape=(len(pts),))

    def __len__(self) -> int:
        return len(self.grid)

    def point(self, i: int) -> dict:
        d = self.grid.point(i)
        d["hedge_deadline_us"] = float(self.hedge_deadline_us[i])
        d["n_hosts"] = self.fleet.n_hosts
        d["lb"] = self.fleet.lb
        return d


class _FleetSlotStats(NamedTuple):
    offered: jnp.ndarray       # all fields (n_hosts,) per point
    dropped: jnp.ndarray
    serviced: jnp.ndarray
    wakeups: jnp.ndarray
    busy_tries: jnp.ndarray
    cycles: jnp.ndarray
    awake_us: jnp.ndarray
    lat_area: jnp.ndarray      # host queue-depth integral (packet*us)
    vac_sum: jnp.ndarray
    nv_sum: jnp.ndarray
    ts_arms: jnp.ndarray       # T_S-class sleeps armed (empty + release)
    energy_uj: jnp.ndarray     # EnergyModel charge (active + arms)
    topo_area: jnp.ndarray     # network delay integral (packet*us)
    hedge_dup: jnp.ndarray     # duplicate requests issued by this host


def _build_fleet_sweep(n_slots: int, slot_us: float, m_max: int,
                       q_max: int, n_hosts: int, mu: float,
                       capacity: float, wake_cost_us: float,
                       sleep_params: tuple, interference_params: tuple,
                       energy_params: tuple,
                       n_seg: int, lb_code: int, lb_weights: tuple,
                       lb_softness_pkts: float, stale_every_slots: int,
                       far_count: int, near_cost_us: float,
                       far_cost_us: float, link_rate_mpps: float,
                       n_shards: int, stepping: str = "fixed"):
    """Build + jit the (point x host) fleet kernel for one static shape.

    The per-host slot body is the single-host kernel's, line for line
    (same PRNG key discipline per host — the parity contract), wrapped
    in an inner host vmap; the load-balancer split, the topology delay,
    and the hedge-duplicate exchange are the only cross-host stages.
    ``n_shards > 1`` wraps the point-axis vmap in ``shard_map`` over the
    first ``n_shards`` local devices.

    ``stepping="fixed"`` scans ``n_slots`` constant ``slot_us`` slots
    (``duration`` is traced and steps past it are carry-held no-ops, so
    one bucketed scan length serves nearby durations bit-identically);
    ``stepping="adaptive"`` treats ``n_slots`` as the event-jump step
    *budget*: every scan step advances one shared variable ``dt`` per
    point — the min over all hosts' wake / drain-out / fill boundaries,
    the schedule segment end, each host's next correlated-stall start,
    and the LB stale-snapshot refresh lattice (the refresh is a jump
    boundary, so the stale signal updates exactly on its
    ``lb_stale_us`` grid) — and the per-host body applies the
    closed-form multi-slot aggregates of ``batched_adaptive``.  The
    cross-host stages (LB split, bottleneck-link M/M/1 wait at the
    macro-slot's admission rate, fluid hedge duplication) consume the
    same ``dt``.
    """
    base_us, slope, sigma_us, tail_prob, tail_mean_us = sleep_params
    intf_prob, intf_mean_us, stall_rate, stall_mean_us = interference_params
    active_power_w, _dvfs_scale, e_states = energy_params
    stall_p = 1.0 - math.exp(-stall_rate * slot_us) if stall_rate else 0.0
    dt = slot_us
    t_idx = jnp.arange(m_max)
    q_idx = jnp.arange(q_max)
    h_idx = jnp.arange(n_hosts)
    far_mask = (h_idx < far_count)
    rack_cost_us = jnp.where(far_mask, far_cost_us, near_cost_us)
    topo_on = (near_cost_us > 0.0 or far_cost_us > 0.0
               or link_rate_mpps > 0.0)
    w_static = (jnp.asarray(lb_weights, jnp.float32) if lb_code == 1
                else jnp.full((n_hosts,), 1.0 / n_hosts, jnp.float32))

    def one_fleet(t_s, t_l, m, nq, lam, seed_lo, seed_hi, hedge_d,
                  duration, sched_edges, sched_scales):
        tmask = t_idx < m
        qmask = q_idx < nq
        # per-arm C-state charges are point constants shared by every
        # host (the target, not the realized vacancy, picks the state)
        e_arm_s = energy_arm_cost(t_s, e_states)
        e_arm_l = energy_arm_cost(t_l, e_states)

        # per-host keys: host h draws the stream of a single-host run
        # seeded (seed + h) — the fleet<->single-host parity contract
        host_lo = seed_lo + h_idx.astype(jnp.uint32)

        def init_host(lo):
            k = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(0), lo), seed_hi)
            k, k0 = jax.random.split(k)
            s0 = jax.random.uniform(k0, (m_max,)) * t_s
            # the extra split exists only in adaptive builds, so the
            # fixed kernel's per-host streams stay bit-identical
            if stepping == "adaptive" and stall_rate > 0.0:
                k, kst = jax.random.split(k)
                ns0 = jax.random.exponential(kst, ()) / stall_rate
            else:
                ns0 = jnp.float32(jnp.inf)
            return k, s0, ns0

        keys, sleep0_h, next0_h = jax.vmap(init_host)(host_lo)
        sleep0_h = jnp.where(tmask[None, :],
                             jnp.maximum(sleep0_h, dt), jnp.inf)

        def host_step(key_h, t, scale_t, lam_h, sleep_rem, attached,
                      backlog, vac_timer, arr_res, stall_end):
            """One host, one slot — the single-host kernel body."""
            now = t.astype(jnp.float32) * dt
            lam_q = jnp.where(qmask, lam_h / nq, 0.0)
            kt_step = jax.random.fold_in(key_h, t)
            if tail_prob > 0.0:
                kt_step, kp, ku = jax.random.split(kt_step, 3)
            if intf_prob > 0.0:
                kt_step, kip, kie = jax.random.split(kt_step, 3)
            if stall_p > 0.0:
                kt_step, ksp, kse, ksu = jax.random.split(kt_step, 4)
            zs = jax.random.normal(kt_step, (q_max + m_max,))

            if stall_p > 0.0:
                hit_s = jax.random.uniform(ksp, ()) < stall_p
                win = now + stall_mean_us * jax.random.exponential(kse, ())
                stall_end = jnp.where(hit_s,
                                      jnp.maximum(stall_end, win),
                                      stall_end)

            if n_seg > 0:
                mu_a = lam_q * scale_t * dt
            else:
                mu_a = lam_q * dt
            raw = arr_res + mu_a + jnp.sqrt(mu_a) * zs[:q_max]
            a = jnp.maximum(raw, 0.0)
            arr_res = jnp.minimum(raw, 0.0)
            room = jnp.maximum(capacity - backlog, 0.0)
            adm = jnp.minimum(a, room)
            backlog = backlog + adm
            offered = a.sum()
            dropped = (a - adm).sum()

            over = jnp.full((m_max,), base_us)
            if sigma_us > 0.0:
                over = over + sigma_us * jnp.abs(zs[q_max:])
            if tail_prob > 0.0:
                hit = jax.random.uniform(kp, (m_max,)) < tail_prob
                over = over + hit * tail_mean_us * jax.random.exponential(
                    ku, (m_max,))
            if intf_prob > 0.0:
                ihit = jax.random.uniform(kip, (m_max,)) < intf_prob
                over = over + ihit * intf_mean_us * jax.random.exponential(
                    kie, (m_max,))
            slp_s = t_s * (1.0 + slope) + over
            slp_l = t_l * (1.0 + slope) + over

            sleeping = tmask & (attached < 0)
            sleep_rem = jnp.where(sleeping, sleep_rem - dt, sleep_rem)
            woken = sleeping & (sleep_rem <= 0.0)
            if stall_p > 0.0:
                push = woken & (now < stall_end)
                woken = woken & ~push
                sleep_rem = jnp.where(
                    push,
                    stall_end - now + jax.random.uniform(ksu, (m_max,)),
                    sleep_rem)
            n_wake = woken.sum().astype(jnp.float32)

            occ = (jax.nn.one_hot(attached, q_max).sum(axis=0) > 0)
            busy_tries = jnp.float32(0.0)
            cycles = jnp.float32(0.0)
            vac_sum = jnp.float32(0.0)
            nv_sum = jnp.float32(0.0)
            ts_arm = jnp.float32(0.0)
            for i in range(m_max):          # static unroll, m_max small
                w = woken[i]
                free_q = qmask & ~occ
                claimable = free_q & (backlog >= 1.0)
                qi = jnp.argmax(jnp.where(claimable, backlog, -1.0))
                do_attach = w & claimable.any()
                empty_claim = w & ~claimable.any() & free_q.any()
                eqi = jnp.argmax(free_q)
                blocked = w & ~free_q.any()

                claim_hot = do_attach & (q_idx == qi)
                claim_any = claim_hot | (empty_claim & (q_idx == eqi))
                vac_sum = vac_sum + (vac_timer * claim_any).sum()
                nv_sum = nv_sum + jnp.where(do_attach, backlog[qi], 0.0)
                vac_timer = jnp.where(claim_any, 0.0, vac_timer)
                cycles = cycles + (do_attach | empty_claim)
                busy_tries = busy_tries + blocked
                ts_arm = ts_arm + empty_claim
                attached = attached.at[i].set(
                    jnp.where(do_attach, qi, attached[i]))
                occ = occ | claim_hot
                sleep_rem = sleep_rem.at[i].add(
                    jnp.where(empty_claim, slp_s[i],
                              jnp.where(blocked, slp_l[i], 0.0)))

            serve = jnp.where(occ, jnp.minimum(backlog, mu * dt), 0.0)
            backlog = backlog - serve
            served = serve.sum()

            q_done = occ & (backlog <= 1e-6)
            att_q = jnp.clip(attached, 0, q_max - 1)
            t_done = (attached >= 0) & q_done[att_q]
            ts_arm = ts_arm + t_done.sum()
            sleep_rem = jnp.where(t_done, slp_s, sleep_rem)
            attached = jnp.where(t_done, -1, attached)
            occ = occ & ~q_done

            vac_timer = vac_timer + jnp.where(qmask & ~occ, dt, 0.0)
            lat_area = backlog.sum() * dt

            awake_step = n_wake * wake_cost_us + served / mu
            energy_step = (active_power_w * awake_step
                           + ts_arm * e_arm_s + busy_tries * e_arm_l)
            out = (offered, dropped, served, n_wake, busy_tries, cycles,
                   vac_sum, nv_sum, adm.sum(), lat_area, ts_arm,
                   energy_step)
            return (sleep_rem, attached, backlog, vac_timer, arr_res,
                    stall_end), out

        def fleet_step(carry, t):
            prev = carry
            (f_sleep, f_att, f_back, f_vac, f_res, f_stall, stale_b,
             S) = carry
            now = t.astype(jnp.float32) * dt
            live = now < duration
            if n_seg > 0:
                si = jnp.clip(
                    jnp.searchsorted(sched_edges, now, side="right") - 1,
                    0, n_seg - 1)
                scale_t = sched_scales[si]
            else:
                scale_t = jnp.float32(1.0)

            # 1. load balancer: split the fleet stream across hosts
            if lb_code == 2:
                # least-loaded on a stale snapshot, refreshed every
                # stale_every_slots (the lag IS the policy's weakness)
                refresh = (t % stale_every_slots) == 0
                stale_b = jnp.where(refresh, f_back.sum(axis=1), stale_b)
                shares = jax.nn.softmax(-stale_b / lb_softness_pkts)
            else:
                shares = w_static
            lam_h = lam * shares                       # (H,) mpps

            new_carry, outs = jax.vmap(
                host_step, in_axes=(0, None, None, 0, 0, 0, 0, 0, 0, 0)
            )(keys, t, scale_t, lam_h, f_sleep, f_att, f_back, f_vac,
              f_res, f_stall)
            (f_sleep, f_att, f_back, f_vac, f_res, f_stall) = new_carry
            (offered_h, dropped_h, served_h, n_wake_h, busy_h, cycles_h,
             vac_h, nv_h, adm_h, lat_area_h, ts_arm_h,
             energy_h) = outs
            back_tot = f_back.sum(axis=1)              # (H,) packets

            # 2. topology: admitted packets pay rack cost; far packets
            # also queue on the shared bottleneck link (M/M/1-style
            # wait at the CURRENT far-rack arrival rate, clamped)
            if topo_on:
                topo_delay_us = rack_cost_us
                if link_rate_mpps > 0.0 and far_count > 0:
                    far_rate = jnp.where(far_mask, adm_h, 0.0).sum() / dt
                    gap = jnp.maximum(
                        link_rate_mpps - far_rate,
                        (1.0 - _LINK_UTIL_CLAMP) * link_rate_mpps)
                    topo_delay_us = topo_delay_us + far_mask / gap
                topo_area_h = adm_h * topo_delay_us
            else:
                topo_area_h = jnp.zeros((n_hosts,))

            # 3. hedging (fluid): the share of this slot's admissions
            # predicted to miss the deadline (drain-time proxy
            # backlog/mu vs D, smooth sigmoid gate) is duplicated onto
            # the least-loaded OTHER host.  hedge_d <= 0 disables and
            # leaves the backlog bit-identical.
            hedge_on = (hedge_d > 0.0).astype(jnp.float32)
            drain_us = back_tot / mu
            gate = jax.nn.sigmoid((drain_us - hedge_d)
                                  / (0.25 * hedge_d + 1e-6))
            dup_h = adm_h * gate * hedge_on            # (H,) duplicates
            b1 = jnp.argmin(back_tot)
            b2 = jnp.argmin(jnp.where(h_idx == b1, jnp.inf, back_tot))
            partner = jnp.where(h_idx == b1, b2, b1)   # (H,)
            dup_per_q = dup_h[:, None] * (qmask / nq)  # (H, q_max)
            inject = jnp.zeros((n_hosts, q_max)).at[partner].add(dup_per_q)
            inj_room = jnp.maximum(capacity - f_back, 0.0)
            f_back = f_back + jnp.minimum(inject, inj_room)

            S = _FleetSlotStats(
                offered=S.offered + offered_h,
                dropped=S.dropped + dropped_h,
                serviced=S.serviced + served_h,
                wakeups=S.wakeups + n_wake_h,
                busy_tries=S.busy_tries + busy_h,
                cycles=S.cycles + cycles_h,
                awake_us=S.awake_us + n_wake_h * wake_cost_us
                         + served_h / mu,
                lat_area=S.lat_area + lat_area_h,
                vac_sum=S.vac_sum + vac_h,
                nv_sum=S.nv_sum + nv_h,
                ts_arms=S.ts_arms + ts_arm_h,
                energy_uj=S.energy_uj + energy_h,
                topo_area=S.topo_area + topo_area_h,
                hedge_dup=S.hedge_dup + dup_h,
            )
            nxt = (f_sleep, f_att, f_back, f_vac, f_res, f_stall,
                   stale_b, S)
            # steps past this point's duration hold the carry — the
            # bucketed scan length pads with no-ops, live steps stay
            # bit-identical to the unpadded scan
            gated = jax.tree_util.tree_map(
                lambda new, old: jnp.where(live, new, old), nxt, prev)
            return gated, None

        zh = jnp.zeros((n_hosts,), jnp.float32)
        if stepping == "fixed":
            init = (sleep0_h,
                    jnp.full((n_hosts, m_max), -1, jnp.int32),
                    jnp.zeros((n_hosts, q_max), jnp.float32),
                    jnp.zeros((n_hosts, q_max), jnp.float32),
                    jnp.zeros((n_hosts, q_max), jnp.float32),
                    jnp.full((n_hosts,), -1.0, jnp.float32),
                    zh,                          # stale LB snapshot
                    _FleetSlotStats(zh, zh, zh, zh, zh, zh, zh, zh, zh,
                                    zh, zh, zh, zh, zh))
            (*_, S), _ = jax.lax.scan(
                fleet_step, init, jnp.arange(n_slots, dtype=jnp.int32))
            n_live = jnp.minimum(jnp.ceil(duration / dt),
                                 jnp.float32(n_slots))
            return S, n_live * dt, n_live, jnp.zeros_like(duration)

        # ---- adaptive (event-jump): one shared variable dt per point —
        # the per-host boundary structure reduced with a fleet-wide min,
        # so all hosts advance in lock-step through the LB coupling
        floor_us = slot_us
        stale_us = float(stale_every_slots) * slot_us

        def fleet_step_a(carry, t):
            prev = carry
            (a_sleep, a_att, a_back, a_vac, a_res, a_stall, a_next,
             lb_snap, next_ref, rem_t, nst, fst, SA) = carry
            now = duration - rem_t
            live = rem_t > 0.0

            if n_seg > 0:
                si = jnp.clip(
                    jnp.searchsorted(sched_edges, now, side="right") - 1,
                    0, n_seg - 1)
                scale_t = sched_scales[si]
                nxt_si = jnp.clip(si + 1, 0, n_seg - 1)
                seg_dt = jnp.where(si + 1 < n_seg,
                                   sched_edges[nxt_si] - now, jnp.inf)
            else:
                scale_t = jnp.float32(1.0)
                seg_dt = jnp.float32(jnp.inf)

            # LB stale refresh is a jump boundary: the snapshot updates
            # exactly on its lb_stale_us lattice (missed lattice points
            # after a forced jump are skipped, matching the fixed
            # kernel's modulo refresh)
            if lb_code == 2:
                fire_ref = now + _WAKE_EPS_US >= next_ref
                lb_snap = jnp.where(fire_ref, a_back.sum(axis=1), lb_snap)
                next_ref = jnp.where(
                    fire_ref,
                    (jnp.floor(now / stale_us + _WAKE_EPS_US) + 1.0)
                    * stale_us,
                    next_ref)
                shares = jax.nn.softmax(-lb_snap / lb_softness_pkts)
                ref_dt = next_ref - now
            else:
                shares = w_static
                ref_dt = jnp.float32(jnp.inf)
            lam_h = lam * shares                       # (H,) mpps
            lam_hq = (lam_h * scale_t)[:, None] \
                * jnp.where(qmask, 1.0 / nq, 0.0)[None, :]

            # ---- the jump: nearest boundary across the whole fleet
            sleeping_h = tmask[None, :] & (a_att < 0)
            occ_h = (jax.nn.one_hot(a_att, q_max).sum(axis=1) > 0)
            wake_dt = jnp.min(jnp.where(
                sleeping_h, jnp.maximum(a_sleep, 0.0), jnp.inf))
            net_out = jnp.where(occ_h, mu - lam_hq, 0.0)
            drain_hq = jnp.where(
                occ_h & (net_out > _RATE_EPS),
                jnp.maximum(a_back, 0.0)
                / jnp.maximum(net_out, _RATE_EPS), jnp.inf)
            drain_dt = jnp.min(drain_hq)
            net_in = lam_hq - jnp.where(occ_h, mu, 0.0)
            fill_dt = jnp.min(jnp.where(
                qmask[None, :] & (net_in > _RATE_EPS)
                & (a_back < capacity - _FILL_SLACK_PKTS),
                (capacity - a_back) / jnp.maximum(net_in, _RATE_EPS),
                jnp.inf))
            stall_dt = jnp.min(a_next) - now
            dt_b = jnp.minimum(
                jnp.minimum(jnp.minimum(wake_dt, drain_dt),
                            jnp.minimum(fill_dt, seg_dt)),
                jnp.minimum(jnp.minimum(ref_dt, stall_dt), rem_t))
            # completion guard — tail-reserve pacing only, see
            # batched_adaptive (same scheme, n_slots is the budget here)
            steps_left = jnp.float32(n_slots) - t.astype(jnp.float32)
            in_tail = steps_left <= jnp.float32(max(n_slots // 8, 2))
            pace = jnp.where(in_tail, rem_t / steps_left, 0.0)
            # floor respects wakes and drain-outs fleet-wide (see
            # batched_adaptive: stepping past either stretches busy
            # periods / coalesces claims and biases the wake rate down
            # through the T_L parking feedback)
            floor_eff = jnp.minimum(
                floor_us,
                jnp.maximum(jnp.minimum(wake_dt, drain_dt),
                            _WAKE_EPS_US))
            dtv = jnp.minimum(
                jnp.maximum(dt_b, jnp.maximum(floor_eff, pace)), rem_t)
            forced = (dtv > jnp.maximum(dt_b, floor_us) + _WAKE_EPS_US) \
                & live
            t_new = now + dtv

            def host_step_a(key_h, lam_q, sleep_rem, attached, backlog,
                            vac_timer, arr_res, stall_end, next_stall):
                """One host, one macro-slot — the closed-form aggregates
                of ``batched_adaptive`` at the shared fleet ``dtv``."""
                kt_step = jax.random.fold_in(key_h, t)
                if tail_prob > 0.0:
                    kt_step, kp, ku = jax.random.split(kt_step, 3)
                if intf_prob > 0.0:
                    kt_step, kip, kie = jax.random.split(kt_step, 3)
                if stall_rate > 0.0:
                    kt_step, kse, ksg, ksu = jax.random.split(kt_step, 4)
                zs = jax.random.normal(kt_step, (q_max + m_max,))

                sleeping = tmask & (attached < 0)
                occ = (jax.nn.one_hot(attached, q_max).sum(axis=0) > 0)

                # drain-boundary steps are deterministic per queue: a
                # noisy draw there is one-sided (positive residual
                # extends the busy period, negative cannot shorten it)
                # — see batched_adaptive for the full argument
                net_out_l = jnp.where(occ, mu - lam_q, 0.0)
                drain_ql = jnp.where(
                    occ & (net_out_l > _RATE_EPS),
                    jnp.maximum(backlog, 0.0)
                    / jnp.maximum(net_out_l, _RATE_EPS), jnp.inf)
                drain_now = occ & (drain_ql <= dtv + _WAKE_EPS_US)
                mu_a = lam_q * dtv
                z_q = jnp.where(drain_now, 0.0, zs[:q_max])
                raw = arr_res + mu_a + jnp.sqrt(mu_a) * z_q
                a = jnp.maximum(raw, 0.0)
                arr_res = jnp.minimum(raw, 0.0)
                room = jnp.maximum(capacity - backlog, 0.0) \
                    + jnp.where(occ, mu * dtv, 0.0)
                adm = jnp.minimum(a, room)
                offered = a.sum()
                dropped = (a - adm).sum()

                serve = jnp.where(
                    occ, jnp.minimum(backlog + adm, mu * dtv), 0.0)
                b_new = jnp.minimum(
                    jnp.maximum(backlog + adm - serve, 0.0), capacity)
                served = serve.sum()

                lat_area = 0.5 * (backlog.sum() + b_new.sum()) * dtv
                vac_timer = vac_timer + jnp.where(qmask & ~occ, dtv, 0.0)
                backlog = b_new

                if stall_rate > 0.0:
                    fire = (next_stall <= t_new) & live
                    w_end = next_stall + stall_mean_us \
                        * jax.random.exponential(kse, ())
                    stall_end = jnp.where(
                        fire, jnp.maximum(stall_end, w_end), stall_end)
                    gap = jax.random.exponential(ksg, ()) / stall_rate
                    next_stall = jnp.where(fire, next_stall + gap,
                                           next_stall)

                over = jnp.full((m_max,), base_us)
                if sigma_us > 0.0:
                    over = over + sigma_us * jnp.abs(zs[q_max:])
                if tail_prob > 0.0:
                    hit = jax.random.uniform(kp, (m_max,)) < tail_prob
                    over = over + hit * tail_mean_us \
                        * jax.random.exponential(ku, (m_max,))
                if intf_prob > 0.0:
                    ihit = jax.random.uniform(kip, (m_max,)) < intf_prob
                    over = over + ihit * intf_mean_us \
                        * jax.random.exponential(kie, (m_max,))
                slp_s = t_s * (1.0 + slope) + over
                slp_l = t_l * (1.0 + slope) + over

                sleep_rem = jnp.where(sleeping, sleep_rem - dtv,
                                      sleep_rem)
                woken = sleeping & (sleep_rem <= _WAKE_EPS_US) & live
                if stall_rate > 0.0:
                    push = woken & (t_new < stall_end)
                    woken = woken & ~push
                    sleep_rem = jnp.where(
                        push,
                        stall_end - t_new
                        + jax.random.uniform(ksu, (m_max,)),
                        sleep_rem)
                n_wake = woken.sum().astype(jnp.float32)

                # queues drained out by the boundary release their
                # thread BEFORE boundary wakes classify — drain-out
                # precedes the boundary in true time, so a thread
                # waking at the boundary must see the queue free
                # (release-after-claim would park it on T_L)
                q_done = occ & (backlog <= 1e-6)
                att_q = jnp.clip(attached, 0, q_max - 1)
                t_done = (attached >= 0) & q_done[att_q]
                sleep_rem = jnp.where(t_done, slp_s, sleep_rem)
                attached = jnp.where(t_done, -1, attached)
                occ = occ & ~q_done

                busy_tries = jnp.float32(0.0)
                cycles = jnp.float32(0.0)
                vac_sum = jnp.float32(0.0)
                nv_sum = jnp.float32(0.0)
                ts_arm = t_done.sum().astype(jnp.float32)
                for i in range(m_max):      # static unroll, m_max small
                    w = woken[i]
                    free_q = qmask & ~occ
                    claimable = free_q & (backlog >= 1.0)
                    qi = jnp.argmax(jnp.where(claimable, backlog, -1.0))
                    do_attach = w & claimable.any()
                    empty_claim = w & ~claimable.any() & free_q.any()
                    eqi = jnp.argmax(free_q)
                    blocked = w & ~free_q.any()

                    claim_hot = do_attach & (q_idx == qi)
                    claim_any = claim_hot | (empty_claim & (q_idx == eqi))
                    vac_sum = vac_sum + (vac_timer * claim_any).sum()
                    nv_sum = nv_sum + jnp.where(do_attach, backlog[qi],
                                                0.0)
                    vac_timer = jnp.where(claim_any, 0.0, vac_timer)
                    cycles = cycles + (do_attach | empty_claim)
                    busy_tries = busy_tries + blocked
                    ts_arm = ts_arm + empty_claim
                    attached = attached.at[i].set(
                        jnp.where(do_attach, qi, attached[i]))
                    occ = occ | claim_hot
                    sleep_rem = sleep_rem.at[i].add(
                        jnp.where(empty_claim, slp_s[i],
                                  jnp.where(blocked, slp_l[i], 0.0)))

                awake_step = n_wake * wake_cost_us + served / mu
                energy_step = (active_power_w * awake_step
                               + ts_arm * e_arm_s
                               + busy_tries * e_arm_l)
                out = (offered, dropped, served, n_wake, busy_tries,
                       cycles, vac_sum, nv_sum, adm.sum(), lat_area,
                       ts_arm, energy_step)
                return (sleep_rem, attached, backlog, vac_timer, arr_res,
                        stall_end, next_stall), out

            new_carry, outs = jax.vmap(host_step_a)(
                keys, lam_hq, a_sleep, a_att, a_back, a_vac, a_res,
                a_stall, a_next)
            (a_sleep, a_att, a_back, a_vac, a_res, a_stall,
             a_next) = new_carry
            (offered_h, dropped_h, served_h, n_wake_h, busy_h, cycles_h,
             vac_h, nv_h, adm_h, lat_area_h, ts_arm_h,
             energy_h) = outs
            back_tot = a_back.sum(axis=1)

            # topology — the macro-slot's admissions pay rack + link
            # cost at the slot's average far-rack arrival rate
            if topo_on:
                topo_delay_us = rack_cost_us
                if link_rate_mpps > 0.0 and far_count > 0:
                    far_rate = jnp.where(far_mask, adm_h, 0.0).sum() / dtv
                    gap = jnp.maximum(
                        link_rate_mpps - far_rate,
                        (1.0 - _LINK_UTIL_CLAMP) * link_rate_mpps)
                    topo_delay_us = topo_delay_us + far_mask / gap
                topo_area_h = adm_h * topo_delay_us
            else:
                topo_area_h = jnp.zeros((n_hosts,))

            # hedging (fluid) — per macro-slot, same gate as fixed
            hedge_on = (hedge_d > 0.0).astype(jnp.float32)
            drain_us = back_tot / mu
            gate = jax.nn.sigmoid((drain_us - hedge_d)
                                  / (0.25 * hedge_d + 1e-6))
            dup_h = adm_h * gate * hedge_on
            b1 = jnp.argmin(back_tot)
            b2 = jnp.argmin(jnp.where(h_idx == b1, jnp.inf, back_tot))
            partner = jnp.where(h_idx == b1, b2, b1)
            dup_per_q = dup_h[:, None] * (qmask / nq)
            inject = jnp.zeros((n_hosts, q_max)).at[partner].add(
                dup_per_q)
            inj_room = jnp.maximum(capacity - a_back, 0.0)
            a_back = a_back + jnp.minimum(inject, inj_room)

            SA = _FleetSlotStats(
                offered=SA.offered + offered_h,
                dropped=SA.dropped + dropped_h,
                serviced=SA.serviced + served_h,
                wakeups=SA.wakeups + n_wake_h,
                busy_tries=SA.busy_tries + busy_h,
                cycles=SA.cycles + cycles_h,
                awake_us=SA.awake_us + n_wake_h * wake_cost_us
                         + served_h / mu,
                lat_area=SA.lat_area + lat_area_h,
                vac_sum=SA.vac_sum + vac_h,
                nv_sum=SA.nv_sum + nv_h,
                ts_arms=SA.ts_arms + ts_arm_h,
                energy_uj=SA.energy_uj + energy_h,
                topo_area=SA.topo_area + topo_area_h,
                hedge_dup=SA.hedge_dup + dup_h,
            )
            rem_t = rem_t - dtv
            nst = nst + 1.0
            fst = fst + forced.astype(jnp.float32)
            nxt = (a_sleep, a_att, a_back, a_vac, a_res, a_stall, a_next,
                   lb_snap, next_ref, rem_t, nst, fst, SA)
            gated = jax.tree_util.tree_map(
                lambda new, old: jnp.where(live, new, old), nxt, prev)
            return gated, None

        z0 = jnp.float32(0.0)
        init_a = (sleep0_h,
                  jnp.full((n_hosts, m_max), -1, jnp.int32),
                  jnp.zeros((n_hosts, q_max), jnp.float32),
                  jnp.zeros((n_hosts, q_max), jnp.float32),
                  jnp.zeros((n_hosts, q_max), jnp.float32),
                  jnp.full((n_hosts,), -1.0, jnp.float32),
                  next0_h,
                  zh,                        # stale LB snapshot
                  z0,                        # next_ref: refresh at t=0
                  jnp.asarray(duration, jnp.float32),
                  z0, z0,                    # n_steps, forced_steps
                  _FleetSlotStats(zh, zh, zh, zh, zh, zh, zh, zh, zh,
                                  zh, zh, zh, zh, zh))
        (*_, rem_f, nst, fst, SA), _ = jax.lax.scan(
            fleet_step_a, init_a, jnp.arange(n_slots, dtype=jnp.int32))
        return SA, duration - rem_f, nst, fst

    inner = jax.vmap(one_fleet)
    if n_shards > 1:
        from jax.sharding import Mesh, PartitionSpec

        from ..compat import shard_map

        mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("pts",))
        spec = PartitionSpec("pts")
        inner = shard_map(inner, mesh=mesh, in_specs=(spec,) * 11,
                          out_specs=spec)
    return jax.jit(inner)


_compiled_fleet_sweep = CompileCache(_build_fleet_sweep, maxsize=64,
                                     name="fleet._compiled_fleet_sweep")


@dataclass
class FleetStats:
    """Per-(point, host) results of one fleet sweep.

    All arrays are float64 of shape ``(len(fgrid), n_hosts)``.  Fleet-
    level metrics reduce over the host axis; tail quantiles come from
    the hedged-tail closed form on the per-host measured means (the
    slot engine keeps no samples).  ``reshaped(name)`` appends the host
    axis to the grid's logical shape.
    """

    fgrid: FleetGrid
    cfg: SimRunConfig
    slot_us: float
    backend: str = "vmap"           # "vmap" | "shard_map(n)"
    offered: np.ndarray = field(default_factory=lambda: np.empty(0))
    dropped: np.ndarray = field(default_factory=lambda: np.empty(0))
    serviced: np.ndarray = field(default_factory=lambda: np.empty(0))
    wakeups: np.ndarray = field(default_factory=lambda: np.empty(0))
    busy_tries: np.ndarray = field(default_factory=lambda: np.empty(0))
    cycles: np.ndarray = field(default_factory=lambda: np.empty(0))
    awake_us: np.ndarray = field(default_factory=lambda: np.empty(0))
    lat_area: np.ndarray = field(default_factory=lambda: np.empty(0))
    vac_sum: np.ndarray = field(default_factory=lambda: np.empty(0))
    nv_sum: np.ndarray = field(default_factory=lambda: np.empty(0))
    ts_arms: np.ndarray = field(default_factory=lambda: np.empty(0))
    energy_uj: np.ndarray = field(default_factory=lambda: np.empty(0))
    topo_area: np.ndarray = field(default_factory=lambda: np.empty(0))
    hedge_dup: np.ndarray = field(default_factory=lambda: np.empty(0))
    # stepping diagnostics (see BatchStats): which kernel ran, its
    # compiled scan length, and per-POINT live/forced step counts and
    # exact simulated time (host axis shares one dt, so these are (P,))
    stepping: str = "fixed"
    scan_len: int = 0
    n_steps: np.ndarray = field(default_factory=lambda: np.empty(0))
    forced_steps: np.ndarray = field(default_factory=lambda: np.empty(0))
    sim_time_us: np.ndarray = field(default_factory=lambda: np.empty(0))

    # -- derived ---------------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return self.fgrid.fleet.n_hosts

    @property
    def host_mean_latency_us(self) -> np.ndarray:
        """(P, H) end-to-end mean sojourn per host: Little's-law host
        component plus the network delay charged to its packets."""
        return ((self.lat_area + self.topo_area)
                / np.maximum(self.serviced, 1.0))

    @property
    def host_weight(self) -> np.ndarray:
        """(P, H) served-traffic share per host (tail-mixture weights)."""
        tot = np.maximum(self.serviced.sum(axis=1, keepdims=True), 1.0)
        return self.serviced / tot

    @property
    def host_cpu_fraction(self) -> np.ndarray:
        return self.awake_us / self.cfg.duration_us

    @property
    def total_cpu_cores(self) -> np.ndarray:
        """(P,) cores burned by the whole fleet (the verdict metric —
        a busy-poll fleet pins n_hosts cores)."""
        return self.awake_us.sum(axis=1) / self.cfg.duration_us

    @property
    def host_power_w(self) -> np.ndarray:
        """(P, H) mean package power per host."""
        return self.energy_uj / self.cfg.duration_us

    @property
    def total_energy_uj(self) -> np.ndarray:
        """(P,) cluster energy (the power half of the verdict metric)."""
        return self.energy_uj.sum(axis=1)

    @property
    def energy_per_packet_nj(self) -> np.ndarray:
        """(P,) cluster energy per served packet."""
        return (1e3 * self.energy_uj.sum(axis=1)
                / np.maximum(self.serviced.sum(axis=1), 1.0))

    @property
    def mean_latency_us(self) -> np.ndarray:
        """(P,) fleet mean end-to-end sojourn (served-weighted)."""
        return ((self.lat_area + self.topo_area).sum(axis=1)
                / np.maximum(self.serviced.sum(axis=1), 1.0))

    @property
    def loss_fraction(self) -> np.ndarray:
        return (self.dropped.sum(axis=1)
                / np.maximum(self.offered.sum(axis=1), 1.0))

    @property
    def offered_total(self) -> np.ndarray:
        return self.offered.sum(axis=1)

    @property
    def offered_with_hedges(self) -> np.ndarray:
        """(P,) offered load including hedge duplicates — strictly
        increasing as the hedge deadline tightens (the cost side of the
        hedging sanity test)."""
        return (self.offered + self.hedge_dup).sum(axis=1)

    @property
    def rho(self) -> np.ndarray:
        """(P,) per-host utilization at uniform split."""
        return (self.fgrid.grid.rate_mpps
                / (self.cfg.service_rate_mpps * self.n_hosts))

    def quantile(self, i: int, q: float = 0.999) -> float:
        """Fleet latency quantile of point ``i`` from the hedged-tail
        closed form on the measured per-host means, with the config's
        correlated-stall environment as the tail component."""
        tail_prob = min(self.cfg.stall_rate_per_us
                        * self.cfg.stall_mean_us, 0.5)
        return hedged_latency_quantile(
            q, self.host_mean_latency_us[i], self.host_weight[i],
            hedge_deadline_us=float(self.fgrid.hedge_deadline_us[i]),
            tail_prob=tail_prob,
            tail_scale_us=self.cfg.stall_mean_us)

    @property
    def p999_latency_us(self) -> np.ndarray:
        return np.asarray([self.quantile(i, 0.999)
                           for i in range(len(self))])

    def reshaped(self, name: str) -> np.ndarray:
        val = np.asarray(getattr(self, name))
        shape = self.fgrid.shape or (len(self),)
        if val.ndim == 2:
            return val.reshape(shape + (self.n_hosts,))
        return val.reshape(shape)

    # -- RunStats rollups ------------------------------------------------------
    def host_run_stats(self, i: int) -> list[RunStats]:
        """One ``RunStats`` per host for point ``i`` (host-level view;
        latency override mean includes the host's network share)."""
        p = self.fgrid.point(i)
        out = []
        for h in range(self.n_hosts):
            mean = float(self.host_mean_latency_us[i, h])
            cap = self.cfg.queue_capacity * max(int(p["n_queues"]), 1)
            out.append(RunStats(
                backend="fleet",
                policy=(f"sleepwake(t_s={p['t_s_us']:g},"
                        f"t_l={p['t_l_us']:g},m={p['m']})"),
                workload=(f"fleet-share({p['rate_mpps']:g}mpps"
                          f"/{self.n_hosts})"),
                wakeups=int(self.wakeups[i, h]),
                cycles=int(self.cycles[i, h]),
                busy_tries=int(self.busy_tries[i, h]),
                items=int(self.serviced[i, h]),
                offered=int(self.offered[i, h]),
                dropped=int(self.dropped[i, h]),
                awake_ns=round(self.awake_us[i, h] * 1e3),
                started_ns=0,
                stopped_ns=round(self.cfg.duration_us * 1e3),
                latency_us=Reservoir(4, seed=int(p["seed"]) + h),
                latency_area_us=float(self.lat_area[i, h]
                                      + self.topo_area[i, h]),
                energy_uj=float(self.energy_uj[i, h]),
                latency_override={
                    "mean": mean,
                    "p99": mean * 3.0,
                    "worst": float(cap / self.cfg.service_rate_mpps
                                   + p["t_l_us"]),
                },
            ))
        return out

    def to_run_stats(self, i: int) -> RunStats:
        """Cluster rollup of point ``i``: n-way ``RunStats.merge_all``
        over the per-host stats, with the fleet-level hedged-tail p99
        replacing the per-host heuristic."""
        hosts = self.host_run_stats(i)
        head = hosts[0]
        head.merge_all(hosts[1:])
        head.latency_override["p99"] = self.quantile(i, 0.99)
        return head

    def __len__(self) -> int:
        return len(self.fgrid)


def simulate_fleet(fgrid: FleetGrid, cfg: SimRunConfig | None = None, *,
                   slot_us: float = 0.5, shard: bool | None = None,
                   stepping: str = "fixed") -> FleetStats:
    """Simulate every fleet operating point — ONE jit-compiled call over
    the whole (point x host) batch; no Python loop over hosts.

    ``shard=None`` (default) splits the point axis across local devices
    via ``shard_map`` whenever more than one device is visible and falls
    back to pure vmap on one device; ``True``/``False`` force the
    respective path.  Points are padded to a multiple of the device
    count and the padding is sliced off the results.

    ``stepping="adaptive"`` switches to the event-jump kernel: hosts
    advance in lock-step by a shared variable ``dt`` (nearest boundary
    across the fleet, incl. the LB stale-refresh lattice).  The step
    budget sums per-host boundary estimates — load-proportionality
    shrinks as ``n_hosts`` grows (a 1000-host fleet has a wake
    somewhere almost every slot), so the budget is clamped at the
    fixed scan length and adaptive never scans more than fixed.
    """
    if stepping not in ("fixed", "adaptive"):
        raise ValueError(
            f"stepping must be 'fixed' or 'adaptive', got {stepping!r}")
    cfg = cfg or SimRunConfig()
    validate_batched_config(cfg)
    fleet = fgrid.fleet.validate()
    n_pts = len(fgrid)
    m_max = int(fgrid.grid.m.max())
    q_max = int(fgrid.grid.n_queues.max())
    n_seg, sched_edges, sched_scales = _schedule_rows(fgrid.grid, cfg)
    stale_every_slots = max(int(round(fleet.lb_stale_us / slot_us)), 1)

    n_slots_true = max(int(math.ceil(cfg.duration_us / slot_us)), 1)
    if stepping == "adaptive":
        est = estimate_adaptive_steps(fgrid.grid, cfg, slot_us, 0)
        if fleet.lb == "least-loaded":
            est += int(math.ceil(
                cfg.duration_us / (stale_every_slots * slot_us)))
        n_slots = bucket_steps(
            min(fleet.n_hosts * est + 64, n_slots_true))
    else:
        n_slots = bucket_steps(n_slots_true)

    n_dev = len(jax.devices())
    use_shard = (n_dev > 1) if shard is None else bool(shard)
    n_shards = max(min(n_dev, n_pts), 1) if use_shard else 1

    sm = cfg.sleep_model
    lb_weights = (tuple(float(w) for w in fleet.shares())
                  if fleet.lb == "weighted" else ())
    fn = _compiled_fleet_sweep(
        n_slots, float(slot_us), m_max, q_max, int(fleet.n_hosts),
        float(cfg.service_rate_mpps), float(cfg.queue_capacity),
        float(cfg.wake_cost_us),
        (float(sm.base_us), float(sm.slope), float(sm.sigma_us),
         float(sm.tail_prob), float(sm.tail_mean_us)),
        (float(cfg.interference_prob), float(cfg.interference_mean_us),
         float(cfg.stall_rate_per_us), float(cfg.stall_mean_us)),
        cfg.energy_model.params(),
        n_seg, _LB_CODE[fleet.lb], lb_weights,
        float(fleet.lb_softness_pkts), stale_every_slots,
        fleet.far_hosts(), float(fleet.near_cost_us),
        float(fleet.far_cost_us), float(fleet.link_rate_mpps),
        n_shards, stepping)

    pad = (-n_pts) % n_shards
    def row(a, dtype):
        arr = np.asarray(a)
        if pad:
            arr = np.concatenate([arr, np.repeat(arr[:1], pad, axis=0)])
        return jnp.asarray(arr, dtype)

    g = fgrid.grid
    seed64 = np.asarray(g.seed, dtype=np.uint64)
    out, simt, nst, fst = fn(
        row(g.t_s_us, jnp.float32), row(g.t_l_us, jnp.float32),
        row(g.m, jnp.int32), row(g.n_queues, jnp.int32),
        row(g.rate_mpps, jnp.float32),
        row((seed64 & np.uint64(0xFFFFFFFF)).astype(np.uint32),
            jnp.uint32),
        row((seed64 >> np.uint64(32)).astype(np.uint32), jnp.uint32),
        row(fgrid.hedge_deadline_us, jnp.float32),
        row(np.full(n_pts, cfg.duration_us), jnp.float32),
        row(sched_edges, jnp.float32),
        row(sched_scales, jnp.float32))
    vals = {k: np.asarray(v, dtype=np.float64)[:n_pts]
            for k, v in out._asdict().items()}
    return FleetStats(
        fgrid=fgrid, cfg=cfg, slot_us=float(slot_us),
        backend=(f"shard_map({n_shards})" if n_shards > 1 else "vmap"),
        stepping=stepping, scan_len=n_slots,
        n_steps=np.asarray(nst, dtype=np.float64)[:n_pts],
        forced_steps=np.asarray(fst, dtype=np.float64)[:n_pts],
        sim_time_us=np.asarray(simt, dtype=np.float64)[:n_pts],
        **vals)
