"""Real-thread execution backend: any policy × any workload, OS threads.

One generic ``Runtime`` runs poller threads against one or more shared
bounded queues, executing the paper's Listing-2 loop shape:

    while running:
        lock_taken = False
        for q in my_queues:                  # from the Assignment
            if not trylock(q):   continue
            lock_taken = True
            while work:  process(...)                        # busy period
            policy.on_cycle_end(busy_us, vacation_us)
            unlock(q)
        sleep(policy.on_wake(ctx))          # 0 => spin (busy-poll policy)

Which queues a thread sweeps — and whether each queue gets its own
policy clone — is decided by an ``Assignment``
(``repro.runtime.assignment``): ``shared`` (default, all threads sweep
all queues), ``dedicated`` (one poller set + controller per queue), or
``stealing`` (home queue first, then the longest backlog).

What used to be three hand-rolled loops (``MetronomePollers``,
``BusyPollLoop``, the serving servers) is now this one loop with the
policy injected; ``repro.core.pollers`` and ``repro.serving.server``
keep their old names as thin shims over it.

CPU accounting uses per-thread CPU time (time.thread_time_ns around the
loop body) — the userspace analogue of the paper's getrusage()
methodology, immune to descheduling on shared hosts.  Spinning policies
are pinned at a full core in the report (their defining cost, and what
the paper charges DPDK).

``Runtime.run(workload, ...)`` additionally replays a ``Workload``
against the queues in real time from a feeder thread, returning the same
``RunStats`` the simulator produces — the sim/real parity surface.
"""

from __future__ import annotations

import threading
import time
import warnings
from typing import Callable

import numpy as np

from repro.core.hr_sleep import hr_sleep

from .assignment import SharedAssignment, ThreadSlot
from .dispatch import RoundRobinDispatch
from .policy import WakeContext
from .queues import BoundedQueue
from .simcore import DEFAULT_ENERGY_MODEL
from .stats import QueueStats, Reservoir, RunStats

__all__ = ["Runtime"]


class Runtime:
    def __init__(
        self,
        queues: list[BoundedQueue],
        process: Callable[[list], None],
        policy,
        *,
        burst_size: int = 32,
        sleep_fn: Callable[[int], None] = hr_sleep,
        latency_sample_every: int = 16,
        idle_work: Callable[[], bool] | None = None,
        latency_reservoir: int = 65_536,
        assignment=None,
        app_load=None,
        energy_model=DEFAULT_ENERGY_MODEL,
    ):
        """``process`` consumes a burst of retrieved items; ``idle_work``
        (optional) is polled during the busy period after each burst and
        returns whether it still made progress — the hook that lets a
        serving engine keep its decode loop inside the busy period.
        ``assignment`` maps threads to queues (default: every thread
        sweeps every queue, the paper's shared-queue shape).
        ``app_load`` (an ``repro.runtime.apps.AppLoad``) co-runs a
        competing application on the same host for the lifetime of the
        run — the paper's Sec 5.6 CPU-sharing scenario: its threads
        start and stop with the pollers, and the work it completed and
        CPU it burned land in ``RunStats.app_ops`` /
        ``RunStats.app_cpu_ns`` (the application-throughput side of the
        sharing trade-off).  ``energy_model`` (an
        ``repro.runtime.simcore.EnergyModel``) prices the run's counters
        into the model-based ``RunStats.energy_uj`` estimate at
        ``stop()`` — real threads have no wattmeter, so the same model
        the simulators account exactly is applied to the measured
        wake/awake/busy-try counters."""
        self.queues = queues
        self.process = process
        self.policy = policy
        self.assignment = assignment or SharedAssignment()
        self.burst_size = burst_size
        self.sleep_fn = sleep_fn
        self.energy_model = energy_model
        self.idle_work = idle_work
        self.app_load = app_load
        self._app_threads: list[threading.Thread] = []
        self.stats = RunStats(backend="threads",
                              policy=getattr(policy, "name", ""))
        self._lat_cap = latency_reservoir
        self._stats_lock = threading.Lock()
        self._running = threading.Event()
        self._threads: list[threading.Thread] = []
        self._cycles_q = [0] * len(queues)
        self._lat_every = max(latency_sample_every, 1)

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> None:
        self._slots = self.assignment.slots(self.policy, len(self.queues))
        # reset each distinct policy once (shared slots alias one object;
        # dedicated slots carry per-queue clones)
        seen: set[int] = set()
        for s in self._slots:
            if id(s.policy) not in seen:
                seen.add(id(s.policy))
                s.policy.reset()
        # queue/lock counters are cumulative; snapshot so a restarted
        # Runtime reports only this run's arrivals and busy tries
        self._base_counts = [(q.offered, q.dropped, q.lock.busy_tries,
                              q.serviced) for q in self.queues]
        self._cycles_q = [0] * len(self.queues)
        now = time.monotonic_ns()
        for q in self.queues:
            # re-arm the vacation clock: it is stamped at queue
            # construction, and a Runtime started later would otherwise
            # report a bogus multi-second first vacation to the policy
            q.last_busy_end_ns = now
        self.stats = RunStats(backend="threads",
                              policy=getattr(self.policy, "name", ""),
                              started_ns=now,
                              latency_us=Reservoir(self._lat_cap))
        self._running.set()
        self._threads = [
            threading.Thread(target=self._run, args=(slot,),
                             name=f"runtime-{i}", daemon=True)
            for i, slot in enumerate(self._slots)
        ]
        for t in self._threads:
            t.start()
        if self.app_load is not None:
            self.app_load.reset()
            self._app_threads = [
                threading.Thread(target=self._run_app,
                                 name=f"app-{i}", daemon=True)
                for i in range(self.app_load.threads)
            ]
            for t in self._app_threads:
                t.start()

    def stop(self, timeout: float = 5.0) -> RunStats:
        self._running.clear()
        for t in self._threads:
            t.join(timeout)
        for t in self._app_threads:
            t.join(timeout)
        self._app_threads = []
        st = self.stats
        st.stopped_ns = time.monotonic_ns()
        base = getattr(self, "_base_counts",
                       [(0, 0, 0, 0)] * len(self.queues))
        cycles_q = getattr(self, "_cycles_q", [0] * len(self.queues))
        st.per_queue = [
            QueueStats(queue=i,
                       offered=q.offered - b[0],
                       dropped=q.dropped - b[1],
                       busy_tries=q.lock.busy_tries - b[2],
                       serviced=q.serviced - b[3],
                       cycles=cycles_q[i])
            for i, (q, b) in enumerate(zip(self.queues, base, strict=True))
        ]
        st.offered = sum(pq.offered for pq in st.per_queue)
        st.dropped = sum(pq.dropped for pq in st.per_queue)
        st.busy_tries = sum(pq.busy_tries for pq in st.per_queue)
        if getattr(self.policy, "spin", False):
            # By construction a spinning policy never sleeps: charge one
            # full core per thread (the paper's DPDK baseline accounting).
            st.awake_ns = st.duration_ns * max(len(self._threads), 1)
        st.energy_uj = self._estimate_energy_uj(st)
        return st

    def _estimate_energy_uj(self, st: RunStats) -> float:
        """Model-based energy from the run's counters (no wattmeter on
        real threads): a spinning policy burns flat active power at the
        DVFS busy frequency on every thread; a sleeping policy pays
        active power over measured CPU time plus one C-state arm charge
        per wake — T_L-priced for the busy-try share of wakes (the lock
        was taken, the policy demoted), T_S-priced for the rest.  The
        targets are the policy's *current* timeouts, so an adaptive
        run's estimate is priced at its converged operating point."""
        em = self.energy_model
        if em is None:
            return 0.0
        if getattr(self.policy, "spin", False):
            return float(em.active_energy_uj(st.duration_ns / 1e3,
                                             spin=True)
                         * max(len(self._threads), 1))
        pol = self.policy
        t_s_us = getattr(pol, "t_short_us", None)
        if t_s_us is None:
            t_s_us = getattr(pol, "period_us", 0.0)
        t_l_us = getattr(getattr(pol, "cfg", None), "t_long_us", t_s_us)
        tl_arms = min(st.busy_tries, st.wakeups)
        ts_arms = st.wakeups - tl_arms
        return float(em.active_power_w * st.awake_ns / 1e3
                     + ts_arms * em.arm_energy_uj(float(t_s_us))
                     + tl_arms * em.arm_energy_uj(float(t_l_us)))

    # -- the paper's loop, policy-parameterized ----------------------------------
    def _run(self, slot: ThreadSlot | None = None) -> None:
        if slot is None:        # direct callers (tests/shims) get default
            slot = ThreadSlot(self.policy, tuple(range(len(self.queues))))
        policy = slot.policy
        st = self.stats
        wake = 0
        while self._running.is_set():
            t_cpu0 = time.thread_time_ns()
            lock_taken = False
            items = 0
            # stats are buffered during the sweep and flushed under ONE
            # _stats_lock acquisition per wake, after every queue lock
            # is back: a queue owner never blocks on another lock
            # (TryLock discipline — analysis rule LOCK002), and stats
            # contention drops from per-cycle to per-wake
            lat_pending: list[float] = []
            cycles_pending: list[int] = []
            # sweep own queues first; with steal, keep visiting the longest
            # unvisited backlog until none remains — mirroring the
            # simulator's sweep so both backends run the same semantics
            targets = list(slot.queues)
            visited = set(targets)
            si = 0
            while si < len(targets):
                qi = targets[si]
                si += 1
                q = self.queues[qi]
                if q.lock.try_acquire():
                    lock_taken = True
                    try:
                        busy_start = time.monotonic_ns()
                        # vacation = unattended time up to lock acquisition
                        # (not wake: earlier queues in this sweep took time)
                        vacation_ns = busy_start - q.last_busy_end_ns
                        while True:
                            burst = q.poll(self.burst_size)
                            if burst:
                                items += len(burst)
                                if wake % self._lat_every == 0:
                                    now = time.monotonic_ns()
                                    lat_pending.extend(
                                        (now - ts) / 1e3
                                        for ts, _ in burst[:4])
                                self.process([it for _, it in burst])
                            did = self.idle_work() if self.idle_work else False
                            if not burst and not did:
                                break
                        busy_end = time.monotonic_ns()
                        q.last_busy_end_ns = busy_end
                        policy.on_cycle_end((busy_end - busy_start) / 1e3,
                                            max(vacation_ns / 1e3, 1e-3))
                        cycles_pending.append(qi)
                    finally:
                        q.lock.release()
                if si == len(targets) and slot.steal:
                    # own/stolen queues done: steal the longest unvisited
                    # backlog next (post-drain depths, like the simulator)
                    best, cand = 0, -1
                    for j, qq in enumerate(self.queues):
                        if j not in visited and len(qq) > best:
                            best, cand = len(qq), j
                    if cand >= 0:
                        targets.append(cand)
                        visited.add(cand)
            t_cpu1 = time.thread_time_ns()
            with self._stats_lock:
                st.wakeups += 1
                st.awake_ns += t_cpu1 - t_cpu0
                st.items += items
                if lock_taken:
                    st.cycles += 1
                if lat_pending:
                    st.latency_us.extend(lat_pending)
                for qi in cycles_pending:
                    self._cycles_q[qi] += 1
            wake += 1
            sleep_ns = policy.on_wake(WakeContext(
                primary=lock_taken or not slot.demote_on_miss, items=items,
                # ns since run start, matching the simulator's clock
                now_ns=time.monotonic_ns() - st.started_ns))
            if sleep_ns > 0:
                self.sleep_fn(sleep_ns)

    def _run_app(self) -> None:
        """Co-run application loop: one quantum of ``app_load.step()``
        per iteration until the runtime stops; totals are folded into
        the run's stats when the thread exits (stop() joins first)."""
        ops = 0
        t_cpu0 = time.thread_time_ns()
        app = self.app_load
        while self._running.is_set():
            ops += app.step()
        dt = time.thread_time_ns() - t_cpu0
        with self._stats_lock:
            self.stats.app_ops += ops
            self.stats.app_cpu_ns += dt

    # -- workload replay ---------------------------------------------------------
    def run(self, workload, *, duration_us: float,
            payload: Callable[[int], object] = lambda i: i,
            seed: int = 0, drain_timeout_s: float = 5.0,
            dispatcher=None, schedule=None) -> RunStats:
        """Replay ``workload`` against the queues in real time, then stop.

        Arrivals are generated by ``workload.iter_arrivals`` and pushed at
        their scheduled offsets (a software traffic generator on the same
        host); ``dispatcher`` (default round-robin, the historical
        behavior) picks the queue each arrival lands in.  ``schedule``
        (a ``repro.runtime.schedule.LoadSchedule``) modulates the
        workload's rate over the run — the live-replay counterpart of
        ``SimRunConfig.schedule``, through the identical time-warping
        wrapper.  Returns the unified ``RunStats`` — directly comparable
        to ``repro.runtime.sim.simulate_run`` for the same
        policy/workload/schedule.
        """
        base_wl = getattr(workload, "base", workload)  # unwrap pre-scheduled
        workload_label = getattr(base_wl, "name", type(base_wl).__name__)
        if schedule is not None:
            from .workload import ScheduledWorkload
            workload = ScheduledWorkload(workload, schedule)
        rng = np.random.default_rng(seed)
        dispatcher = dispatcher or RoundRobinDispatch()
        dispatcher.reset(len(self.queues), rng)
        self.start()
        t0 = time.monotonic_ns()
        n = 0
        max_lag_ns = 0
        for t_us in workload.iter_arrivals(duration_us, rng):
            gap_ns = t0 + int(t_us * 1e3) - time.monotonic_ns()
            if gap_ns > 0:
                time.sleep(gap_ns / 1e9)
            else:
                max_lag_ns = max(max_lag_ns, -gap_ns)
            backlogs = [len(q) for q in self.queues]
            self.queues[dispatcher.pick(n, backlogs)].push(payload(n))
            n += 1
        tail_ns = t0 + int(duration_us * 1e3) - time.monotonic_ns()
        if tail_ns > 0:
            time.sleep(tail_ns / 1e9)
        deadline = time.monotonic() + drain_timeout_s
        while any(len(q) for q in self.queues) and time.monotonic() < deadline:
            time.sleep(0.005)
        st = self.stop()
        st.workload = workload_label
        sched = schedule or getattr(workload, "schedule", None)
        st.schedule = sched.descriptor() if sched is not None else ""
        st.feeder_lag_us = max_lag_ns / 1e3
        if n and max_lag_ns / 1e3 > 0.05 * duration_us:
            warnings.warn(
                f"workload generator fell {max_lag_ns / 1e3:.0f}us behind "
                f"its schedule ({n} arrivals in {duration_us:.0f}us): the "
                "host cannot source this rate in real time, so the run is "
                "not comparable to a simulate_run of the same workload",
                RuntimeWarning, stacklevel=2)
        return st
