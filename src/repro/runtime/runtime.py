"""Real-thread execution backend: any policy × any workload, OS threads.

One generic ``Runtime`` runs ``policy.threads`` OS threads against one or
more shared bounded queues, executing the paper's Listing-2 loop shape:

    while running:
        lock_taken = False
        for q in queues:
            if not trylock(q):   continue
            lock_taken = True
            while work:  process(...)                        # busy period
            policy.on_cycle_end(busy_us, vacation_us)
            unlock(q)
        sleep(policy.on_wake(ctx))          # 0 => spin (busy-poll policy)

What used to be three hand-rolled loops (``MetronomePollers``,
``BusyPollLoop``, the serving servers) is now this one loop with the
policy injected; ``repro.core.pollers`` and ``repro.serving.server``
keep their old names as thin shims over it.

CPU accounting uses per-thread CPU time (time.thread_time_ns around the
loop body) — the userspace analogue of the paper's getrusage()
methodology, immune to descheduling on shared hosts.  Spinning policies
are pinned at a full core in the report (their defining cost, and what
the paper charges DPDK).

``Runtime.run(workload, ...)`` additionally replays a ``Workload``
against the queues in real time from a feeder thread, returning the same
``RunStats`` the simulator produces — the sim/real parity surface.
"""

from __future__ import annotations

import threading
import time
import warnings
from typing import Callable

import numpy as np

from repro.core.hr_sleep import hr_sleep

from .policy import WakeContext
from .queues import BoundedQueue
from .stats import Reservoir, RunStats

__all__ = ["Runtime"]


class Runtime:
    def __init__(
        self,
        queues: list[BoundedQueue],
        process: Callable[[list], None],
        policy,
        *,
        burst_size: int = 32,
        sleep_fn: Callable[[int], None] = hr_sleep,
        latency_sample_every: int = 16,
        idle_work: Callable[[], bool] | None = None,
        latency_reservoir: int = 65_536,
    ):
        """``process`` consumes a burst of retrieved items; ``idle_work``
        (optional) is polled during the busy period after each burst and
        returns whether it still made progress — the hook that lets a
        serving engine keep its decode loop inside the busy period."""
        self.queues = queues
        self.process = process
        self.policy = policy
        self.burst_size = burst_size
        self.sleep_fn = sleep_fn
        self.idle_work = idle_work
        self.stats = RunStats(backend="threads",
                              policy=getattr(policy, "name", ""))
        self._lat_cap = latency_reservoir
        self._stats_lock = threading.Lock()
        self._running = threading.Event()
        self._threads: list[threading.Thread] = []
        self._lat_every = max(latency_sample_every, 1)

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> None:
        self.policy.reset()
        # queue/lock counters are cumulative; snapshot so a restarted
        # Runtime reports only this run's arrivals and busy tries
        self._base_counts = [(q.offered, q.dropped, q.lock.busy_tries)
                             for q in self.queues]
        self.stats = RunStats(backend="threads",
                              policy=getattr(self.policy, "name", ""),
                              started_ns=time.monotonic_ns(),
                              latency_us=Reservoir(self._lat_cap))
        self._running.set()
        self._threads = [
            threading.Thread(target=self._run, name=f"runtime-{i}", daemon=True)
            for i in range(self.policy.threads)
        ]
        for t in self._threads:
            t.start()

    def stop(self, timeout: float = 5.0) -> RunStats:
        self._running.clear()
        for t in self._threads:
            t.join(timeout)
        st = self.stats
        st.stopped_ns = time.monotonic_ns()
        base = getattr(self, "_base_counts", [(0, 0, 0)] * len(self.queues))
        st.offered = sum(q.offered - b[0] for q, b in zip(self.queues, base))
        st.dropped = sum(q.dropped - b[1] for q, b in zip(self.queues, base))
        st.busy_tries = sum(q.lock.busy_tries - b[2]
                            for q, b in zip(self.queues, base))
        if getattr(self.policy, "spin", False):
            # By construction a spinning policy never sleeps: charge one
            # full core per thread (the paper's DPDK baseline accounting).
            st.awake_ns = st.duration_ns * max(self.policy.threads, 1)
        return st

    # -- the paper's loop, policy-parameterized ----------------------------------
    def _run(self) -> None:
        policy = self.policy
        st = self.stats
        wake = 0
        while self._running.is_set():
            t_wake = time.monotonic_ns()
            t_cpu0 = time.thread_time_ns()
            lock_taken = False
            items = 0
            for q in self.queues:
                if not q.lock.try_acquire():
                    continue
                lock_taken = True
                try:
                    vacation_ns = t_wake - q.last_busy_end_ns
                    busy_start = time.monotonic_ns()
                    while True:
                        burst = q.poll(self.burst_size)
                        if burst:
                            items += len(burst)
                            if wake % self._lat_every == 0:
                                now = time.monotonic_ns()
                                sample = [(now - ts) / 1e3
                                          for ts, _ in burst[:4]]
                                with self._stats_lock:
                                    st.latency_us.extend(sample)
                            self.process([it for _, it in burst])
                        did = self.idle_work() if self.idle_work else False
                        if not burst and not did:
                            break
                    busy_end = time.monotonic_ns()
                    q.last_busy_end_ns = busy_end
                    policy.on_cycle_end((busy_end - busy_start) / 1e3,
                                        max(vacation_ns / 1e3, 1e-3))
                finally:
                    q.lock.release()
            t_cpu1 = time.thread_time_ns()
            with self._stats_lock:
                st.wakeups += 1
                st.awake_ns += t_cpu1 - t_cpu0
                st.items += items
                if lock_taken:
                    st.cycles += 1
            wake += 1
            sleep_ns = policy.on_wake(WakeContext(
                primary=lock_taken, items=items,
                # ns since run start, matching the simulator's clock
                now_ns=time.monotonic_ns() - st.started_ns))
            if sleep_ns > 0:
                self.sleep_fn(sleep_ns)

    # -- workload replay ---------------------------------------------------------
    def run(self, workload, *, duration_us: float,
            payload: Callable[[int], object] = lambda i: i,
            seed: int = 0, drain_timeout_s: float = 5.0) -> RunStats:
        """Replay ``workload`` against the queues in real time, then stop.

        Arrivals are generated by ``workload.iter_arrivals`` and pushed at
        their scheduled offsets (a software traffic generator on the same
        host).  Returns the unified ``RunStats`` — directly comparable to
        ``repro.runtime.sim.simulate_run`` for the same policy/workload.
        """
        rng = np.random.default_rng(seed)
        self.start()
        t0 = time.monotonic_ns()
        n = 0
        max_lag_ns = 0
        for t_us in workload.iter_arrivals(duration_us, rng):
            gap_ns = t0 + int(t_us * 1e3) - time.monotonic_ns()
            if gap_ns > 0:
                time.sleep(gap_ns / 1e9)
            else:
                max_lag_ns = max(max_lag_ns, -gap_ns)
            self.queues[n % len(self.queues)].push(payload(n))
            n += 1
        tail_ns = t0 + int(duration_us * 1e3) - time.monotonic_ns()
        if tail_ns > 0:
            time.sleep(tail_ns / 1e9)
        deadline = time.monotonic() + drain_timeout_s
        while any(len(q) for q in self.queues) and time.monotonic() < deadline:
            time.sleep(0.005)
        st = self.stop()
        st.workload = getattr(workload, "name", type(workload).__name__)
        st.feeder_lag_us = max_lag_ns / 1e3
        if n and max_lag_ns / 1e3 > 0.05 * duration_us:
            warnings.warn(
                f"workload generator fell {max_lag_ns / 1e3:.0f}us behind "
                f"its schedule ({n} arrivals in {duration_us:.0f}us): the "
                "host cannot source this rate in real time, so the run is "
                "not comparable to a simulate_run of the same workload",
                RuntimeWarning, stacklevel=2)
        return st
