"""Unified run statistics for every execution backend.

One ``RunStats`` dataclass is produced by the discrete-event simulator,
the threaded ``Runtime``, and the serving server, so policies and
workloads can be compared apples-to-apples across backends (and the old
``PollerStats``/``ServerStats``/``SimResult`` views become thin aliases
or conversions of this).

``Reservoir`` is a bounded uniform sample: long-running servers record
latency forever without unbounded memory growth (each of the first
``capacity`` values is kept; afterwards value *n* replaces a random slot
with probability capacity/n — the classic Algorithm R invariant, every
value seen has equal probability of being in the sample).
"""

from __future__ import annotations

import copy
import random
import warnings
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Reservoir", "QueueStats", "RunStats", "WindowedSeries",
           "TrackingStats", "hedged_latency_quantile"]


class Reservoir:
    """Bounded uniform reservoir sample of a float stream (Algorithm R).

    Quacks enough like a list (len/iter/bool/__array__/extend/append)
    that existing consumers — ``np.median(stats.latency_samples_us)``,
    truthiness guards — keep working unchanged.
    """

    __slots__ = ("capacity", "count", "_buf", "_rng", "_np_rng")

    def __init__(self, capacity: int = 65_536, seed: int = 0):
        if capacity < 1:
            raise ValueError("Reservoir capacity must be >= 1")
        self.capacity = capacity
        self.count = 0              # total values ever offered
        self._buf: list[float] = []
        self._rng = random.Random(seed)
        self._np_rng = np.random.default_rng(seed)

    def append(self, value: float) -> None:
        self.count += 1
        if len(self._buf) < self.capacity:
            self._buf.append(float(value))
            return
        j = self._rng.randrange(self.count)
        if j < self.capacity:
            self._buf[j] = float(value)

    def extend(self, values) -> None:
        if not isinstance(values, (list, tuple, np.ndarray)):
            for v in values:            # generators: no length to batch on
                self.append(v)
            return
        arr = np.asarray(values, dtype=np.float64).ravel()
        if arr.size == 0:
            return
        free = self.capacity - len(self._buf)
        if free > 0:                    # fill phase, no randomness needed
            take = min(free, arr.size)
            self._buf.extend(arr[:take].tolist())
            self.count += take
            arr = arr[take:]
        if arr.size == 0:
            return
        # bulk Algorithm R: value #k replaces a random slot iff
        # randrange(k) < capacity — one vectorized draw for the batch
        ks = np.arange(self.count + 1, self.count + arr.size + 1)
        self.count += arr.size
        js = (self._np_rng.random(arr.size) * ks).astype(np.int64)
        hit = js < self.capacity
        for j, v in zip(js[hit].tolist(), arr[hit].tolist(), strict=True):
            self._buf[j] = v            # in order: later values win ties

    def merge(self, other: "Reservoir") -> "Reservoir":
        """Weighted Algorithm-R union: after merging, the sample behaves
        as if the two underlying streams had been fed into one reservoir
        of ``self.capacity`` — each of the ``self.count + other.count``
        values seen by either side is (approximately) equally likely to
        be in the merged buffer.  In place; returns ``self``.

        While both sides are still lossless (nothing evicted yet) the
        union is an exact concatenation.  Otherwise the merged buffer
        draws each slot from ``self`` with probability proportional to
        ``self.count`` (binomial split, sampled without replacement
        within each side) — the standard reservoir-union construction
        used to combine per-shard samples.
        """
        if other.count == 0:
            return self
        total = self.count + other.count
        lossless = (self.count == len(self._buf)
                    and other.count == len(other._buf))
        if lossless and total <= self.capacity:
            self._buf.extend(other._buf)
            self.count = total
            return self
        k = min(self.capacity, len(self._buf) + len(other._buf))
        n_self = int(self._np_rng.binomial(k, self.count / total))
        n_self = min(n_self, len(self._buf))
        n_other = min(k - n_self, len(other._buf))
        n_self = min(k - n_other, len(self._buf))   # top up if other clipped
        pick_s = self._np_rng.choice(len(self._buf), size=n_self,
                                     replace=False)
        pick_o = self._np_rng.choice(len(other._buf), size=n_other,
                                     replace=False)
        self._buf = ([self._buf[i] for i in pick_s]
                     + [float(other._buf[i]) for i in pick_o])
        self.count = total
        return self

    def merge_all(self, others) -> "Reservoir":
        """n-way weighted union in ONE buffer rebuild.

        Distributionally equivalent to left-folding pairwise ``merge``
        over ``others`` — every value seen by any side ends up in the
        merged sample with (approximately) equal probability — but a
        1000-shard rollup does a single multinomial slot allocation and
        one sampling pass instead of O(n) full buffer re-copies.  In
        place; returns ``self``.

        Slots are allocated across sides by a multinomial draw
        proportional to each side's stream count, clipped to what each
        buffer actually holds, with the clipped excess handed to sides
        that still have unsampled values (largest-room first).
        """
        parts = [o for o in others if o.count > 0]
        if not parts:
            return self
        counts = np.asarray([self.count] + [o.count for o in parts],
                            dtype=np.float64)
        bufs = [self._buf] + [o._buf for o in parts]
        lens = np.asarray([len(b) for b in bufs], dtype=np.int64)
        total = int(counts.sum())
        if total <= self.capacity and int(counts.sum()) == int(lens.sum()):
            # every side still lossless and the union fits: exact concat
            merged: list[float] = list(self._buf)
            for b in bufs[1:]:
                merged.extend(float(v) for v in b)
            self._buf = merged
            self.count = total
            return self
        k = min(self.capacity, int(lens.sum()))
        alloc = self._np_rng.multinomial(k, counts / counts.sum())
        for _ in range(len(bufs)):
            excess = int(np.maximum(alloc - lens, 0).sum())
            if excess == 0:
                break
            alloc = np.minimum(alloc, lens)
            room = lens - alloc
            for i in np.argsort(-room):
                give = min(excess, int(room[i]))
                alloc[i] += give
                excess -= give
                if excess == 0:
                    break
        buf: list[float] = []
        for n_i, b in zip(alloc.tolist(), bufs, strict=True):
            if n_i == 0:
                continue
            if n_i >= len(b):
                buf.extend(float(v) for v in b)
            else:
                pick = self._np_rng.choice(len(b), size=n_i, replace=False)
                buf.extend(float(b[j]) for j in pick)
        self._buf = buf
        self.count = total
        return self

    def __len__(self) -> int:
        return len(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def __getitem__(self, i):
        return self._buf[i]

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self._buf, dtype=dtype or np.float64)

    def __repr__(self) -> str:
        return (f"Reservoir(n={len(self._buf)}/{self.capacity}, "
                f"seen={self.count})")


def _empty() -> np.ndarray:
    return np.empty(0)


@dataclass
class WindowedSeries:
    """Per-window accumulators of one run, shared by every backend.

    Raw sums are stored (never derived values), so the derived metrics —
    per-window mean latency via Little's law, CPU fraction, offered /
    served rates, estimated vs true rho — are computed by *one* code
    path regardless of which engine filled the accumulators, and two
    equal-grid series merge by plain addition.  All arrays have one
    entry per window of ``window_us``.

      - ``offered`` / ``served``  packets entering / leaving per window;
      - ``lat_area_us``  queue-depth integral accrued in the window
        (packet*us) — ``mean_latency_us`` is its Little's-law ratio;
      - ``awake_us``  poller CPU charged in the window;
      - ``energy_uj``  EnergyModel charge accrued in the window
        (active + sleep-arm + transition components);
      - ``rho_sum`` / ``rho_cnt``  controller load-estimate samples
        (one per primary wake; zero count = no estimator, e.g. the
        batched engine's static points or busy polling);
      - ``ts_sum``  the controller's T_S at those samples;
      - ``p99_latency_us``  per-window sampled p99 (NaN where the
        backend keeps no samples, e.g. the batched engine);
      - ``spill_*``  scalar contributions at event times past the run
        duration (the event engine's final-drain pass; always 0 from
        the batched engines, whose scan stops at duration).  Window
        sums plus spill equal the run totals — the conservation law.
    """

    window_us: float
    service_rate_mpps: float
    offered: np.ndarray
    served: np.ndarray
    lat_area_us: np.ndarray
    awake_us: np.ndarray
    energy_uj: np.ndarray = field(default_factory=_empty)
    rho_sum: np.ndarray = field(default_factory=_empty)
    rho_cnt: np.ndarray = field(default_factory=_empty)
    ts_sum: np.ndarray = field(default_factory=_empty)
    p99_latency_us: np.ndarray = field(default_factory=_empty)
    spill_offered: float = 0.0
    spill_served: float = 0.0
    spill_lat_area_us: float = 0.0
    spill_awake_us: float = 0.0
    spill_energy_uj: float = 0.0

    def __post_init__(self):
        n = len(self.offered)
        for f in ("energy_uj", "rho_sum", "rho_cnt", "ts_sum"):
            if getattr(self, f).size == 0:
                setattr(self, f, np.zeros(n))
        if self.p99_latency_us.size == 0:
            self.p99_latency_us = np.full(n, np.nan)

    # -- derived ---------------------------------------------------------------
    @property
    def n_windows(self) -> int:
        return len(self.offered)

    @property
    def t_us(self) -> np.ndarray:
        """Window start times."""
        return np.arange(self.n_windows) * self.window_us

    @property
    def mean_latency_us(self) -> np.ndarray:
        """Little's-law mean sojourn per window (NaN where nothing was
        served — no departures means no latency observation)."""
        out = np.full(self.n_windows, np.nan)
        m = self.served > 0
        out[m] = self.lat_area_us[m] / self.served[m]
        return out

    @property
    def cpu_fraction(self) -> np.ndarray:
        return self.awake_us / max(self.window_us, 1e-9)

    @property
    def power_w(self) -> np.ndarray:
        """Mean package power per window (uJ over us is W)."""
        return self.energy_uj / max(self.window_us, 1e-9)

    @property
    def offered_mpps(self) -> np.ndarray:
        return self.offered / max(self.window_us, 1e-9)

    @property
    def tput_mpps(self) -> np.ndarray:
        return self.served / max(self.window_us, 1e-9)

    @property
    def rho_true(self) -> np.ndarray:
        """Actual offered load per window (what Eq 10 is estimating)."""
        return self.offered_mpps / max(self.service_rate_mpps, 1e-9)

    @property
    def rho_est(self) -> np.ndarray:
        """Controller EWMA estimate per window (NaN without samples)."""
        out = np.full(self.n_windows, np.nan)
        m = self.rho_cnt > 0
        out[m] = self.rho_sum[m] / self.rho_cnt[m]
        return out

    @property
    def ts_us(self) -> np.ndarray:
        out = np.full(self.n_windows, np.nan)
        m = self.rho_cnt > 0
        out[m] = self.ts_sum[m] / self.rho_cnt[m]
        return out

    def merge(self, other: "WindowedSeries") -> "WindowedSeries":
        """Sum accumulators of two equal-grid shards (raises on
        mismatched window grids — derived ratios then re-derive from the
        pooled sums).  Sampled p99 combines conservatively (max)."""
        if (self.window_us != other.window_us
                or self.n_windows != other.n_windows):
            raise ValueError("cannot merge WindowedSeries on different "
                             "window grids")
        for f in ("offered", "served", "lat_area_us", "awake_us",
                  "energy_uj", "rho_sum", "rho_cnt", "ts_sum",
                  "spill_offered", "spill_served", "spill_lat_area_us",
                  "spill_awake_us", "spill_energy_uj"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            self.p99_latency_us = np.fmax(self.p99_latency_us,
                                          other.p99_latency_us)
        return self

    def tracking(self, transitions_us, target_latency_us: float, *,
                 settle_rel: float = 0.25, settle_abs_us: float = 2.0,
                 hold_windows: int = 3) -> "TrackingStats":
        """Adaptation quality against this series — ONE implementation
        for every backend (the acceptance criterion of the
        nonstationary-traffic tier).

        The run is cut into regimes at ``transitions_us`` (the
        schedule's load-change times).  Per regime the *settled* latency
        is the median of the regime's last third of windows; the
        convergence time after a transition is how long the windowed
        mean latency takes to enter the settle band
        ``max(settle_abs_us, settle_rel * settled)`` around that value
        and hold it for ``hold_windows`` consecutive windows (sustained
        entry, so one noisy window deep in an otherwise-settled regime
        does not push convergence to the end of the run).  Overshoot is
        the worst windowed excursion above the settled value; the
        violation fraction counts windows whose mean latency exceeds
        ``target_latency_us`` among the windows that actually served
        traffic; ``rho_rmse`` is the tracking error of the controller's
        load estimate against the true offered load (NaN without an
        estimator).
        """
        lat = self.mean_latency_us
        t = self.t_us
        n = self.n_windows
        bounds = [0.0] + sorted(float(x) for x in transitions_us
                                if 0.0 < x < n * self.window_us)
        bounds_idx = [int(np.searchsorted(t, b, side="left"))
                      for b in bounds] + [n]

        conv, overshoot = [], 0.0
        for k in range(len(bounds)):
            lo, hi = bounds_idx[k], bounds_idx[k + 1]
            if hi <= lo:
                if k > 0:
                    conv.append(float("nan"))
                continue
            seg = lat[lo:hi]
            valid = seg[np.isfinite(seg)]
            if valid.size == 0:
                if k > 0:
                    conv.append(float("nan"))
                continue
            tail = valid[-max(valid.size // 3, 1):]
            settled = float(np.median(tail))
            band = max(settle_abs_us, settle_rel * settled)
            overshoot = max(overshoot, float(np.nanmax(seg)) - settled)
            if k > 0:          # convergence is measured after a transition
                inside = ~(np.abs(seg - settled) > band)   # NaN => inside
                # first window from which the metric holds the band for
                # `hold_windows` consecutive windows (clipped to the
                # regime length for short regimes)
                kk = min(max(int(hold_windows), 1), inside.size)
                streak = (np.convolve(inside.astype(np.int64),
                                      np.ones(kk, np.int64),
                                      mode="valid") == kk)
                idx = int(np.argmax(streak)) if streak.any() else -1
                conv.append(float(t[lo + idx] + self.window_us
                                  - bounds[k]) if idx >= 0
                            else float("nan"))

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            # violations are judged over windows that actually served
            # traffic: NaN (nothing-served) windows would otherwise pad
            # the denominator and understate violations under schedules
            # with idle phases
            fin = lat[np.isfinite(lat)]
            violation = (float(np.mean(fin > target_latency_us))
                         if fin.size else 0.0)
            err = self.rho_est - self.rho_true
            err = err[np.isfinite(err)]
            rho_rmse = (float(np.sqrt(np.mean(err ** 2)))
                        if err.size else float("nan"))
        conv_t = tuple(conv)
        finite = [c for c in conv_t if np.isfinite(c)]
        return TrackingStats(
            window_us=self.window_us,
            target_latency_us=float(target_latency_us),
            transitions_us=tuple(bounds[1:]),
            convergence_us=conv_t,
            mean_convergence_us=(float(np.mean(finite)) if finite
                                 else float("nan")),
            max_overshoot_us=float(overshoot),
            violation_fraction=violation,
            rho_rmse=rho_rmse,
        )


@dataclass(frozen=True)
class TrackingStats:
    """How well the closed loop tracked a nonstationary offered load.

    Produced by ``WindowedSeries.tracking`` — the identical computation
    on every backend — and consumed by ``benchmarks/adaptation.py``'s
    verdict rows (feed-forward vs pure-Eq-12 convergence, busy-poll's
    flat CPU)."""

    window_us: float
    target_latency_us: float
    transitions_us: tuple        # load-change times the run was cut at
    convergence_us: tuple        # per transition; NaN = never settled
    mean_convergence_us: float
    max_overshoot_us: float      # worst windowed excursion above settled
    violation_fraction: float    # windows with mean latency > target
    rho_rmse: float              # EWMA rho vs true rho (NaN: no estimator)

    def summary(self) -> dict:
        return {
            "window_us": self.window_us,
            "target_latency_us": self.target_latency_us,
            "n_transitions": len(self.transitions_us),
            "mean_convergence_us": self.mean_convergence_us,
            "max_overshoot_us": self.max_overshoot_us,
            "violation_fraction": self.violation_fraction,
            "rho_rmse": self.rho_rmse,
        }


@dataclass
class QueueStats:
    """Per-Rx-queue slice of a run's counters.  Every field sums to the
    matching ``RunStats`` total across ``RunStats.per_queue`` (the
    conservation law the multi-queue refactor is tested against)."""

    queue: int
    offered: int = 0
    dropped: int = 0
    serviced: int = 0
    busy_tries: int = 0
    cycles: int = 0
    # per-queue retrieval-latency sample (populated by the event
    # simulator; None where the backend doesn't break latency down)
    latency_us: Reservoir | None = None

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)

    def merge(self, other: "QueueStats") -> "QueueStats":
        """Combine with the same queue's slice from a parallel shard."""
        self.offered += other.offered
        self.dropped += other.dropped
        self.serviced += other.serviced
        self.busy_tries += other.busy_tries
        self.cycles += other.cycles
        if self.latency_us is not None and other.latency_us is not None:
            self.latency_us.merge(other.latency_us)
        return self

    def merge_all(self, others) -> "QueueStats":
        """n-way ``merge``: counters sum once, the latency reservoir does
        one weighted union (``Reservoir.merge_all``).  In place."""
        others = list(others)
        if not others:
            return self
        for f in ("offered", "dropped", "serviced", "busy_tries", "cycles"):
            setattr(self, f,
                    getattr(self, f) + sum(getattr(o, f) for o in others))
        if self.latency_us is not None:
            self.latency_us.merge_all(
                o.latency_us for o in others if o.latency_us is not None)
        return self


@dataclass
class RunStats:
    """One result type for sim / threads / server runs.

    Time bookkeeping is in nanoseconds (``awake_ns`` over
    ``stopped_ns - started_ns``) so the real-thread backends can feed
    ``time.thread_time_ns`` straight in; the simulator converts its
    microsecond clock once at the end.  Cycle-level arrays
    (vacations/busies/backlogs, adaptation time series) are only
    populated by the simulator — real threads would pay too much for
    them on the hot path.
    """

    backend: str = ""                 # "sim" | "threads" | "server"
    policy: str = ""
    workload: str = ""
    # nonstationary runs: the LoadSchedule descriptor that modulated the
    # workload ("" = stationary) — keeps benchmark/JSON rows
    # self-describing without reaching back to the config object
    schedule: str = ""

    wakeups: int = 0
    cycles: int = 0                   # busy periods won (lock taken)
    busy_tries: int = 0               # failed trylocks (backup wakes)
    items: int = 0                    # packets / requests serviced
    offered: int = 0
    dropped: int = 0

    awake_ns: int = 0
    started_ns: int = 0
    stopped_ns: int = 0

    # co-run application load (repro.runtime.apps): work quanta the
    # competing app completed during the run and the CPU it burned —
    # kept separate from awake_ns, which is the I/O task's CPU alone
    app_ops: int = 0
    app_cpu_ns: int = 0

    latency_us: Reservoir = field(default_factory=Reservoir)
    # analytic backends (the busy-poll fluid model) report closed-form
    # latency summaries instead of samples
    latency_override: dict | None = None
    # exact queue-depth integral (packet*us): simulation engines set this
    # so Little's law recovers the true all-packet mean sojourn —
    # ``mean_latency_us`` from samples is the *vacation-found-packet*
    # estimator (per-cycle weighted), which reads systematically higher
    # by roughly (1+rho) at load; use ``mean_sojourn_us`` to compare
    # engines or backends on the same quantity
    latency_area_us: float = 0.0
    # EnergyModel charge of the run (active + sleep-arm + transition
    # components, see SimRunConfig.energy_model).  Simulation engines
    # account it exactly; the threaded Runtime/Server backends fill a
    # model-based estimate from their wake/awake counters.
    energy_uj: float = 0.0
    # real-time replay only: worst lateness of the arrival generator vs
    # the workload's schedule.  >> mean inter-arrival gap means the host
    # could not source the workload and the run is NOT sim-comparable.
    feeder_lag_us: float = 0.0

    # multi-queue ingress: one entry per Rx queue (empty when the backend
    # does not break its counters down, e.g. the spin fluid model)
    per_queue: list[QueueStats] = field(default_factory=list)
    # simulator: busy periods cut short by the drain round cap, stranding
    # backlog until the next wake — nonzero means saturated cycles whose
    # service was deferred, and summary() warns about it
    drain_truncations: int = 0

    # windowed adaptation series (cfg.window_us > 0): filled by BOTH
    # simulation engines with the same accumulator convention, so
    # WindowedSeries/TrackingStats are one code path across backends
    windows: WindowedSeries | None = None

    # simulator-only cycle samples and adaptation series
    vacations_us: np.ndarray = field(default_factory=_empty)
    busies_us: np.ndarray = field(default_factory=_empty)
    n_v: np.ndarray = field(default_factory=_empty)
    rho_series: np.ndarray = field(default_factory=_empty)
    ts_series: np.ndarray = field(default_factory=_empty)
    tput_series_mpps: np.ndarray = field(default_factory=_empty)
    offered_series_mpps: np.ndarray = field(default_factory=_empty)
    series_t_us: np.ndarray = field(default_factory=_empty)

    # -- derived ---------------------------------------------------------------
    @property
    def duration_ns(self) -> int:
        return max(self.stopped_ns - self.started_ns, 1)

    @property
    def cpu_fraction(self) -> float:
        """Sum of thread awake time over wall duration (can exceed 1.0)."""
        return self.awake_ns / self.duration_ns

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)

    @property
    def app_cpu_fraction(self) -> float:
        """Cores the co-run application load actually got (0 when none
        was installed)."""
        return self.app_cpu_ns / self.duration_ns

    @property
    def energy_per_packet_nj(self) -> float:
        """Package energy per serviced packet (nJ) — the per-packet
        cost metric the power-proportionality claims are judged on."""
        return 1e3 * self.energy_uj / max(self.items, 1)

    @property
    def mean_power_w(self) -> float:
        """Mean package power over the wall window (uJ / us = W)."""
        return self.energy_uj / (self.duration_ns / 1e3)

    @property
    def serviced(self) -> int:
        return self.items

    # legacy PollerStats / ServerStats spellings
    @property
    def busy_periods(self) -> int:
        return self.cycles

    @property
    def latency_samples_us(self) -> Reservoir:
        return self.latency_us

    @property
    def retrieval_lat_us(self) -> Reservoir:
        return self.latency_us

    # latency summaries (empty-safe, like the old SimResult defaults)
    @property
    def mean_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["mean"]
        return float(np.mean(self.latency_us)) if self.latency_us else 0.0

    @property
    def p99_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["p99"]
        return (float(np.percentile(np.asarray(self.latency_us), 99))
                if self.latency_us else 0.0)

    @property
    def worst_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["worst"]
        return float(np.max(np.asarray(self.latency_us))) if self.latency_us else 0.0

    @property
    def mean_sojourn_us(self) -> float:
        """All-packet mean time in system via Little's law (area under
        the queue-depth curve over packets served); falls back to the
        sampled mean where the backend keeps no depth integral."""
        if self.latency_area_us > 0.0:
            return self.latency_area_us / max(self.items, 1)
        return self.mean_latency_us

    @property
    def mean_vacation_us(self) -> float:
        return float(np.mean(self.vacations_us)) if self.vacations_us.size else 0.0

    @property
    def mean_busy_us(self) -> float:
        return float(np.mean(self.busies_us)) if self.busies_us.size else 0.0

    @property
    def mean_nv(self) -> float:
        return float(np.mean(self.n_v)) if self.n_v.size else 0.0

    def merge(self, other: "RunStats") -> "RunStats":
        """Combine two runs that shard one logical experiment — parallel
        queue shards, seed replicas of the same simulated window, or
        per-worker slices of a batched sweep.  Counters add, latency
        reservoirs take their weighted Algorithm-R union, per-queue
        slices merge by queue index, and the wall window becomes the
        union ``[min(started), max(stopped)]`` (so ``cpu_fraction`` of
        equal-window shards is the summed awake time over that one
        window, i.e. total cores burned).  In place; returns ``self``.

        Cycle-sample arrays concatenate; binned time series merge only
        when both sides share the same bin grid (rates add, rho/T_S
        average) and are dropped otherwise.
        """
        for f in ("wakeups", "cycles", "busy_tries", "items", "offered",
                  "dropped", "awake_ns", "app_ops", "app_cpu_ns",
                  "drain_truncations", "latency_area_us", "energy_uj"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.started_ns = min(self.started_ns, other.started_ns)
        self.stopped_ns = max(self.stopped_ns, other.stopped_ns)
        for f in ("backend", "policy", "workload", "schedule"):
            if getattr(self, f) != getattr(other, f):
                setattr(self, f, "mixed")
        # latency: sample-based sides merge reservoirs; analytic
        # overrides combine as an items-weighted mean (p99/worst upper
        # bounds) since there are no samples to re-pool.
        if self.latency_override or other.latency_override:
            mine = self.latency_override or {
                "mean": self.mean_latency_us, "p99": self.p99_latency_us,
                "worst": self.worst_latency_us}
            theirs = other.latency_override or {
                "mean": other.mean_latency_us, "p99": other.p99_latency_us,
                "worst": other.worst_latency_us}
            # items was already summed above; recover the pre-merge split
            w_a, w_b = self.items - other.items, other.items
            tot = max(w_a + w_b, 1)
            self.latency_override = {
                "mean": (mine["mean"] * w_a + theirs["mean"] * w_b) / tot,
                "p99": max(mine["p99"], theirs["p99"]),
                "worst": max(mine["worst"], theirs["worst"]),
            }
        else:
            self.latency_us.merge(other.latency_us)
        self.feeder_lag_us = max(self.feeder_lag_us, other.feeder_lag_us)
        # adopt copies of the donor's per-queue slices — aliasing them
        # would let a later merge mutate `other` retroactively
        if self.per_queue and other.per_queue:
            by_q = {q.queue: q for q in self.per_queue}
            for oq in other.per_queue:
                if oq.queue in by_q:
                    by_q[oq.queue].merge(oq)
                else:
                    self.per_queue.append(copy.deepcopy(oq))
            self.per_queue.sort(key=lambda q: q.queue)
        elif other.per_queue:
            self.per_queue = copy.deepcopy(other.per_queue)
        # windowed series: pool accumulators on matching grids, drop on
        # mismatch (the same convention the binned series follow below)
        if self.windows is not None and other.windows is not None:
            try:
                self.windows.merge(other.windows)
            except ValueError:
                self.windows = None
        elif other.windows is not None:
            self.windows = copy.deepcopy(other.windows)
        for f in ("vacations_us", "busies_us", "n_v"):
            setattr(self, f, np.concatenate([getattr(self, f),
                                             getattr(other, f)]))
        same_grid = (self.series_t_us.size
                     and self.series_t_us.shape == other.series_t_us.shape
                     and np.array_equal(self.series_t_us, other.series_t_us))
        if same_grid:
            for f in ("tput_series_mpps", "offered_series_mpps"):
                setattr(self, f, getattr(self, f) + getattr(other, f))
            for f in ("rho_series", "ts_series"):
                setattr(self, f, (getattr(self, f) + getattr(other, f)) / 2)
        else:
            for f in ("rho_series", "ts_series", "tput_series_mpps",
                      "offered_series_mpps", "series_t_us"):
                setattr(self, f, _empty())
        return self

    def merge_all(self, others) -> "RunStats":
        """n-way ``merge`` for cluster rollups: one pass over all shards
        instead of a left-fold of pairwise merges.  Counters and window
        accumulators sum once, each latency reservoir family does one
        weighted union, and cycle-sample arrays concatenate in a single
        allocation — a 1000-host fleet rollup is O(total data), not
        O(n) re-copies of an ever-growing buffer.  In place.

        Semantics match folding ``merge`` exactly for all counters and
        reservoirs; the only deliberate difference is the binned
        rho/T_S series, which take an unweighted mean over all shards
        (the fold's nested pairwise average weights early shards less).
        """
        others = list(others)
        if not others:
            return self
        # capture pre-merge items for the analytic-override weighting
        items_w = [self.items] + [o.items for o in others]
        for f in ("wakeups", "cycles", "busy_tries", "items", "offered",
                  "dropped", "awake_ns", "app_ops", "app_cpu_ns",
                  "drain_truncations", "latency_area_us", "energy_uj"):
            setattr(self, f,
                    getattr(self, f) + sum(getattr(o, f) for o in others))
        self.started_ns = min(self.started_ns,
                              *(o.started_ns for o in others))
        self.stopped_ns = max(self.stopped_ns,
                              *(o.stopped_ns for o in others))
        for f in ("backend", "policy", "workload", "schedule"):
            vals = {getattr(self, f)} | {getattr(o, f) for o in others}
            if len(vals) > 1:
                setattr(self, f, "mixed")
        if self.latency_override or any(o.latency_override for o in others):
            sides = [self] + others
            views = [s.latency_override or {
                "mean": s.mean_latency_us, "p99": s.p99_latency_us,
                "worst": s.worst_latency_us} for s in sides]
            tot = max(sum(items_w), 1)
            self.latency_override = {
                "mean": sum(v["mean"] * w
                            for v, w in zip(views, items_w, strict=True))
                        / tot,
                "p99": max(v["p99"] for v in views),
                "worst": max(v["worst"] for v in views),
            }
        else:
            self.latency_us.merge_all(o.latency_us for o in others)
        self.feeder_lag_us = max(self.feeder_lag_us,
                                 *(o.feeder_lag_us for o in others))
        donors = [o for o in others if o.per_queue]
        if donors:
            if not self.per_queue:
                self.per_queue = copy.deepcopy(donors[0].per_queue)
                donors = donors[1:]
            by_q = {q.queue: q for q in self.per_queue}
            grouped: dict[int, list[QueueStats]] = {}
            for o in donors:
                for oq in o.per_queue:
                    if oq.queue in by_q:
                        grouped.setdefault(oq.queue, []).append(oq)
                    else:
                        q = copy.deepcopy(oq)
                        self.per_queue.append(q)
                        by_q[oq.queue] = q
            for queue, slices in grouped.items():
                by_q[queue].merge_all(slices)
            self.per_queue.sort(key=lambda q: q.queue)
        win_donors = [o.windows for o in others if o.windows is not None]
        if self.windows is None and win_donors:
            self.windows = copy.deepcopy(win_donors[0])
            win_donors = win_donors[1:]
        if self.windows is not None:
            try:
                for w in win_donors:
                    self.windows.merge(w)
            except ValueError:
                self.windows = None
        for f in ("vacations_us", "busies_us", "n_v"):
            setattr(self, f, np.concatenate(
                [getattr(self, f)] + [getattr(o, f) for o in others]))
        same_grid = all(
            self.series_t_us.size
            and o.series_t_us.shape == self.series_t_us.shape
            and np.array_equal(o.series_t_us, self.series_t_us)
            for o in others)
        if same_grid and self.series_t_us.size:
            n_sides = 1 + len(others)
            for f in ("tput_series_mpps", "offered_series_mpps"):
                setattr(self, f, getattr(self, f)
                        + sum(getattr(o, f) for o in others))
            for f in ("rho_series", "ts_series"):
                setattr(self, f, (getattr(self, f)
                                  + sum(getattr(o, f) for o in others))
                        / n_sides)
        else:
            for f in ("rho_series", "ts_series", "tput_series_mpps",
                      "offered_series_mpps", "series_t_us"):
                setattr(self, f, _empty())
        return self

    def summary(self) -> dict:
        """Flat dict of the headline numbers (benchmark CSV rows, logs)."""
        if self.drain_truncations:
            warnings.warn(
                f"{self.drain_truncations} busy period(s) hit the drain "
                "round cap and stranded backlog until the next wake; "
                "service/latency numbers understate the saturation",
                RuntimeWarning, stacklevel=2)
        return {
            "backend": self.backend, "policy": self.policy,
            "workload": self.workload, "schedule": self.schedule,
            "wakeups": self.wakeups,
            "cycles": self.cycles, "busy_tries": self.busy_tries,
            "serviced": self.items, "offered": self.offered,
            "dropped": self.dropped, "loss_fraction": self.loss_fraction,
            "cpu_fraction": self.cpu_fraction,
            "energy_uj": self.energy_uj,
            "energy_per_packet_nj": self.energy_per_packet_nj,
            "mean_power_w": self.mean_power_w,
            "mean_latency_us": self.mean_latency_us,
            "mean_sojourn_us": self.mean_sojourn_us,
            "p99_latency_us": self.p99_latency_us,
            "n_queues": max(len(self.per_queue), 1),
            "drain_truncations": self.drain_truncations,
        }


def _fleet_survival(x: np.ndarray, mean_us: np.ndarray,
                    weight: np.ndarray, tail_prob: float,
                    tail_scale_us: float) -> np.ndarray:
    """Mixture survival of the per-host two-component latency model at
    points ``x`` (see ``hedged_latency_quantile``)."""
    xs = np.maximum(np.asarray(x, dtype=np.float64)[..., None], 0.0)
    body = np.exp(-xs / mean_us)
    if tail_prob > 0.0:
        tail = np.exp(-xs / (mean_us + tail_scale_us))
        per_host = (1.0 - tail_prob) * body + tail_prob * tail
    else:
        per_host = body
    return per_host @ weight


def hedged_latency_quantile(q: float, mean_us, weight=None, *,
                            hedge_deadline_us: float = 0.0,
                            tail_prob: float = 0.0,
                            tail_scale_us: float = 0.0) -> float:
    """Latency quantile of a replicated fleet under hedged requests.

    Per-host latency follows a two-component survival

        S_h(x) = (1 - p) * exp(-x / L_h) + p * exp(-x / (L_h + c))

    — an exponential body at the host's measured mean sojourn ``L_h``
    (``mean_us``, per host, network delays included) plus an
    environment-tail component of mass ``p = tail_prob`` at scale
    ``c = tail_scale_us`` (requests that land in a correlated stall
    window; pass the host's stalled-time fraction and stall mean).  The
    fleet distribution is the served-share-weighted mixture over hosts.

    Hedging with deadline ``D = hedge_deadline_us`` duplicates a request
    that has not completed by ``D`` to an independent replica drawn from
    the fleet mixture; first completion wins, so beyond the deadline the
    survival multiplies by the fresh replica's survival at age x - D:

        S_h^D(x) = S_h(x) * S_fleet(x - D)   for x > D.

    Stall windows are independent across hosts, so this is exactly the
    mechanism by which hedging collapses the correlated-stall tail: both
    replicas must stall for the request to stay slow.  Tightening D can
    only lower S pointwise, hence every quantile is monotonically
    non-increasing in D — the property the hedging sanity test pins.
    ``D <= 0`` disables hedging.  Solved by bisection; returns the
    latency in microseconds at which the fleet CDF reaches ``q``.
    """
    if not 0.0 < q < 1.0:
        raise ValueError("q must be in (0, 1)")
    mean_us = np.maximum(np.asarray(mean_us, dtype=np.float64).ravel(),
                         1e-9)
    if weight is None:
        weight = np.full(mean_us.size, 1.0 / mean_us.size)
    else:
        weight = np.asarray(weight, dtype=np.float64).ravel()
        weight = weight / max(weight.sum(), 1e-30)
    d = float(hedge_deadline_us)

    def survival(x):
        s = _fleet_survival(x, mean_us, weight, tail_prob, tail_scale_us)
        if d > 0.0:
            over = np.maximum(np.asarray(x, dtype=np.float64) - d, 0.0)
            partner = _fleet_survival(over, mean_us, weight, tail_prob,
                                      tail_scale_us)
            s = np.where(np.asarray(x) > d, s * partner, s)
        return s

    target = 1.0 - q
    hi = float(np.max(mean_us) + tail_scale_us) * 4.0 + max(d, 0.0) + 1.0
    for _ in range(200):
        if float(survival(hi)) < target:
            break
        hi *= 2.0
    lo = 0.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if float(survival(mid)) > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
