"""Unified run statistics for every execution backend.

One ``RunStats`` dataclass is produced by the discrete-event simulator,
the threaded ``Runtime``, and the serving server, so policies and
workloads can be compared apples-to-apples across backends (and the old
``PollerStats``/``ServerStats``/``SimResult`` views become thin aliases
or conversions of this).

``Reservoir`` is a bounded uniform sample: long-running servers record
latency forever without unbounded memory growth (each of the first
``capacity`` values is kept; afterwards value *n* replaces a random slot
with probability capacity/n — the classic Algorithm R invariant, every
value seen has equal probability of being in the sample).
"""

from __future__ import annotations

import random
import warnings
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Reservoir", "QueueStats", "RunStats"]


class Reservoir:
    """Bounded uniform reservoir sample of a float stream (Algorithm R).

    Quacks enough like a list (len/iter/bool/__array__/extend/append)
    that existing consumers — ``np.median(stats.latency_samples_us)``,
    truthiness guards — keep working unchanged.
    """

    __slots__ = ("capacity", "count", "_buf", "_rng", "_np_rng")

    def __init__(self, capacity: int = 65_536, seed: int = 0):
        if capacity < 1:
            raise ValueError("Reservoir capacity must be >= 1")
        self.capacity = capacity
        self.count = 0              # total values ever offered
        self._buf: list[float] = []
        self._rng = random.Random(seed)
        self._np_rng = np.random.default_rng(seed)

    def append(self, value: float) -> None:
        self.count += 1
        if len(self._buf) < self.capacity:
            self._buf.append(float(value))
            return
        j = self._rng.randrange(self.count)
        if j < self.capacity:
            self._buf[j] = float(value)

    def extend(self, values) -> None:
        if not isinstance(values, (list, tuple, np.ndarray)):
            for v in values:            # generators: no length to batch on
                self.append(v)
            return
        arr = np.asarray(values, dtype=np.float64).ravel()
        if arr.size == 0:
            return
        free = self.capacity - len(self._buf)
        if free > 0:                    # fill phase, no randomness needed
            take = min(free, arr.size)
            self._buf.extend(arr[:take].tolist())
            self.count += take
            arr = arr[take:]
        if arr.size == 0:
            return
        # bulk Algorithm R: value #k replaces a random slot iff
        # randrange(k) < capacity — one vectorized draw for the batch
        ks = np.arange(self.count + 1, self.count + arr.size + 1)
        self.count += arr.size
        js = (self._np_rng.random(arr.size) * ks).astype(np.int64)
        hit = js < self.capacity
        for j, v in zip(js[hit].tolist(), arr[hit].tolist()):
            self._buf[j] = v            # in order: later values win ties

    def __len__(self) -> int:
        return len(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def __getitem__(self, i):
        return self._buf[i]

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self._buf, dtype=dtype or np.float64)

    def __repr__(self) -> str:
        return (f"Reservoir(n={len(self._buf)}/{self.capacity}, "
                f"seen={self.count})")


def _empty() -> np.ndarray:
    return np.empty(0)


@dataclass
class QueueStats:
    """Per-Rx-queue slice of a run's counters.  Every field sums to the
    matching ``RunStats`` total across ``RunStats.per_queue`` (the
    conservation law the multi-queue refactor is tested against)."""

    queue: int
    offered: int = 0
    dropped: int = 0
    serviced: int = 0
    busy_tries: int = 0
    cycles: int = 0

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)


@dataclass
class RunStats:
    """One result type for sim / threads / server runs.

    Time bookkeeping is in nanoseconds (``awake_ns`` over
    ``stopped_ns - started_ns``) so the real-thread backends can feed
    ``time.thread_time_ns`` straight in; the simulator converts its
    microsecond clock once at the end.  Cycle-level arrays
    (vacations/busies/backlogs, adaptation time series) are only
    populated by the simulator — real threads would pay too much for
    them on the hot path.
    """

    backend: str = ""                 # "sim" | "threads" | "server"
    policy: str = ""
    workload: str = ""

    wakeups: int = 0
    cycles: int = 0                   # busy periods won (lock taken)
    busy_tries: int = 0               # failed trylocks (backup wakes)
    items: int = 0                    # packets / requests serviced
    offered: int = 0
    dropped: int = 0

    awake_ns: int = 0
    started_ns: int = 0
    stopped_ns: int = 0

    latency_us: Reservoir = field(default_factory=Reservoir)
    # analytic backends (the busy-poll fluid model) report closed-form
    # latency summaries instead of samples
    latency_override: dict | None = None
    # real-time replay only: worst lateness of the arrival generator vs
    # the workload's schedule.  >> mean inter-arrival gap means the host
    # could not source the workload and the run is NOT sim-comparable.
    feeder_lag_us: float = 0.0

    # multi-queue ingress: one entry per Rx queue (empty when the backend
    # does not break its counters down, e.g. the spin fluid model)
    per_queue: list[QueueStats] = field(default_factory=list)
    # simulator: busy periods cut short by the drain round cap, stranding
    # backlog until the next wake — nonzero means saturated cycles whose
    # service was deferred, and summary() warns about it
    drain_truncations: int = 0

    # simulator-only cycle samples and adaptation series
    vacations_us: np.ndarray = field(default_factory=_empty)
    busies_us: np.ndarray = field(default_factory=_empty)
    n_v: np.ndarray = field(default_factory=_empty)
    rho_series: np.ndarray = field(default_factory=_empty)
    ts_series: np.ndarray = field(default_factory=_empty)
    tput_series_mpps: np.ndarray = field(default_factory=_empty)
    offered_series_mpps: np.ndarray = field(default_factory=_empty)
    series_t_us: np.ndarray = field(default_factory=_empty)

    # -- derived ---------------------------------------------------------------
    @property
    def duration_ns(self) -> int:
        return max(self.stopped_ns - self.started_ns, 1)

    @property
    def cpu_fraction(self) -> float:
        """Sum of thread awake time over wall duration (can exceed 1.0)."""
        return self.awake_ns / self.duration_ns

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)

    @property
    def serviced(self) -> int:
        return self.items

    # legacy PollerStats / ServerStats spellings
    @property
    def busy_periods(self) -> int:
        return self.cycles

    @property
    def latency_samples_us(self) -> Reservoir:
        return self.latency_us

    @property
    def retrieval_lat_us(self) -> Reservoir:
        return self.latency_us

    # latency summaries (empty-safe, like the old SimResult defaults)
    @property
    def mean_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["mean"]
        return float(np.mean(self.latency_us)) if self.latency_us else 0.0

    @property
    def p99_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["p99"]
        return (float(np.percentile(np.asarray(self.latency_us), 99))
                if self.latency_us else 0.0)

    @property
    def worst_latency_us(self) -> float:
        if self.latency_override:
            return self.latency_override["worst"]
        return float(np.max(np.asarray(self.latency_us))) if self.latency_us else 0.0

    @property
    def mean_vacation_us(self) -> float:
        return float(np.mean(self.vacations_us)) if self.vacations_us.size else 0.0

    @property
    def mean_busy_us(self) -> float:
        return float(np.mean(self.busies_us)) if self.busies_us.size else 0.0

    @property
    def mean_nv(self) -> float:
        return float(np.mean(self.n_v)) if self.n_v.size else 0.0

    def summary(self) -> dict:
        """Flat dict of the headline numbers (benchmark CSV rows, logs)."""
        if self.drain_truncations:
            warnings.warn(
                f"{self.drain_truncations} busy period(s) hit the drain "
                "round cap and stranded backlog until the next wake; "
                "service/latency numbers understate the saturation",
                RuntimeWarning, stacklevel=2)
        return {
            "backend": self.backend, "policy": self.policy,
            "workload": self.workload, "wakeups": self.wakeups,
            "cycles": self.cycles, "busy_tries": self.busy_tries,
            "serviced": self.items, "offered": self.offered,
            "dropped": self.dropped, "loss_fraction": self.loss_fraction,
            "cpu_fraction": self.cpu_fraction,
            "mean_latency_us": self.mean_latency_us,
            "p99_latency_us": self.p99_latency_us,
            "n_queues": max(len(self.per_queue), 1),
            "drain_truncations": self.drain_truncations,
        }
