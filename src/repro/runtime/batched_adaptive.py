"""Adaptive macro-slot (event-jump) variant of the batched JAX engine.

The fixed-slot kernel in ``batched.py`` spends compute proportional to
*simulated time*: at the paper's own operating points (T_S ~ 50us,
threads asleep most of the time) ~99% of its 0.5us scan steps advance a
slot in which no thread wakes and no queue drains.  This module applies
Metronome's thesis to the simulator itself — skip the sleep: each scan
step advances a *variable* ``dt`` equal to the distance to the next
"interesting" boundary, all computed in-scan with pure array ops:

  - the earliest pending thread wake (``min`` over sleeping timers);
  - the predicted drain-out of any locked queue
    (``backlog / (mu - lam_q)`` — a thread-release point), and the
    predicted fill-to-capacity of any queue whose net inflow is
    positive (the point where drops start and the backlog path stops
    being linear);
  - the current load-schedule segment's end (the arrival rate is
    constant inside a macro-slot by construction);
  - the next ``window_us`` edge (each macro-slot lands in one window);
  - the next correlated-stall start (the Poisson stall process is
    sampled event-style: the next start time is an Exp(1/rate)
    inter-arrival carried in the scan state, instead of a per-slot
    Bernoulli — same process, exact inter-arrival law);
  - the end of the run.

The per-slot update generalizes to closed-form multi-slot aggregates:

  - *arrivals*: the residual-carried Gaussian fluid scales exactly —
    one draw of variance ``lam*dt`` has the same law as the sum of
    ``dt/slot_us`` per-slot draws, so the process (and the PRNG *rate*
    contract: one fresh draw per macro-slot, overshoot/interference
    charged only on re-arm events) is preserved;
  - *drain*: ``min(backlog + admitted, mu*dt)`` per locked queue —
    closed-form-exact because drain-out times are themselves jump
    boundaries, so no locked queue empties strictly inside a macro-slot
    (admission concurrently frees ring room: ``room = capacity -
    backlog + mu*dt`` on locked queues, the continuous-admission
    semantics the event engine has and fixed slots approximate);
  - *latency area*: the trapezoid ``(B0 + B1)/2 * dt`` — exact for the
    piecewise-linear fluid backlog path between boundaries (the
    fixed-slot engine's end-of-slot rectangle is the dt -> 0 limit);
  - *vacations*: unlocked queues accumulate the full ``dt`` before
    claims are processed at the macro-slot's end boundary, so a
    harvested vacation includes its final partial interval (exact,
    where the fixed-slot engine loses the claim slot's fraction).

Scan length is ``O(#wakes + #busy periods + #schedule segments +
#windows + #stalls)`` instead of ``duration_us / slot_us`` — bounded
ahead of time by ``estimate_adaptive_steps`` (a conservative per-point
wake budget), bucketed to the same geometric ladder as the fixed
engine's slot count, and never above the fixed engine's own scan
length.  Two safety valves make the static bound safe rather than
truncating:

  - ``dt`` is floored at ``slot_us`` (adaptive rarely steps finer than
    fixed) — but the floor yields to the nearest wake or drain-out
    boundary: stepping past a wake coalesces two claims into one and
    turns the loser into a T_L-parked busy try, and stretching a
    sub-slot residual drain holds the queue lock past its true empty
    time; either bias feeds the busy-try/parking loop and suppresses
    the wake rate ~25% at m > 1.  Inside the reserved tail (last
    eighth) of the step budget ``remaining / steps_left`` pacing
    guarantees every point reaches ``duration_us`` exactly (the final
    live step takes ``dt = remaining``, so "sum of dt == duration" is
    exact, not approximate), degrading to coarser-than-boundary jumps
    instead of ending early if the budget was underestimated;
  - every step where that floor overrode the boundary distance by more
    than the wake epsilon is counted in ``forced_steps``
    (``BatchStats.forced_steps``), so budget pressure is measurable
    instead of silent.

Where adaptive is approximate where fixed-slot is not: a forced jump
can cross a wake / segment / stall boundary (the wake still happens —
late, with the timer residual carried into the next sleep, so long-run
wake *rates* stay unbiased, the same bias correction the fixed engine
applies to slot quantization); at most one stall window opens per
macro-slot; a queue whose drain-out boundary lands inside the step
takes its arrivals deterministically for that step (a noisy draw there
is one-sided — a positive residual extends the busy period while a
negative one cannot shorten it, since release happens at the boundary
either way — so suppressing it keeps busy-period lengths, and through
them the wake rate, unbiased); and a queue whose stochastic arrivals
deviate from the fluid prediction mid-jump has its trapezoid latency
area off by the deviation (drops themselves stay exact: admission is
capacity-clamped every step).  Parity is the acceptance bar, pinned in
tests/test_batched_engine.py: adaptive-vs-event within the same
documented bands the fixed engine holds (max(1.5us, 12%) latency /
0.02 + 5% CPU quiet; max(4.5us, 22%) / 0.025 + 6% / loss 0.03 noisy),
and adaptive-vs-fixed within those bands on the same 24 + 16 random
configurations.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .simcore import SimRunConfig

__all__ = ["estimate_adaptive_steps", "adaptive_sweep_arrays"]

# SimRunConfig fields this engine deliberately does NOT read — the same
# declarations batched.py makes, proven complete by the engine-parity
# static check (repro.analysis PARITY001/002, which treats this module
# exactly like batched.py):
#   - grid-supplied: seed / n_queues come per-point from the SweepGrid
#     row (the grid axis IS the sweep surface);
#   - event-engine-only: binned time series stay with simulate_run
#     (validate_batched_config rejects them before this module runs);
#   - sample-path detail: no latency reservoir exists in a fluid engine.
_GRID_SUPPLIED_FIELDS = ("seed", "n_queues")
_EVENT_ENGINE_ONLY_FIELDS = ("timeseries_bin_us",)
_NO_SAMPLE_PATH_FIELDS = ("latency_reservoir",)

_WAKE_EPS_US = 1e-6      # timer-expiry threshold after a macro-jump
_RATE_EPS = 1e-9         # net-rate floor for drain/fill boundaries
_FILL_SLACK_PKTS = 1.0   # stop chasing the fill boundary this close to cap


class _AdaptiveStats(NamedTuple):
    offered: jnp.ndarray
    dropped: jnp.ndarray
    serviced: jnp.ndarray
    wakeups: jnp.ndarray
    busy_tries: jnp.ndarray
    cycles: jnp.ndarray
    awake_us: jnp.ndarray
    lat_area: jnp.ndarray
    vac_sum: jnp.ndarray
    nv_sum: jnp.ndarray
    ts_arms: jnp.ndarray       # T_S-class sleeps armed (empty + release)
    energy_uj: jnp.ndarray     # EnergyModel charge (active + arms)
    n_steps: jnp.ndarray
    forced_steps: jnp.ndarray


def estimate_adaptive_steps(grid, cfg: SimRunConfig, slot_us: float,
                            n_windows: int) -> int:
    """Conservative scan-length budget for the adaptive kernel.

    Boundaries per point: each thread wake is one step, and each busy
    period ends in exactly one drain-out step (the drain-boundary step
    takes its arrivals deterministically, so the prediction lands on
    empty with no corrective jumps), so ``3x`` the wake budget
    ``m * duration / primary_cycle`` covers wake + drain + fill + claim
    slack; schedule segments, window edges and the expected stall-start
    count add linearly.  Clamped at the fixed engine's own slot count —
    adaptive never scans more than fixed would have."""
    sm = cfg.sleep_model
    ts_us = np.maximum(np.asarray(grid.t_s_us, dtype=np.float64),
                       2.0 * slot_us)
    cycle = ts_us * (1.0 + sm.slope) + sm.base_us
    wake_budget = (np.asarray(grid.m, dtype=np.float64)
                   * cfg.duration_us / cycle)
    n_bound = 0
    scheds = list(grid.schedules) if grid.schedules else []
    if cfg.schedule is not None:
        scheds.append(cfg.schedule)
    for s in scheds:
        if s is not None:
            n_bound = max(n_bound, len(s.jump_boundaries(cfg.duration_us)))
    extras = (n_bound + n_windows
              + cfg.stall_rate_per_us * cfg.duration_us + 64)
    n_slots = max(int(math.ceil(cfg.duration_us / slot_us)), 1)
    return int(min(math.ceil(3.0 * float(wake_budget.max()) + extras),
                   n_slots))


def _build_adaptive_sweep(max_steps: int, slot_us: float, m_max: int,
                          q_max: int, mu: float, capacity: float,
                          wake_cost_us: float, sleep_params: tuple,
                          interference_params: tuple,
                          energy_params: tuple, n_seg: int = 0,
                          n_windows: int = 0, window_us: float = 0.0):
    """Build + jit the vmapped event-jump kernel for one static shape.

    Same static surface as ``batched._build_sweep`` (the two engines
    compile from the same grid preparation), except the scan length is
    the adaptive step *budget* rather than the slot count, and
    ``duration`` is a traced per-point input: steps after a point
    reaches its duration are dt=0 no-ops (carry held via a live mask),
    which is also what lets one step budget be shared across a vmapped
    batch and across the bucketing ladder."""
    from .batched import energy_arm_cost
    base_us, slope, sigma_us, tail_prob, tail_mean_us = sleep_params
    intf_prob, intf_mean_us, stall_rate, stall_mean_us = interference_params
    active_power_w, _dvfs_scale, e_states = energy_params
    t_idx = jnp.arange(m_max)
    q_idx = jnp.arange(q_max)
    floor_us = slot_us

    def one_point(t_s, t_l, m, nq, lam, seed_lo, seed_hi, duration,
                  sched_edges, sched_scales):
        tmask = t_idx < m
        qmask = q_idx < nq
        # per-arm C-state charges are point constants (the target, not
        # the realized vacancy, selects the state — see EnergyModel)
        e_arm_s = energy_arm_cost(t_s, e_states)
        e_arm_l = energy_arm_cost(t_l, e_states)

        # both 32-bit halves of the 64-bit seed are folded in, so seeds
        # differing only in their high bits stay independent
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), seed_lo), seed_hi)
        key, k0 = jax.random.split(key)
        # active launch (event-engine convention): first wakes land
        # uniformly inside one primary timeout
        sleep0 = jax.random.uniform(k0, (m_max,)) * t_s
        sleep0 = jnp.where(tmask, jnp.maximum(sleep0, floor_us), jnp.inf)
        if stall_rate > 0.0:
            key, kst = jax.random.split(key)
            next_stall0 = jax.random.exponential(kst, ()) / stall_rate
        else:
            next_stall0 = jnp.float32(jnp.inf)

        def step(carry, t):
            prev = carry
            (sleep_rem, attached, backlog, vac_timer, arr_res, stall_end,
             next_stall, rem_t, A, win_acc) = carry
            now = duration - rem_t
            live = rem_t > 0.0
            kt_step = jax.random.fold_in(key, t)
            if tail_prob > 0.0:
                kt_step, kp, ku = jax.random.split(kt_step, 3)
            if intf_prob > 0.0:
                kt_step, kip, kie = jax.random.split(kt_step, 3)
            if stall_rate > 0.0:
                kt_step, kse, ksg, ksu = jax.random.split(kt_step, 4)
            # one fused normal draw covers arrivals + sleep noise
            zs = jax.random.normal(kt_step, (q_max + m_max,))

            # ---- the jump: distance to the next interesting boundary
            sleeping = tmask & (attached < 0)
            occ = (jax.nn.one_hot(attached, q_max).sum(axis=0) > 0)
            wake_dt = jnp.min(jnp.where(
                sleeping, jnp.maximum(sleep_rem, 0.0), jnp.inf))
            if n_seg > 0:
                si = jnp.clip(
                    jnp.searchsorted(sched_edges, now, side="right") - 1,
                    0, n_seg - 1)
                scale_t = sched_scales[si]
                nxt_si = jnp.clip(si + 1, 0, n_seg - 1)
                seg_dt = jnp.where(si + 1 < n_seg,
                                   sched_edges[nxt_si] - now, jnp.inf)
            else:
                scale_t = jnp.float32(1.0)
                seg_dt = jnp.float32(jnp.inf)
            lam_q = jnp.where(qmask, lam * scale_t / nq, 0.0)
            # locked queues: expected time to drain out (thread release)
            net_out = jnp.where(occ, mu - lam_q, 0.0)
            drain_q = jnp.where(
                occ & (net_out > _RATE_EPS),
                jnp.maximum(backlog, 0.0)
                / jnp.maximum(net_out, _RATE_EPS), jnp.inf)
            drain_dt = jnp.min(drain_q)
            # filling queues: expected time to reach capacity (drops
            # start; within one packet of capacity the path is flat at
            # cap and no further fill chasing is needed)
            net_in = lam_q - jnp.where(occ, mu, 0.0)
            fill_dt = jnp.min(jnp.where(
                qmask & (net_in > _RATE_EPS)
                & (backlog < capacity - _FILL_SLACK_PKTS),
                (capacity - backlog) / jnp.maximum(net_in, _RATE_EPS),
                jnp.inf))
            if n_windows > 0:
                win_dt = ((jnp.floor(now / window_us) + 1.0) * window_us
                          - now)
            else:
                win_dt = jnp.float32(jnp.inf)
            stall_dt = next_stall - now
            dt_b = jnp.minimum(
                jnp.minimum(jnp.minimum(wake_dt, drain_dt),
                            jnp.minimum(fill_dt, seg_dt)),
                jnp.minimum(jnp.minimum(win_dt, stall_dt), rem_t))
            # completion guard: only inside the reserved tail (the last
            # eighth of the budget) is the remaining-average pace
            # enforced — with an adequate budget rem hits 0 long before
            # the tail and no jump is ever distorted; with a short one
            # the tail sweeps to the end at the average pace instead of
            # truncating.  (Enforcing rem/steps_left from step 0 would
            # coarsen every boundary finer than the whole-run average.)
            steps_left = jnp.float32(max_steps) - t.astype(jnp.float32)
            in_tail = steps_left <= jnp.float32(max(max_steps // 8, 2))
            pace = jnp.where(in_tail, rem_t / steps_left, 0.0)
            # the floor never steps PAST a wake or a drain-out: stepping
            # past a wake coalesces two nearby wakes into one boundary
            # and turns the second thread's claim into a busy try (it
            # sees the first claimant's lock); stretching a sub-floor
            # residual drain holds the queue lock past its true empty
            # time.  Either way P(wake lands in a busy period) inflates
            # and the T_L parking feedback amplifies it ~4x at m > 1.
            # Sub-floor steps stay budget-safe: their count is bounded
            # by wakes + drain-outs, both inside the step estimate
            floor_eff = jnp.minimum(
                floor_us,
                jnp.maximum(jnp.minimum(wake_dt, drain_dt),
                            _WAKE_EPS_US))
            dt = jnp.minimum(
                jnp.maximum(dt_b, jnp.maximum(floor_eff, pace)), rem_t)
            # a floor_us clamp over a sub-slot boundary is expected
            # (adaptive never steps finer than fixed); "forced" counts
            # only genuine budget pressure from the tail pace
            forced = (dt > jnp.maximum(dt_b, floor_us) + _WAKE_EPS_US) \
                & live
            t_new = now + dt

            # 1. arrivals over dt: residual-carried Gaussian fluid ~
            # Poisson — one draw of variance lam*dt per queue; admission
            # is concurrent with drain on locked queues (ring frees as
            # it is polled), so room grows by mu*dt there.  A queue
            # whose drain boundary lands inside this step takes its
            # arrivals deterministically: a noisy draw there is one-
            # sided (a positive residual extends the busy period, a
            # negative one cannot shorten it — release happens at the
            # boundary either way), which stretches busy periods and
            # biases the wake rate down through the parking feedback
            drain_now = occ & (drain_q <= dt + _WAKE_EPS_US)
            mu_a = lam_q * dt
            z_q = jnp.where(drain_now, 0.0, zs[:q_max])
            raw = arr_res + mu_a + jnp.sqrt(mu_a) * z_q
            a = jnp.maximum(raw, 0.0)
            arr_res = jnp.minimum(raw, 0.0)      # deficit carried forward
            room = jnp.maximum(capacity - backlog, 0.0) \
                + jnp.where(occ, mu * dt, 0.0)
            adm = jnp.minimum(a, room)
            offered = a.sum()
            dropped = (a - adm).sum()

            # 2. drain: no locked queue empties strictly inside the
            # macro-slot (drain-out is a boundary), so min(B0 + adm,
            # mu*dt) is the exact aggregate of the per-slot updates
            serve = jnp.where(occ,
                              jnp.minimum(backlog + adm, mu * dt), 0.0)
            b_new = jnp.minimum(jnp.maximum(backlog + adm - serve, 0.0),
                                capacity)
            served = serve.sum()

            # 3. Little integral: trapezoid over the linear fluid path;
            # vacations accrue on old occupancy BEFORE end-boundary
            # claims, so a harvested vacation includes its final
            # partial interval and a queue released at this boundary
            # starts its vacation next step — both event-exact
            lat_area = 0.5 * (backlog.sum() + b_new.sum()) * dt
            vac_timer = vac_timer + jnp.where(qmask & ~occ, dt, 0.0)
            backlog = b_new

            # 4. stall process at the boundary: the carried Exp
            # inter-arrival fires when the jump reaches it; overlapping
            # windows extend (max), the event engine's lazy merge.  At
            # most one window opens per macro-slot (a forced jump past
            # two starts opens the second one step late).
            if stall_rate > 0.0:
                fire = (next_stall <= t_new) & live
                w_end = next_stall \
                    + stall_mean_us * jax.random.exponential(kse, ())
                stall_end = jnp.where(fire,
                                      jnp.maximum(stall_end, w_end),
                                      stall_end)
                gap = jax.random.exponential(ksg, ()) / stall_rate
                next_stall = jnp.where(fire, next_stall + gap, next_stall)

            # sleep overshoot + per-wake OS interference draws — one per
            # thread per macro-slot, charged only on re-arm events, the
            # same per-sleep rate contract as the fixed engine
            over = jnp.full((m_max,), base_us)
            if sigma_us > 0.0:
                over = over + sigma_us * jnp.abs(zs[q_max:])
            if tail_prob > 0.0:
                hit = jax.random.uniform(kp, (m_max,)) < tail_prob
                over = over + hit * tail_mean_us * jax.random.exponential(
                    ku, (m_max,))
            if intf_prob > 0.0:
                ihit = jax.random.uniform(kip, (m_max,)) < intf_prob
                over = over + ihit * intf_mean_us * jax.random.exponential(
                    kie, (m_max,))
            slp_s = t_s * (1.0 + slope) + over
            slp_l = t_l * (1.0 + slope) + over

            # 5. wakes at the boundary: timers land exactly on it when
            # the wake governed the jump; the (negative) residual carry
            # keeps wake rates unbiased when a forced jump overshot
            sleep_rem = jnp.where(sleeping, sleep_rem - dt, sleep_rem)
            woken = sleeping & (sleep_rem <= _WAKE_EPS_US) & live
            if stall_rate > 0.0:
                # timers expiring inside an open stall window defer to
                # its end (+U(0,1)us re-arm jitter), not counted as wakes
                push = woken & (t_new < stall_end)
                woken = woken & ~push
                sleep_rem = jnp.where(
                    push,
                    stall_end - t_new + jax.random.uniform(ksu, (m_max,)),
                    sleep_rem)
            n_wake = woken.sum().astype(jnp.float32)

            # 6. queues drained out by the boundary release their
            # thread (fresh T_S sleep, no residual) BEFORE boundary
            # wakes classify: drain-out precedes the boundary in true
            # time, so a thread waking at the boundary must see the
            # queue free — release-after-claim would misclassify it as
            # a busy try and park it on T_L
            q_done = occ & (backlog <= 1e-6)
            att_q = jnp.clip(attached, 0, q_max - 1)
            t_done = (attached >= 0) & q_done[att_q]
            sleep_rem = jnp.where(t_done, slp_s, sleep_rem)
            attached = jnp.where(t_done, -1, attached)
            occ = occ & ~q_done

            # 7. claim loop — identical to the fixed-slot kernel's
            busy_tries = jnp.float32(0.0)
            cycles = jnp.float32(0.0)
            vac_sum = jnp.float32(0.0)
            nv_sum = jnp.float32(0.0)
            ts_arm = t_done.sum().astype(jnp.float32)
            for i in range(m_max):            # static unroll, m_max small
                w = woken[i]
                free_q = qmask & ~occ
                claimable = free_q & (backlog >= 1.0)
                qi = jnp.argmax(jnp.where(claimable, backlog, -1.0))
                do_attach = w & claimable.any()
                empty_claim = w & ~claimable.any() & free_q.any()
                eqi = jnp.argmax(free_q)      # first free (empty) queue
                blocked = w & ~free_q.any()

                claim_hot = do_attach & (q_idx == qi)
                claim_any = claim_hot | (empty_claim & (q_idx == eqi))
                vac_sum = vac_sum + (vac_timer * claim_any).sum()
                nv_sum = nv_sum + jnp.where(do_attach, backlog[qi], 0.0)
                vac_timer = jnp.where(claim_any, 0.0, vac_timer)
                cycles = cycles + (do_attach | empty_claim)
                busy_tries = busy_tries + blocked
                ts_arm = ts_arm + empty_claim
                attached = attached.at[i].set(
                    jnp.where(do_attach, qi, attached[i]))
                occ = occ | claim_hot
                # re-sleep adds onto the expired-timer residual
                sleep_rem = sleep_rem.at[i].add(
                    jnp.where(empty_claim, slp_s[i],
                              jnp.where(blocked, slp_l[i], 0.0)))

            rem_t = rem_t - dt
            # energy: active power over this step's awake time plus the
            # per-arm C-state charges (blocked wakes re-arm T_L)
            awake_step = n_wake * wake_cost_us + served / mu
            energy_step = (active_power_w * awake_step
                           + ts_arm * e_arm_s + busy_tries * e_arm_l)
            A = _AdaptiveStats(
                offered=A.offered + offered,
                dropped=A.dropped + dropped,
                serviced=A.serviced + served,
                wakeups=A.wakeups + n_wake,
                busy_tries=A.busy_tries + busy_tries,
                cycles=A.cycles + cycles,
                awake_us=A.awake_us + awake_step,
                lat_area=A.lat_area + lat_area,
                vac_sum=A.vac_sum + vac_sum,
                nv_sum=A.nv_sum + nv_sum,
                ts_arms=A.ts_arms + ts_arm,
                energy_uj=A.energy_uj + energy_step,
                n_steps=A.n_steps + 1.0,
                forced_steps=A.forced_steps + forced.astype(jnp.float32),
            )
            if n_windows > 0:
                # window edges are jump boundaries, so a macro-slot
                # never spans windows (except forced jumps, attributed
                # to the window containing their start)
                wi = jnp.clip((now / window_us).astype(jnp.int32),
                              0, n_windows - 1)
                win_acc = win_acc.at[wi].add(jnp.stack([
                    offered, served, lat_area, awake_step, energy_step]))
            nxt = (sleep_rem, attached, backlog, vac_timer, arr_res,
                   stall_end, next_stall, rem_t, A, win_acc)
            # finished points hold their carry: every later step is a
            # no-op, so one compiled budget serves the whole batch
            gated = jax.tree_util.tree_map(
                lambda new, old: jnp.where(live, new, old), nxt, prev)
            return gated, None

        z0 = jnp.float32(0.0)
        init = (sleep0,
                jnp.full((m_max,), -1, jnp.int32),
                jnp.zeros(q_max, jnp.float32),
                jnp.zeros(q_max, jnp.float32),
                jnp.zeros(q_max, jnp.float32),
                jnp.float32(-1.0),          # stall_end: no window open
                next_stall0,
                jnp.asarray(duration, jnp.float32),
                _AdaptiveStats(z0, z0, z0, z0, z0, z0, z0, z0, z0, z0,
                               z0, z0, z0, z0),
                jnp.zeros((max(n_windows, 1), 5), jnp.float32))
        (_, _, backlog_f, _, _, _, _, rem_f, A, win_acc), _ = \
            jax.lax.scan(step, init,
                         jnp.arange(max_steps, dtype=jnp.int32))
        # duration - rem_f is the *exact* simulated time: rem is carried
        # by subtraction and the final live step takes dt = rem, so a
        # completed point has rem_f == 0.0 exactly (not approximately)
        return A, win_acc, backlog_f.sum(), duration - rem_f

    return jax.jit(jax.vmap(one_point))


# created on first use so importing this module has no registry side
# effects; the instance lives in batched.CompileCache._registry like
# every other kernel cache
_compiled_adaptive_sweep = None


def adaptive_sweep_arrays(grid, cfg: SimRunConfig, slot_us: float):
    """Run ``grid`` through the event-jump kernel.

    Returns ``(vals, win, final_backlog, sim_time_us, scan_len)`` in
    the array conventions ``simulate_batch`` assembles into
    ``BatchStats`` — this module owns the kernel; dispatch, validation
    and result packaging stay in ``batched.simulate_batch``.
    """
    # batched.py imports this module lazily inside simulate_batch and
    # this import runs only at call time, so the two modules stay
    # import-order independent
    from .batched import CompileCache, _schedule_rows, bucket_steps

    global _compiled_adaptive_sweep
    if _compiled_adaptive_sweep is None:
        _compiled_adaptive_sweep = CompileCache(
            _build_adaptive_sweep, maxsize=64,
            name="batched_adaptive._compiled_adaptive_sweep")

    n_windows = (int(math.ceil(cfg.duration_us / cfg.window_us))
                 if cfg.window_us > 0 else 0)
    m_max = int(grid.m.max())
    q_max = int(grid.n_queues.max())
    n_seg, sched_edges, sched_scales = _schedule_rows(grid, cfg)
    max_steps = bucket_steps(
        estimate_adaptive_steps(grid, cfg, slot_us, n_windows))
    sm = cfg.sleep_model
    fn = _compiled_adaptive_sweep(
        max_steps, float(slot_us), m_max, q_max,
        float(cfg.service_rate_mpps), float(cfg.queue_capacity),
        float(cfg.wake_cost_us),
        (float(sm.base_us), float(sm.slope), float(sm.sigma_us),
         float(sm.tail_prob), float(sm.tail_mean_us)),
        (float(cfg.interference_prob), float(cfg.interference_mean_us),
         float(cfg.stall_rate_per_us), float(cfg.stall_mean_us)),
        cfg.energy_model.params(),
        n_seg, n_windows, float(cfg.window_us))
    seed64 = np.asarray(grid.seed, dtype=np.uint64)
    n = len(grid)
    out, win, back_f, simt = fn(
        jnp.asarray(grid.t_s_us, jnp.float32),
        jnp.asarray(grid.t_l_us, jnp.float32),
        jnp.asarray(grid.m, jnp.int32),
        jnp.asarray(grid.n_queues, jnp.int32),
        jnp.asarray(grid.rate_mpps, jnp.float32),
        jnp.asarray((seed64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        jnp.asarray((seed64 >> np.uint64(32)).astype(np.uint32)),
        jnp.full((n,), float(cfg.duration_us), jnp.float32),
        jnp.asarray(sched_edges, jnp.float32),
        jnp.asarray(sched_scales, jnp.float32))
    vals = {k: np.asarray(v, dtype=np.float64)
            for k, v in out._asdict().items()}
    win_np = (np.asarray(win, dtype=np.float64) if n_windows
              else np.empty(0))
    return (vals, win_np, np.asarray(back_f, dtype=np.float64),
            np.asarray(simt, dtype=np.float64), max_steps)
