"""Ingress dispatchers — *which queue* an arrival lands in.

A multi-queue NIC spreads flows across N Rx rings with an RSS hash; the
spread is rarely uniform (a handful of elephant flows pin whole rings).
A ``Dispatcher`` models that placement step for every backend:

  - the discrete-event simulator calls ``split(n, backlogs)`` to divide
    an aggregate arrival count across queues (aggregate-exact, like the
    workload itself);
  - the threaded ``Runtime`` / serving ``Server`` call
    ``pick(seq, backlogs, key=...)`` per arrival.

``reset(n_queues, rng)`` re-arms internal state before each run; the
``rng`` is the run's generator, so a dispatch pattern is reproducible
per seed.

Implementations:
  - ``RoundRobinDispatch``  uniform spread; with one queue it degenerates
    to "everything in the queue" and consumes no randomness, so single-
    queue runs reproduce the pre-multi-queue event sequence exactly;
  - ``FlowHashDispatch``    RSS emulation: flows with Zipf popularity are
    hashed to queues, so per-queue load inherits the skew of wherever
    the elephant flows land (``key=`` gives stable request affinity);
  - ``LeastLoadedDispatch`` idealized load balancer: arrivals water-fill
    the shortest backlogs (the upper bound NIC hashing can't reach).
"""

from __future__ import annotations

import zlib
from typing import Protocol, runtime_checkable

import numpy as np

__all__ = [
    "Dispatcher",
    "RoundRobinDispatch",
    "FlowHashDispatch",
    "LeastLoadedDispatch",
]


@runtime_checkable
class Dispatcher(Protocol):
    name: str

    def reset(self, n_queues: int, rng: np.random.Generator) -> None: ...

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray: ...

    def pick(self, seq: int, backlogs, key=None) -> int: ...


def _stable_hash(key) -> int:
    """Process-independent hash (``hash(str)`` is salted per process)."""
    return zlib.crc32(repr(key).encode())


class RoundRobinDispatch:
    """Uniform spread: arrival ``seq`` goes to queue ``seq % N``; aggregate
    counts are divided as evenly as possible with a rotating remainder
    cursor so no queue is systematically favored."""

    name = "round-robin"

    def __init__(self):
        self._n = 1
        self._cursor = 0

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)
        self._cursor = 0

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        out = np.full(self._n, n // self._n, dtype=np.int64)
        extra = n % self._n
        if extra:
            idx = (self._cursor + np.arange(extra)) % self._n
            out[idx] += 1
            self._cursor = (self._cursor + extra) % self._n
        return out

    def pick(self, seq: int, backlogs, key=None) -> int:
        return seq % self._n


class FlowHashDispatch:
    """RSS emulation: ``n_flows`` flows with Zipf(``zipf_s``) popularity,
    each flow hashed to one queue at ``reset`` time.  Per-queue load is
    the sum of its flows' popularity — skewed exactly the way a real RSS
    indirection table is when elephant flows land together.

    ``pick(..., key=k)`` maps ``k`` through the same flow table, so equal
    keys always reach the same queue (request/flow affinity); without a
    key a flow is drawn from the popularity distribution.
    """

    name = "flow-hash"

    def __init__(self, n_flows: int = 256, zipf_s: float = 1.2):
        if n_flows < 1:
            raise ValueError("n_flows must be >= 1")
        if zipf_s <= 0:
            raise ValueError("zipf_s must be > 0")
        self.n_flows = int(n_flows)
        self.zipf_s = float(zipf_s)
        self._n = 1
        self._flow_queue = np.zeros(self.n_flows, dtype=np.int64)
        self._flow_probs = np.full(self.n_flows, 1.0 / self.n_flows)
        self._queue_weights = np.ones(1)
        self._cum_probs = np.cumsum(self._flow_probs)
        self._rng: np.random.Generator | None = None

    @property
    def queue_weights(self) -> np.ndarray:
        """Fraction of offered load each queue receives (diagnostics)."""
        return self._queue_weights.copy()

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)
        self._rng = rng
        w = 1.0 / np.arange(1, self.n_flows + 1, dtype=np.float64) ** self.zipf_s
        self._flow_probs = w / w.sum()
        self._cum_probs = np.cumsum(self._flow_probs)
        # random flow->queue placement: the skew comes from *where* the
        # heavy flows land, exactly like a hardware indirection table
        self._flow_queue = rng.integers(0, self._n, size=self.n_flows)
        self._queue_weights = np.bincount(
            self._flow_queue, weights=self._flow_probs, minlength=self._n)

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        if n <= 0:
            return np.zeros(self._n, dtype=np.int64)
        return self._rng.multinomial(int(n), self._queue_weights)

    def pick(self, seq: int, backlogs, key=None) -> int:
        if key is not None:
            flow = _stable_hash(key) % self.n_flows
        else:
            flow = int(np.searchsorted(self._cum_probs, self._rng.random()))
            flow = min(flow, self.n_flows - 1)
        return int(self._flow_queue[flow])


class LeastLoadedDispatch:
    """Idealized balancer: each arrival joins the shortest backlog;
    aggregate counts water-fill queues toward a common level.  The
    upper bound on what queue-aware placement can achieve (real RSS
    cannot see backlogs)."""

    name = "least-loaded"

    def __init__(self):
        self._n = 1

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        if n <= 0:
            return np.zeros(self._n, dtype=np.int64)
        b = np.asarray(backlogs, dtype=np.float64)
        # water level L with sum(max(L - b, 0)) = n, found over sorted b
        s = np.sort(b)
        for k in range(1, self._n + 1):
            level = (n + s[:k].sum()) / k
            if k == self._n or level <= s[k]:
                break
        fill = np.maximum(level - b, 0.0)
        alloc = np.floor(fill).astype(np.int64)
        short = int(n - alloc.sum())
        if short > 0:
            # hand the integer remainder to the least-loaded-after-fill
            after = b + alloc
            idx = np.argsort(after, kind="stable")[:short]
            alloc[idx] += 1
        return alloc

    def pick(self, seq: int, backlogs, key=None) -> int:
        return int(np.argmin(np.asarray(backlogs)))
