"""Ingress dispatchers — *which queue* an arrival lands in.

A multi-queue NIC spreads flows across N Rx rings with an RSS hash; the
spread is rarely uniform (a handful of elephant flows pin whole rings).
A ``Dispatcher`` models that placement step for every backend:

  - the discrete-event simulator calls ``split(n, backlogs)`` to divide
    an aggregate arrival count across queues (aggregate-exact, like the
    workload itself);
  - the threaded ``Runtime`` / serving ``Server`` call
    ``pick(seq, backlogs, key=...)`` per arrival.

``reset(n_queues, rng)`` re-arms internal state before each run; the
``rng`` is the run's generator, so a dispatch pattern is reproducible
per seed.

Implementations:
  - ``RoundRobinDispatch``  uniform spread; with one queue it degenerates
    to "everything in the queue" and consumes no randomness, so single-
    queue runs reproduce the pre-multi-queue event sequence exactly;
  - ``FlowHashDispatch``    RSS emulation: flows with Zipf popularity are
    hashed to queues, so per-queue load inherits the skew of wherever
    the elephant flows land (``key=`` gives stable request affinity);
  - ``LeastLoadedDispatch`` idealized load balancer: arrivals water-fill
    the shortest backlogs (the upper bound NIC hashing can't reach);
  - ``WeightedDispatch``    weighted round-robin: fixed per-queue traffic
    shares (a fleet balancer splitting across heterogeneous replicas);
  - ``StaleLeastLoadedDispatch``  least-loaded against a backlog snapshot
    that refreshes only every ``refresh_every`` decisions — the finite-
    polling-rate balancer whose stale signal herds arrivals onto a
    replica that *was* idle (the regime the fleet tier studies).
"""

from __future__ import annotations

import zlib
from typing import Protocol, runtime_checkable

import numpy as np

__all__ = [
    "Dispatcher",
    "RoundRobinDispatch",
    "FlowHashDispatch",
    "LeastLoadedDispatch",
    "WeightedDispatch",
    "StaleLeastLoadedDispatch",
]


@runtime_checkable
class Dispatcher(Protocol):
    name: str

    def reset(self, n_queues: int, rng: np.random.Generator) -> None: ...

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray: ...

    def pick(self, seq: int, backlogs, key=None) -> int: ...


def _stable_hash(key) -> int:
    """Process-independent hash (``hash(str)`` is salted per process)."""
    return zlib.crc32(repr(key).encode())


class RoundRobinDispatch:
    """Uniform spread: arrival ``seq`` goes to queue ``seq % N``; aggregate
    counts are divided as evenly as possible with a rotating remainder
    cursor so no queue is systematically favored."""

    name = "round-robin"

    def __init__(self):
        self._n = 1
        self._cursor = 0

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)
        self._cursor = 0

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        out = np.full(self._n, n // self._n, dtype=np.int64)
        extra = n % self._n
        if extra:
            idx = (self._cursor + np.arange(extra)) % self._n
            out[idx] += 1
            self._cursor = (self._cursor + extra) % self._n
        return out

    def pick(self, seq: int, backlogs, key=None) -> int:
        return seq % self._n


class FlowHashDispatch:
    """RSS emulation: ``n_flows`` flows with Zipf(``zipf_s``) popularity,
    each flow hashed to one queue at ``reset`` time.  Per-queue load is
    the sum of its flows' popularity — skewed exactly the way a real RSS
    indirection table is when elephant flows land together.

    ``pick(..., key=k)`` maps ``k`` through the same flow table, so equal
    keys always reach the same queue (request/flow affinity); without a
    key a flow is drawn from the popularity distribution.
    """

    name = "flow-hash"

    def __init__(self, n_flows: int = 256, zipf_s: float = 1.2):
        if n_flows < 1:
            raise ValueError("n_flows must be >= 1")
        if zipf_s <= 0:
            raise ValueError("zipf_s must be > 0")
        self.n_flows = int(n_flows)
        self.zipf_s = float(zipf_s)
        self._n = 1
        self._flow_queue = np.zeros(self.n_flows, dtype=np.int64)
        self._flow_probs = np.full(self.n_flows, 1.0 / self.n_flows)
        self._queue_weights = np.ones(1)
        self._cum_probs = np.cumsum(self._flow_probs)
        self._rng: np.random.Generator | None = None

    @property
    def queue_weights(self) -> np.ndarray:
        """Fraction of offered load each queue receives (diagnostics)."""
        return self._queue_weights.copy()

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)
        self._rng = rng
        w = 1.0 / np.arange(1, self.n_flows + 1, dtype=np.float64) ** self.zipf_s
        self._flow_probs = w / w.sum()
        self._cum_probs = np.cumsum(self._flow_probs)
        # random flow->queue placement: the skew comes from *where* the
        # heavy flows land, exactly like a hardware indirection table
        self._flow_queue = rng.integers(0, self._n, size=self.n_flows)
        self._queue_weights = np.bincount(
            self._flow_queue, weights=self._flow_probs, minlength=self._n)

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        if n <= 0:
            return np.zeros(self._n, dtype=np.int64)
        return self._rng.multinomial(int(n), self._queue_weights)

    def pick(self, seq: int, backlogs, key=None) -> int:
        if key is not None:
            flow = _stable_hash(key) % self.n_flows
        else:
            flow = int(np.searchsorted(self._cum_probs, self._rng.random()))
            flow = min(flow, self.n_flows - 1)
        return int(self._flow_queue[flow])


class LeastLoadedDispatch:
    """Idealized balancer: each arrival joins the shortest backlog;
    aggregate counts water-fill queues toward a common level.  The
    upper bound on what queue-aware placement can achieve (real RSS
    cannot see backlogs)."""

    name = "least-loaded"

    def __init__(self):
        self._n = 1

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        if n <= 0:
            return np.zeros(self._n, dtype=np.int64)
        b = np.asarray(backlogs, dtype=np.float64)
        # water level L with sum(max(L - b, 0)) = n, found over sorted b
        s = np.sort(b)
        for k in range(1, self._n + 1):
            level = (n + s[:k].sum()) / k
            if k == self._n or level <= s[k]:
                break
        fill = np.maximum(level - b, 0.0)
        alloc = np.floor(fill).astype(np.int64)
        short = int(n - alloc.sum())
        if short > 0:
            # hand the integer remainder to the least-loaded-after-fill
            after = b + alloc
            idx = np.argsort(after, kind="stable")[:short]
            alloc[idx] += 1
        return alloc

    def pick(self, seq: int, backlogs, key=None) -> int:
        return int(np.argmin(np.asarray(backlogs)))


class WeightedDispatch:
    """Weighted round-robin: queue i receives a fixed ``weights[i]``
    share of arrivals.  Aggregate counts split by largest remainder
    (deterministic, so equal-seed runs reproduce); per-arrival picks
    walk the cumulative weights with a rotating fractional cursor, the
    classic smooth-WRR spread without bursts onto one queue."""

    name = "weighted"

    def __init__(self, weights):
        w = np.asarray(weights, dtype=np.float64)
        if w.size < 1 or np.min(w) <= 0:
            raise ValueError("weights must be positive and non-empty")
        self._weights = w / w.sum()
        self._cum = np.cumsum(self._weights)
        self._n = w.size
        self._frac = np.zeros(w.size)

    @property
    def queue_weights(self) -> np.ndarray:
        return self._weights.copy()

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        if int(n_queues) != self._n:
            raise ValueError(
                f"WeightedDispatch built for {self._n} queues, "
                f"run has {n_queues}")
        self._frac = np.zeros(self._n)

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        if n <= 0:
            return np.zeros(self._n, dtype=np.int64)
        # carry fractional credit across calls so small batches still
        # honor the shares in the long run
        ideal = n * self._weights + self._frac
        alloc = np.floor(ideal).astype(np.int64)
        short = int(n - alloc.sum())
        if short > 0:
            idx = np.argsort(-(ideal - alloc), kind="stable")[:short]
            alloc[idx] += 1
        self._frac = ideal - alloc
        return alloc

    def pick(self, seq: int, backlogs, key=None) -> int:
        # deterministic low-discrepancy walk over the cumulative shares
        u = ((seq + 0.5) * 0.6180339887498949) % 1.0
        return int(np.searchsorted(self._cum, u, side="right")
                   .clip(0, self._n - 1))


class StaleLeastLoadedDispatch:
    """Least-loaded routing on a *stale* backlog signal: the snapshot the
    decisions use refreshes only every ``refresh_every`` dispatch calls,
    modeling a balancer that polls replica queue depths at a finite
    rate.  ``refresh_every=1`` degenerates to ``LeastLoadedDispatch``
    exactly; large values reproduce the herd-to-the-idle-replica
    misbehavior of real stale-signal balancers."""

    name = "stale-least-loaded"

    def __init__(self, refresh_every: int = 64):
        if refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")
        self.refresh_every = int(refresh_every)
        self._fresh = LeastLoadedDispatch()
        self._n = 1
        self._snapshot = np.zeros(1)
        self._calls = 0

    def reset(self, n_queues: int, rng: np.random.Generator) -> None:
        self._n = int(n_queues)
        self._fresh.reset(n_queues, rng)
        self._snapshot = np.zeros(self._n)
        self._calls = 0

    def _maybe_refresh(self, backlogs) -> None:
        if self._calls % self.refresh_every == 0:
            self._snapshot = np.asarray(backlogs, dtype=np.float64).copy()
        self._calls += 1

    def split(self, n: int, backlogs: np.ndarray) -> np.ndarray:
        self._maybe_refresh(backlogs)
        out = self._fresh.split(n, self._snapshot)
        # decisions feed back into the *snapshot* (the balancer knows
        # what it sent), just not into the true backlogs it cannot see
        self._snapshot = self._snapshot + out
        return out

    def pick(self, seq: int, backlogs, key=None) -> int:
        self._maybe_refresh(backlogs)
        q = int(np.argmin(self._snapshot))
        self._snapshot[q] += 1.0
        return q
