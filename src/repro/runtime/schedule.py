"""Load schedules — *how the offered load changes over time*.

Every workload the runtime knew before this module was stationary: a
Poisson/CBR/on-off/trace-replay process whose long-run rate never moves.
Metronome's central claim, though, is *adaptive* retrieval — the Eq-10
EWMA load estimate drives the Eq-12 timeout so CPU tracks the offered
load — and a closed loop can only be judged against a load that
actually changes.  A ``LoadSchedule`` is a deterministic, dimensionless
rate multiplier ``scale(t)`` applied on top of any base ``Workload``:

  - ``StepSchedule``      piecewise-constant steps (paper Fig 11's
                          load steps), also the compiled form every
                          other schedule reduces to;
  - ``RampSchedule``      linear ramp discretized into a staircase;
  - ``SinusoidSchedule``  periodic diurnal-style modulation
                          (staircase-sampled, exactly periodic);
  - ``MMPPSchedule``      Markov-modulated segments: exponential dwell
                          times between random scale states, pre-
                          materialized from a private seed so both
                          engines replay the identical sample path;
  - ``from_trace``        a measured (timestamp, rate) series turned
                          into a step schedule.

All schedules are piecewise-constant by construction (``segments``)
which gives every consumer the same view:

  - the event engine (``repro.runtime.sim``) and the threaded
    ``Runtime`` modulate any base workload via *time warping*
    (``ScheduledWorkload`` in workload.py): the base process is run on
    the warped clock ``W(t) = ∫ scale`` — for Poisson this is exactly
    the inhomogeneous-rate process, for CBR/trace it is the natural
    speed-up/slow-down;
  - the batched JAX engine (``repro.runtime.batched``) evaluates
    ``scale(t)`` per ``lax.scan`` slot from the ``(edges, scales)``
    arrays — vmappable, so a ``SweepGrid`` can carry a different
    schedule per point;
  - ``transitions()`` names the times where the offered load changes
    regime — the anchor points ``TrackingStats`` measures convergence
    against.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "LoadSchedule",
    "StepSchedule",
    "RampSchedule",
    "SinusoidSchedule",
    "MMPPSchedule",
    "from_trace",
]


class LoadSchedule:
    """Base: a piecewise-constant, non-negative rate multiplier.

    Subclasses provide ``_materialize(until_us) -> (edges, scales)``
    with ``edges[0] == 0``, edges strictly increasing and covering
    ``[0, until_us]``; everything else (point lookup, integral, warp
    inverse, per-slot sampling) is derived here, identically for every
    schedule kind.
    """

    name = "schedule"

    def _materialize(self, until_us: float):
        raise NotImplementedError

    def _cum(self, until_us: float):
        """Cached ``(edges, scales, cum)`` with ``cum[i]`` = integral up
        to ``edges[i]``.  The warp lookups (``integral`` /
        ``inverse_integral`` / ``scale_at``) sit on the event engine's
        per-event path, so they answer from this cache with a binary
        search instead of re-materializing arrays and re-running a
        cumsum on every call; the cache rebuilds (geometrically grown)
        only when a lookup reaches past the materialized horizon."""
        if (getattr(self, "_cum_cache", None) is not None
                and self._cum_until >= until_us):
            return self._cum_cache
        until = max(float(until_us), 2.0 * getattr(self, "_cum_until", 0.0))
        edges, scales = self._materialize(until)
        edges = np.asarray(edges, dtype=np.float64)
        scales = np.asarray(scales, dtype=np.float64)
        cum = np.concatenate(
            [[0.0], np.cumsum(np.diff(edges) * scales[:-1])])
        object.__setattr__(self, "_cum_cache", (edges, scales, cum))
        object.__setattr__(self, "_cum_until",
                           max(until, float(edges[-1])))
        return self._cum_cache

    # -- point / window lookups -----------------------------------------------
    def scale_at(self, t_us: float) -> float:
        edges, scales, _ = self._cum(max(t_us, 0.0) + 1e-9)
        i = int(np.searchsorted(edges, t_us, side="right")) - 1
        return float(scales[min(max(i, 0), len(scales) - 1)])

    def mean_scale(self, t0_us: float, t1_us: float) -> float:
        if t1_us <= t0_us:
            return self.scale_at(t0_us)
        return (self.integral(t1_us) - self.integral(t0_us)) / (t1_us - t0_us)

    # -- warped clock ----------------------------------------------------------
    def integral(self, t_us: float) -> float:
        """W(t) = ∫_0^t scale(u) du — the warped clock a base workload
        runs on (piecewise linear, exactly invertible)."""
        t_us = max(float(t_us), 0.0)
        edges, scales, cum = self._cum(t_us + 1e-9)
        i = int(np.searchsorted(edges, t_us, side="right")) - 1
        i = min(max(i, 0), len(scales) - 1)
        return float(cum[i] + (t_us - edges[i]) * scales[i])

    def inverse_integral(self, w_us: float, *, hint_until_us: float = 1e6):
        """W^{-1}(w): real time at which the warped clock reads ``w``
        (left edge of any zero-scale plateau)."""
        w_us = max(float(w_us), 0.0)
        until = max(hint_until_us, 1.0)
        for _ in range(64):        # geometric growth, bounded
            edges, scales, cum = self._cum(until)
            total = cum[-1] + max(until - float(edges[-1]), 0.0) \
                * float(scales[-1])
            if total >= w_us or scales[-1] <= 0.0:
                break
            until *= 2.0
        i = int(np.searchsorted(cum, w_us, side="left")) - 1
        i = min(max(i, 0), len(scales) - 1)
        s = float(scales[i])
        if s <= 0.0:
            return float(edges[i])
        return float(edges[i] + (w_us - cum[i]) / s)

    # -- compiled forms --------------------------------------------------------
    def segments(self, duration_us: float) -> tuple[np.ndarray, np.ndarray]:
        """``(edges, scales)`` covering ``[0, duration_us]`` —
        ``scale(t) = scales[searchsorted(edges, t, 'right') - 1]``."""
        edges, scales = self._materialize(duration_us)
        keep = edges < duration_us
        keep[0] = True
        return (np.asarray(edges[keep], dtype=np.float64),
                np.asarray(scales[keep], dtype=np.float64))

    def compiled(self, duration_us: float,
                 max_segments: int = 256) -> tuple[np.ndarray, np.ndarray]:
        """Fixed-width ``(edges, scales)`` of exactly ``max_segments``
        entries (last segment repeated as padding) — the vmappable form
        the batched engine consumes, one row per ``SweepGrid`` point."""
        edges, scales = self.segments(duration_us)
        if edges.size > max_segments:
            # resample on an even grid — schedules denser than the cap
            # are flattened to their window means
            grid = np.linspace(0.0, duration_us, max_segments,
                               endpoint=False)
            vals = [self.mean_scale(t, t + duration_us / max_segments)
                    for t in grid]
            return grid, np.asarray(vals, dtype=np.float64)
        pad = max_segments - edges.size
        return (np.concatenate([edges, np.full(pad, duration_us + 1.0)
                                + np.arange(pad)]),
                np.concatenate([scales, np.full(pad, scales[-1])]))

    def transitions(self, duration_us: float) -> tuple[float, ...]:
        """Times (excluding 0) where the offered load changes regime —
        what ``TrackingStats`` measures convergence against.  Default:
        every interior segment edge with a scale change."""
        edges, scales = self.segments(duration_us)
        out = [float(e) for e, a, b in
               zip(edges[1:], scales[1:], scales[:-1], strict=True)
               if a != b]
        return tuple(out)

    def jump_boundaries(self, duration_us: float) -> np.ndarray:
        """Interior segment edges in ``(0, duration_us)`` — the times an
        event-jump (adaptive macro-slot) kernel must not step across,
        because the arrival rate is only piecewise-constant between
        them.  Used by ``batched_adaptive.estimate_adaptive_steps`` to
        budget the scan length; the kernel itself stops at these edges
        via the compiled ``(edges, scales)`` rows."""
        edges, _ = self.segments(duration_us)
        inner = edges[(edges > 0.0) & (edges < duration_us)]
        return np.asarray(inner, dtype=np.float64)

    def descriptor(self) -> str:
        return self.name

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.descriptor()})"


@dataclass(frozen=True)
class StepSchedule(LoadSchedule):
    """Piecewise-constant steps: ``scales[i]`` on
    ``[times[i], times[i+1])`` with ``times[0] == 0``."""

    times_us: tuple = (0.0,)
    scales: tuple = (1.0,)
    name: str = field(default="step", compare=False)

    def __post_init__(self):
        t = tuple(float(x) for x in self.times_us)
        s = tuple(float(x) for x in self.scales)
        if len(t) != len(s) or not t or t[0] != 0.0:
            raise ValueError("StepSchedule needs times[0]=0 and "
                             "len(times) == len(scales)")
        if any(b <= a for a, b in zip(t, t[1:], strict=False)):
            raise ValueError("StepSchedule times must strictly increase")
        if any(x < 0 for x in s):
            raise ValueError("StepSchedule scales must be >= 0")
        object.__setattr__(self, "times_us", t)
        object.__setattr__(self, "scales", s)

    def _materialize(self, until_us: float):
        return (np.asarray(self.times_us), np.asarray(self.scales))

    def descriptor(self) -> str:
        # '|'-separated: benchmark rows embed descriptors in 'k=v;k=v'
        # derived strings, so ';' (and ',', the CSV delimiter) are out
        parts = "|".join(f"{t:g}:{s:g}" for t, s in
                         zip(self.times_us, self.scales, strict=True))
        return f"step[{parts}]"


@dataclass(frozen=True)
class RampSchedule(LoadSchedule):
    """Linear ramp from ``scale_from`` to ``scale_to`` over
    ``[t_start_us, t_end_us]``, discretized into ``n_steps`` equal
    stairs (flat before and after)."""

    t_start_us: float
    t_end_us: float
    scale_from: float = 1.0
    scale_to: float = 1.0
    n_steps: int = 32
    name: str = field(default="ramp", compare=False)

    def __post_init__(self):
        if self.t_end_us <= self.t_start_us:
            raise ValueError("RampSchedule needs t_end_us > t_start_us")
        if self.n_steps < 1:
            raise ValueError("RampSchedule needs n_steps >= 1")
        if min(self.scale_from, self.scale_to) < 0:
            raise ValueError("RampSchedule scales must be >= 0")

    def _materialize(self, until_us: float):
        ts = [0.0]
        ss = [float(self.scale_from)]
        step = (self.t_end_us - self.t_start_us) / self.n_steps
        for k in range(self.n_steps):
            frac = (k + 0.5) / self.n_steps      # midpoint value per stair
            ts.append(self.t_start_us + k * step)
            ss.append(self.scale_from
                      + frac * (self.scale_to - self.scale_from))
        ts.append(self.t_end_us)
        ss.append(float(self.scale_to))
        return np.asarray(ts), np.asarray(ss)

    def transitions(self, duration_us: float) -> tuple[float, ...]:
        # one regime change begins at ramp start and completes at ramp
        # end — the per-stair micro-edges are not separate transitions
        out = [t for t in (self.t_start_us, self.t_end_us)
               if 0.0 < t < duration_us]
        return tuple(out)

    def descriptor(self) -> str:
        return (f"ramp[{self.t_start_us:g}-{self.t_end_us:g}us|"
                f"{self.scale_from:g}->{self.scale_to:g}]")


@dataclass(frozen=True)
class SinusoidSchedule(LoadSchedule):
    """Periodic modulation ``mean + amplitude*sin(2*pi*t/period)``,
    staircase-sampled at ``steps_per_period`` (clipped at 0)."""

    period_us: float
    amplitude: float = 0.5
    mean: float = 1.0
    steps_per_period: int = 16
    name: str = field(default="sinusoid", compare=False)

    def __post_init__(self):
        if self.period_us <= 0 or self.steps_per_period < 4:
            raise ValueError("SinusoidSchedule needs period_us > 0 and "
                             "steps_per_period >= 4")

    def _materialize(self, until_us: float):
        n_periods = int(np.ceil(max(until_us, 1e-9) / self.period_us))
        step = self.period_us / self.steps_per_period
        k = np.arange(n_periods * self.steps_per_period)
        ts = k * step
        phase = 2.0 * np.pi * (k + 0.5) / self.steps_per_period
        ss = np.maximum(self.mean + self.amplitude * np.sin(phase), 0.0)
        return ts, ss

    def transitions(self, duration_us: float) -> tuple[float, ...]:
        # continuous modulation: no discrete regime changes to converge
        # after (tracking reduces to violation fraction / rho RMSE)
        return ()

    def descriptor(self) -> str:
        return (f"sinusoid[T={self.period_us:g}us|"
                f"{self.mean:g}±{self.amplitude:g}]")


class MMPPSchedule(LoadSchedule):
    """Markov-modulated steps: dwell Exp(``mean_dwell_us``) in a state,
    then jump to a different scale state uniformly.  The sample path is
    materialized from a private ``seed`` (not the run rng), so the event
    engine, the threaded runtime and the batched engine all replay the
    *same* schedule."""

    name = "mmpp"

    def __init__(self, states=(0.3, 1.0, 1.8), *,
                 mean_dwell_us: float = 20_000.0, seed: int = 0):
        states = tuple(float(s) for s in states)
        if len(states) < 2 or any(s < 0 for s in states):
            raise ValueError("MMPPSchedule needs >= 2 non-negative states")
        if mean_dwell_us <= 0:
            raise ValueError("MMPPSchedule needs mean_dwell_us > 0")
        self.states = states
        self.mean_dwell_us = float(mean_dwell_us)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._edges = [0.0]
        self._scale_idx = [int(self._rng.integers(len(states)))]

    def _materialize(self, until_us: float):
        while self._edges[-1] < until_us:
            self._edges.append(self._edges[-1]
                               + float(self._rng.exponential(
                                   self.mean_dwell_us)))
            nxt = int(self._rng.integers(len(self.states) - 1))
            cur = self._scale_idx[-1]
            self._scale_idx.append(nxt + (nxt >= cur))   # never self-jump
        return (np.asarray(self._edges),
                np.asarray([self.states[i] for i in self._scale_idx]))

    def __eq__(self, other):
        return (isinstance(other, MMPPSchedule)
                and self.states == other.states
                and self.mean_dwell_us == other.mean_dwell_us
                and self.seed == other.seed)

    def __hash__(self):
        return hash((self.states, self.mean_dwell_us, self.seed))

    def descriptor(self) -> str:
        return (f"mmpp[{len(self.states)}states|"
                f"dwell={self.mean_dwell_us:g}us|seed={self.seed}]")


def from_trace(times_us, rates_mpps, *, base_rate_mpps: float) -> StepSchedule:
    """A measured (timestamp, rate) series as a step schedule relative to
    ``base_rate_mpps`` (the stationary rate of the workload it will
    modulate): ``scale(t) = rates[i] / base_rate`` on
    ``[times[i], times[i+1])``."""
    if base_rate_mpps <= 0:
        raise ValueError("from_trace needs base_rate_mpps > 0")
    times = [float(t) for t in times_us]
    if not times:
        raise ValueError("from_trace needs at least one sample")
    if times[0] != 0.0:
        times = [0.0] + times
        rates_mpps = [rates_mpps[0]] + list(rates_mpps)
    sched = StepSchedule(
        times_us=tuple(times),
        scales=tuple(float(r) / base_rate_mpps for r in rates_mpps))
    object.__setattr__(sched, "name", "trace")
    return sched
