"""Bounded ingress queue shared by the real-thread backends."""

from __future__ import annotations

import collections
import time
from typing import Any

from repro.core.trylock import TryLock

__all__ = ["BoundedQueue"]


class BoundedQueue:
    """Bounded MPSC-ish queue standing in for the NIC Rx descriptor ring.

    ``push`` drops (and counts) on overflow — Rx-ring semantics, paper
    Table 2/3 loss accounting.  ``poll`` is only called under the queue's
    TryLock, so a plain deque suffices (append is GIL-atomic for pushers).
    """

    __slots__ = ("_q", "capacity", "dropped", "offered", "serviced", "lock",
                 "last_busy_end_ns")

    def __init__(self, capacity: int = 1024):
        self._q: collections.deque = collections.deque()
        self.capacity = capacity
        self.dropped = 0
        self.offered = 0
        self.serviced = 0
        self.lock = TryLock()
        self.last_busy_end_ns = time.monotonic_ns()

    def push(self, item: Any) -> bool:
        self.offered += 1
        if len(self._q) >= self.capacity:
            self.dropped += 1
            return False
        self._q.append((time.monotonic_ns(), item))
        return True

    def poll(self, max_items: int) -> list[tuple[int, Any]]:
        out = []
        q = self._q
        for _ in range(min(max_items, len(q))):
            try:
                out.append(q.popleft())
            except IndexError:  # racing pushers can't cause this; be safe
                break
        self.serviced += len(out)
        return out

    def __len__(self) -> int:
        return len(self._q)
