"""Co-run application loads — the paper's Sec 5.6 CPU-sharing scenario.

Metronome's second headline claim is that sleep&wake retrieval leaves
the CPU it does not need to *other* work: an I/O task and a
CPU-intensive application can share cores, where DPDK-style busy polling
pins a full core forever.  This module makes that co-located
application a first-class object on both execution surfaces:

  - **real threads**: an ``AppLoad`` is a unit of competing application
    work that ``Runtime`` (and ``Server(..., app_load=...)``) co-runs on
    the host alongside the poller threads, counting the work it actually
    got done (``ops``) and the CPU it burned (``cpu_ns``) — the paper's
    Fig 15 "application throughput next to the dataplane" measurement;
  - **simulation**: ``co_run_config`` maps an app's CPU demand to the
    ``SimRunConfig`` interference model (per-wake preemption delays,
    correlated descheduling windows), so the event and batched engines
    can sweep co-location scenarios deterministically.

Two concrete loads:

  - ``DutyCycleBurner`` — a closed-loop CPU burner that wants
    ``demand`` of one core (burn ``demand * period``, sleep the rest):
    the canonical "CPU-intensive application" knob;
  - ``MatmulAppLoad`` — a jitted JAX matmul step on the same XLA
    substrate as ``repro.kernels``: a realistic compute tenant whose
    quantum is one device-synchronized matmul.

Contention model behind ``co_run_config`` (one CFS-scheduled core
hosting the I/O task and an app of demand ``a``):

  - a *sleep&wake* poller spends most time blocked, so the app runs in
    its gaps; the cost of co-location is per-wake — each timer fire
    lands on a busy core with probability ~``min(a, 1)`` and must wait
    out a wakeup-preemption delay — plus rare longer windows where the
    app (or kernel work on its behalf) cannot be preempted at all;
  - a *spinning* poller is always runnable, so CFS alternates it with
    the app in scheduler-quantum timeslices: the app's fair share
    against a spinner is ``min(a, 1/2)`` of the core, delivered as
    quantum-length windows during which the spinner is descheduled and
    retrieves nothing (modeled as correlated stall windows; the spin
    fluid model serves zero during them).  A closed-loop app with
    ``a <= 1/2`` still gets its work done (the spinner keeps
    ``1 - a``); past that the app saturates at half and the spinner
    collapses toward half its nominal service rate.
"""

from __future__ import annotations

import time
from dataclasses import replace
from typing import Protocol, runtime_checkable

from .simcore import SimRunConfig

__all__ = [
    "AppLoad",
    "DutyCycleBurner",
    "MatmulAppLoad",
    "co_run_config",
]


@runtime_checkable
class AppLoad(Protocol):
    """A unit of competing application work co-run with the pollers.

    Contract:
      - ``name``     label for benchmark rows;
      - ``threads``  how many app threads to deploy;
      - ``demand``   fraction of one core each thread *wants* (>= 1.0
                     means unthrottled / always runnable) — consumed by
                     the simulation mapping and equal-core accounting;
      - ``reset()``  re-arm internal state at run start;
      - ``step()``   run one quantum of work and return the work units
                     completed (called in a loop until the runtime
                     stops; must return promptly — quanta of ~1ms keep
                     stop() latency bounded).
    """

    name: str

    @property
    def threads(self) -> int: ...

    @property
    def demand(self) -> float: ...

    def reset(self) -> None: ...

    def step(self) -> int: ...


class DutyCycleBurner:
    """Closed-loop CPU burner: each quantum burns ``demand * period_us``
    of CPU (spin on the monotonic clock), then sleeps the remainder of
    the period.  ``demand >= 1`` never sleeps (an unthrottled tenant).
    ``ops`` counts completed quanta."""

    name = "duty-cycle-burner"

    def __init__(self, demand: float = 0.5, *, period_us: float = 1_000.0,
                 threads: int = 1):
        if demand < 0.0:
            raise ValueError("demand must be >= 0")
        self._demand = float(demand)
        self.period_us = float(period_us)
        self._threads = int(threads)

    @property
    def threads(self) -> int:
        return self._threads

    @property
    def demand(self) -> float:
        return self._demand

    def reset(self) -> None:
        pass

    def step(self) -> int:
        period_ns = int(self.period_us * 1e3)
        burn_ns = int(min(self._demand, 1.0) * period_ns)
        deadline = time.perf_counter_ns() + burn_ns
        while time.perf_counter_ns() < deadline:
            pass
        idle_ns = period_ns - burn_ns
        if idle_ns > 0:
            time.sleep(idle_ns / 1e9)
        return 1

    def __repr__(self) -> str:
        return (f"DutyCycleBurner(demand={self._demand}, "
                f"period_us={self.period_us}, threads={self._threads})")


class MatmulAppLoad:
    """A compute tenant on the repo's JAX/XLA substrate: one quantum is
    one jitted ``(n x n) @ (n x n)`` matmul, synchronized to completion
    (``block_until_ready``), so each ``step()`` really occupies the
    backend for the matmul's duration.  ``demand`` defaults to 1.0 —
    an unthrottled tenant that takes whatever the scheduler gives it."""

    name = "matmul-app"

    def __init__(self, n: int = 256, *, threads: int = 1,
                 demand: float = 1.0, dtype=None):
        self.n = int(n)
        self._threads = int(threads)
        self._demand = float(demand)
        self._dtype = dtype
        self._fn = None
        self._x = None

    @property
    def threads(self) -> int:
        return self._threads

    @property
    def demand(self) -> float:
        return self._demand

    def reset(self) -> None:
        # build lazily so numpy-only paths never import jax
        import jax
        import jax.numpy as jnp

        dtype = self._dtype or jnp.float32
        key = jax.random.PRNGKey(0)
        self._x = jax.random.normal(key, (self.n, self.n), dtype=dtype)
        self._fn = jax.jit(lambda a: a @ a)
        self._fn(self._x).block_until_ready()      # compile outside the loop

    def step(self) -> int:
        if self._fn is None:
            self.reset()
        self._x = self._fn(self._x)
        self._x.block_until_ready()
        return 1

    def __repr__(self) -> str:
        return f"MatmulAppLoad(n={self.n}, threads={self._threads})"


def _combine_bernoulli_exp(prob_a, mean_a, prob_b, mean_b):
    """Layer two Bernoulli x Exp delay sources: hit probabilities
    union (independent events), means combine weighted by each source's
    expected-delay contribution so the total E[delay] is preserved."""
    prob = 1.0 - (1.0 - prob_a) * (1.0 - prob_b)
    if prob <= 0.0:
        return 0.0, 0.0
    mean = (prob_a * mean_a + prob_b * mean_b) / prob
    return prob, mean


def _combine_stalls(cfg: SimRunConfig, new_rate: float, new_mean: float):
    """Layer a stall-window source onto ``cfg``'s: Poisson rates add,
    window means combine weighted by each source's rate contribution so
    the total stalled-time fraction (rate x mean) is preserved."""
    tot_rate = cfg.stall_rate_per_us + new_rate
    if tot_rate <= 0.0:
        return 0.0, 0.0
    mean = (cfg.stall_rate_per_us * cfg.stall_mean_us
            + new_rate * new_mean) / tot_rate
    return tot_rate, mean


def co_run_config(cfg: SimRunConfig, demand: float, *, spin: bool = False,
                  preempt_mean_us: float = 8.0,
                  pileup_every_us: float = 8_000.0,
                  pileup_mean_us: float = 120.0,
                  quantum_us: float = 250.0) -> SimRunConfig:
    """Derive the ``SimRunConfig`` for co-running an app of CPU demand
    ``demand`` (fraction of one core) next to the I/O task on one core.

    ``spin=False`` (sleep&wake poller): each wake lands on a busy core
    w.p. ``min(demand, 1)`` and waits an Exp(``preempt_mean_us``)
    wakeup-preemption delay; non-preemptible pile-ups add an
    Exp(``pileup_mean_us``) stall window every ``pileup_every_us /
    demand`` on average.

    ``spin=True`` (busy-polling poller): CFS deschedules the spinner
    for quantum-length windows whenever the app is runnable — the app's
    fair share against an always-runnable spinner is ``min(demand,
    0.5)``, delivered as Exp(``quantum_us``) stall windows at rate
    ``share / quantum_us`` (expected capacity loss = the share).  The
    spin fluid model (``repro.runtime.sim._simulate_spin``) serves
    nothing during stall windows, so latency spikes and ring overflows
    emerge exactly as on a shared host.

    Existing interference in ``cfg`` is layered, not overwritten:
    Bernoulli hit probabilities union, Exp means combine preserving the
    expected delay, stall rates add.
    """
    if demand < 0.0:
        raise ValueError("demand must be >= 0")
    if demand == 0.0:
        return cfg
    occ = min(demand, 1.0)
    if spin:
        share = min(demand, 0.5)
        tot_rate, stall_mean = _combine_stalls(cfg, share / quantum_us,
                                               quantum_us)
        return replace(cfg, stall_rate_per_us=tot_rate,
                       stall_mean_us=stall_mean)
    prob, mean = _combine_bernoulli_exp(
        cfg.interference_prob, cfg.interference_mean_us,
        occ, preempt_mean_us)
    tot_rate, stall_mean = _combine_stalls(cfg, occ / pileup_every_us,
                                           pileup_mean_us)
    return replace(cfg, interference_prob=prob,
                   interference_mean_us=mean,
                   stall_rate_per_us=tot_rate,
                   stall_mean_us=stall_mean)
