"""Traffic workloads — *what* arrives at the retrieval queue, and when.

A ``Workload`` is consumed two ways, one per execution backend:

  - the discrete-event simulator calls ``counts_in(t0, t1)`` over a
    monotone sweep of windows (aggregate-exact: no per-packet events, so
    a line-rate second costs O(#cycles));
  - the threaded ``Runtime`` / serving server call
    ``iter_arrivals(duration_us, rng)`` and replay each arrival in real
    time against the queue.

``reset(rng)`` re-arms internal state (phase schedules, materialized
trace times) before each run; ``rate_at(t)`` is the rate *envelope* in
packets/us used for diagnostics and saturation checks, not accounting.

Implementations: ``PoissonWorkload`` (optionally time-varying),
``CBRWorkload`` (constant bit rate), ``OnOffBurstyWorkload`` (exponential
on/off phases — bursty edge traffic), and ``TraceReplayWorkload``
(timestamped trace with ``speedup``/``jitter``, the pcap-sender replay
model: each inter-arrival gap is divided by ``speedup`` and multiplied
by a fresh ``1 ± jitter`` factor).

``ScheduledWorkload`` makes any of them *nonstationary*: a
``repro.runtime.schedule.LoadSchedule`` multiplies the base rate over
time via time warping — the base process runs on the warped clock
``W(t) = ∫ scale(u) du``, which for Poisson is exactly the
inhomogeneous-rate process and for CBR/trace replay the natural
speed-up/slow-down.  Both window counting (``counts_in``) and real-time
replay (``iter_arrivals``) are warped, so the event engine, the batched
engine and the threaded runtime all see the same offered-load
trajectory.
"""

from __future__ import annotations

from typing import Iterator, Protocol, Sequence, runtime_checkable

import numpy as np

__all__ = [
    "Workload",
    "PoissonWorkload",
    "CBRWorkload",
    "OnOffBurstyWorkload",
    "TraceReplayWorkload",
    "ScheduledWorkload",
]


@runtime_checkable
class Workload(Protocol):
    name: str

    def reset(self, rng: np.random.Generator) -> None: ...

    def rate_at(self, t_us: float) -> float: ...

    def counts_in(self, t0_us: float, t1_us: float) -> int: ...

    def iter_arrivals(self, duration_us: float,
                      rng: np.random.Generator) -> Iterator[float]: ...


class PoissonWorkload:
    """Memoryless arrivals at ``rate_mpps`` packets/us, optionally
    modulated by ``profile(t_us) -> rate`` (paper Fig 11 ramps)."""

    name = "poisson"

    def __init__(self, rate_mpps: float = 14.88, *, profile=None):
        self.rate_mpps = float(rate_mpps)
        self.profile = profile
        self._rng: np.random.Generator | None = None

    def reset(self, rng: np.random.Generator) -> None:
        self._rng = rng

    def rate_at(self, t_us: float) -> float:
        return float(self.profile(t_us)) if self.profile else self.rate_mpps

    def counts_in(self, t0_us: float, t1_us: float) -> int:
        dt = t1_us - t0_us
        if dt <= 0:
            return 0
        lam = self.rate_at(t0_us)
        return int(self._rng.poisson(lam * dt)) if lam > 0 else 0

    def iter_arrivals(self, duration_us, rng) -> Iterator[float]:
        t = 0.0
        while True:
            lam = self.rate_at(t)
            if lam <= 0:
                t += 1_000.0       # idle probe step while the profile is off
                if t >= duration_us:
                    return
                continue
            t += float(rng.exponential(1.0 / lam))
            if t >= duration_us:
                return
            yield t


class CBRWorkload:
    """Constant bit rate: one arrival every 1/rate us, deterministically."""

    name = "cbr"

    def __init__(self, rate_mpps: float = 14.88):
        self.rate_mpps = float(rate_mpps)

    def reset(self, rng: np.random.Generator) -> None:
        pass

    def rate_at(self, t_us: float) -> float:
        return self.rate_mpps

    def counts_in(self, t0_us: float, t1_us: float) -> int:
        if t1_us <= t0_us:
            return 0
        # stateless and exact over disjoint windows: cumulative counts
        return int(np.floor(t1_us * self.rate_mpps)
                   - np.floor(t0_us * self.rate_mpps))

    def iter_arrivals(self, duration_us, rng) -> Iterator[float]:
        period = 1.0 / self.rate_mpps
        t = period
        while t < duration_us:
            yield t
            t += period


class OnOffBurstyWorkload:
    """Exponential on/off phases: Poisson at ``peak_mpps`` while "on",
    silence while "off" — the bursty edge-traffic scenario a single mean
    rate cannot express (mean rate = peak * duty cycle)."""

    name = "on-off"

    def __init__(self, peak_mpps: float = 14.88, *,
                 on_mean_us: float = 5_000.0, off_mean_us: float = 15_000.0,
                 start_on: bool = True):
        self.peak_mpps = float(peak_mpps)
        self.on_mean_us = float(on_mean_us)
        self.off_mean_us = float(off_mean_us)
        self.start_on = start_on
        self._rng: np.random.Generator | None = None
        self._edges: list[float] = []     # phase boundaries, t=0 first edge
        self._first_on = start_on

    @property
    def duty_cycle(self) -> float:
        return self.on_mean_us / (self.on_mean_us + self.off_mean_us)

    def reset(self, rng: np.random.Generator) -> None:
        self._rng = rng
        self._edges = [0.0]
        self._first_on = self.start_on

    def _extend_schedule(self, until_us: float) -> None:
        while self._edges[-1] < until_us:
            on = self._first_on == (len(self._edges) % 2 == 1)
            mean = self.on_mean_us if on else self.off_mean_us
            self._edges.append(self._edges[-1] + float(self._rng.exponential(mean)))

    def _is_on(self, phase_idx: int) -> bool:
        # phase i spans edges[i]..edges[i+1]; phase 0 is `start_on`
        return self._first_on == (phase_idx % 2 == 0)

    def _on_time(self, t0_us: float, t1_us: float) -> float:
        self._extend_schedule(t1_us)
        edges = self._edges
        i = int(np.searchsorted(edges, t0_us, side="right")) - 1
        on_time = 0.0
        while i < len(edges) - 1 and edges[i] < t1_us:
            lo = max(edges[i], t0_us)
            hi = min(edges[i + 1], t1_us)
            if hi > lo and self._is_on(i):
                on_time += hi - lo
            i += 1
        return on_time

    def rate_at(self, t_us: float) -> float:
        return self.peak_mpps * self.duty_cycle   # envelope (mean) rate

    def counts_in(self, t0_us: float, t1_us: float) -> int:
        on_time = self._on_time(t0_us, t1_us)
        if on_time <= 0:
            return 0
        return int(self._rng.poisson(self.peak_mpps * on_time))

    def iter_arrivals(self, duration_us, rng) -> Iterator[float]:
        t = 0.0
        on = self.start_on
        while t < duration_us:
            span = float(rng.exponential(self.on_mean_us if on
                                         else self.off_mean_us))
            if on:
                u = t
                while True:
                    u += float(rng.exponential(1.0 / self.peak_mpps))
                    if u >= min(t + span, duration_us):
                        break
                    yield u
            t += span
            on = not on


class TraceReplayWorkload:
    """Temporal replay of a timestamped trace (the pcap-sender model).

    Inter-arrival gaps from the trace are divided by ``speedup`` and each
    multiplied by an independent ``1 + U(-jitter, +jitter)`` factor
    (clipped at 0); ``loop=True`` restarts the trace — with fresh jitter
    — until the run's duration is covered.  The trace is normalized to
    its own start: with ``jitter=0`` the replayed arrival times are
    exactly ``(ts - ts[0]) / speedup``.
    """

    name = "trace-replay"

    def __init__(self, timestamps_us: Sequence[float], *,
                 speedup: float = 1.0, jitter: float = 0.0,
                 loop: bool = False):
        ts = np.asarray(sorted(float(t) for t in timestamps_us),
                        dtype=np.float64)
        if ts.size == 0:
            raise ValueError("trace must contain at least one timestamp")
        if speedup <= 0:
            raise ValueError("speedup must be > 0")
        if not 0.0 <= jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")
        if loop and ts.size > 1 and ts[-1] == ts[0]:
            raise ValueError(
                "loop=True needs a trace with nonzero span: all timestamps "
                "are equal, so each lap would advance time by nothing")
        self.trace_us = ts
        self.speedup = float(speedup)
        self.jitter = float(jitter)
        self.loop = loop
        self._rng: np.random.Generator | None = None
        self._times: np.ndarray = np.empty(0)

    @property
    def base_gaps_us(self) -> np.ndarray:
        """Replayed gaps before jitter: trace deltas / speedup.  The
        first gap is 0 (trace normalized to its own start)."""
        ts = self.trace_us
        return np.diff(ts, prepend=ts[0]) / self.speedup

    def _lap(self) -> np.ndarray:
        """One pass over the trace: jittered, sped-up gaps."""
        gaps = self.base_gaps_us
        if self.jitter:
            factors = 1.0 + self._rng.uniform(-self.jitter, self.jitter,
                                              size=gaps.size)
            gaps = np.maximum(gaps * factors, 0.0)
        return gaps

    def reset(self, rng: np.random.Generator) -> None:
        self._rng = rng
        self._times = np.cumsum(self._lap())

    def _ensure(self, until_us: float) -> None:
        while self.loop and self._times[-1] < until_us:
            start = self._times[-1]
            gaps = self._lap()
            # restart gap: reuse the first gap (or the mean gap for
            # single-packet traces) so laps don't collapse onto one
            # instant; a tiny floor guarantees forward progress even for
            # near-degenerate traces (zero-span ones are rejected upfront)
            gaps[0] = max(gaps[0], float(np.mean(gaps)) if gaps.size > 1
                          else 1.0 / max(self.mean_rate_mpps, 1e-9), 1e-3)
            self._times = np.concatenate([self._times, start + np.cumsum(gaps)])

    @property
    def mean_rate_mpps(self) -> float:
        span = (self.trace_us[-1] - self.trace_us[0]) / self.speedup
        return self.trace_us.size / max(span, 1e-9)

    def rate_at(self, t_us: float) -> float:
        return self.mean_rate_mpps

    def counts_in(self, t0_us: float, t1_us: float) -> int:
        if t1_us <= t0_us:
            return 0
        self._ensure(t1_us)
        t = self._times
        # [t0, t1) windows: an arrival at exactly t=0 lands in the first
        # window of the simulator's monotone sweep
        return int(np.searchsorted(t, t1_us, side="left")
                   - np.searchsorted(t, t0_us, side="left"))

    def iter_arrivals(self, duration_us, rng) -> Iterator[float]:
        self.reset(rng)
        self._ensure(duration_us)
        for t in self._times:
            if t >= duration_us:
                return
            yield float(t)

    def __repr__(self) -> str:
        return (f"TraceReplayWorkload(n={self.trace_us.size}, "
                f"speedup={self.speedup}, jitter={self.jitter}, "
                f"loop={self.loop})")


class ScheduledWorkload:
    """Any base workload modulated by a ``LoadSchedule`` — nonstationary
    traffic through time warping.

    The base process is evaluated on the warped clock ``W(t) =
    ∫_0^t scale(u) du``: window counts on real ``[t0, t1)`` become base
    counts on ``[W(t0), W(t1))`` and replayed arrival times map back
    through ``W^{-1}``.  For a Poisson base this *is* the
    inhomogeneous Poisson process at rate ``lambda * scale(t)``; for
    CBR / trace replay it is the piecewise speed change a sender would
    apply.  The wrapper satisfies the full ``Workload`` protocol, so
    every backend (event engine, threaded runtime, serving replay)
    consumes it unchanged.
    """

    def __init__(self, base: Workload, schedule):
        self.base = base
        self.schedule = schedule
        self.name = (f"{getattr(base, 'name', type(base).__name__)}"
                     f"@{schedule.descriptor()}")

    def reset(self, rng: np.random.Generator) -> None:
        self.base.reset(rng)

    def rate_at(self, t_us: float) -> float:
        return (self.base.rate_at(self.schedule.integral(t_us))
                * self.schedule.scale_at(t_us))

    def counts_in(self, t0_us: float, t1_us: float) -> int:
        if t1_us <= t0_us:
            return 0
        return self.base.counts_in(self.schedule.integral(t0_us),
                                   self.schedule.integral(t1_us))

    def iter_arrivals(self, duration_us, rng) -> Iterator[float]:
        warped_end = self.schedule.integral(duration_us)
        for u in self.base.iter_arrivals(warped_end, rng):
            t = self.schedule.inverse_integral(
                u, hint_until_us=duration_us)
            if t >= duration_us:
                return
            yield float(t)

    def __repr__(self) -> str:
        return f"ScheduledWorkload({self.base!r}, {self.schedule!r})"
