"""Calibration layer: batched sweeps -> validated operating tables.

The paper picks (T_S, T_L, M) from closed forms (Eq 6/12/13); the closed
forms ignore sleep overshoot, wake cost, role churn and queue-capacity
clipping, so a configuration that is optimal on paper is merely a good
initial guess.  This module closes the loop empirically:

  1. sweep a dense (T_S, T_L, M) x load grid through the batched JAX
     engine (``repro.runtime.batched``) — thousands of operating points
     in one JIT-compiled call;
  2. cross-check every point's measured mean vacation against the
     ``repro.core.analytics`` closed form (``mean_vacation_general``,
     evaluated at the per-queue load, widened by the environment's
     interference slack) — points where engine and analysis disagree
     wildly are discarded as untrustworthy rather than silently
     selected;
  3. optionally spot-check selected points against the exact
     event-driven engine (``simulate_run``) within the batched engine's
     documented parity tolerance — in the same environment the sweep
     ran in, OS interference and correlated stalls included;
  4. for each offered load, select the cheapest point — min CPU by
     default, min predicted energy with ``objective="energy"`` — whose
     mean latency meets the target -> an ``OperatingTable`` that
     records the environment it was calibrated for.

The table is a feed-forward term for the runtime control plane:
``MetronomeController``/``MetronomePolicy`` accept it (the Eq 10 EWMA
keeps estimating rho; the table maps rho to a pre-validated operating
point), ``Server(..., operating_table=...)`` loads one at startup, and
``OperatingTable.save/load`` round-trips through JSON so calibration can
run offline (e.g. benchmarks/sweep_frontier.py) and deploy later.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, replace

import numpy as np

from repro.core import analytics

from .batched import SweepGrid, simulate_batch, validate_batched_config
from .simcore import SimRunConfig

__all__ = [
    "OperatingPoint",
    "OperatingTable",
    "CalibrationMismatch",
    "analytic_guard_mask",
    "build_operating_table",
    "schedule_spot_check",
]


class CalibrationMismatch(AssertionError):
    """A selected operating point failed its event-engine spot check."""


@dataclass(frozen=True)
class OperatingPoint:
    """One calibrated row: at load ``rho``, run (t_s, t_l, m) and expect
    the predicted mean latency / CPU.  ``meets_target=False`` marks
    loads where no swept point met the latency target (the returned
    point is then the latency-minimizing fallback)."""

    rho: float
    t_s_us: float
    t_l_us: float
    m: int
    mean_latency_us: float
    cpu_fraction: float
    loss_fraction: float
    meets_target: bool = True
    # predicted package energy over the calibration run (EnergyModel
    # accounting; divide by the environment's duration_us for watts);
    # 0.0 on tables predating the field
    energy_uj: float = 0.0


@dataclass(frozen=True)
class OperatingTable:
    """Load -> operating point map with interpolating lookups.

    ``timeouts_us(rho)`` is the feed-forward surface consumed by
    ``MetronomeController``: piecewise-linear interpolation of (T_S,
    T_L) between calibrated loads, clamped to the calibrated range.
    ``lookup(rho)`` returns the governing row — the nearest calibrated
    load at or *above* the request, so feasibility is conservative.

    ``environment`` records the ``SimRunConfig`` the table was
    calibrated in (sleep model, wake cost, n_queues, OS interference /
    stall injection, ...) as a JSON-safe dict, so a table calibrated on
    a noisy shared host is never mistaken for a quiet-host table (and
    vice versa) once deployed.  ``None`` only on tables predating the
    field or built by hand.
    """

    target_mean_latency_us: float
    service_rate_mpps: float
    points: tuple[OperatingPoint, ...]
    environment: dict | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "points",
            tuple(sorted(self.points, key=lambda p: p.rho)))
        if not self.points:
            raise ValueError("OperatingTable needs at least one point")

    # -- lookups ---------------------------------------------------------------
    @property
    def rhos(self) -> np.ndarray:
        return np.asarray([p.rho for p in self.points])

    def lookup(self, rho: float) -> OperatingPoint:
        i = int(np.searchsorted(self.rhos, rho, side="left"))
        return self.points[min(i, len(self.points) - 1)]

    def timeouts_us(self, rho: float) -> tuple[float, float]:
        rhos = self.rhos
        t_s = float(np.interp(rho, rhos, [p.t_s_us for p in self.points]))
        t_l = float(np.interp(rho, rhos, [p.t_l_us for p in self.points]))
        return t_s, t_l

    def t_s_us(self, rho: float) -> float:
        return self.timeouts_us(rho)[0]

    # -- persistence -----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "target_mean_latency_us": self.target_mean_latency_us,
            "service_rate_mpps": self.service_rate_mpps,
            "environment": self.environment,
            "points": [asdict(p) for p in self.points],
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "OperatingTable":
        d = json.loads(text)
        return cls(target_mean_latency_us=d["target_mean_latency_us"],
                   service_rate_mpps=d["service_rate_mpps"],
                   environment=d.get("environment"),
                   points=tuple(OperatingPoint(**p) for p in d["points"]))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "OperatingTable":
        with open(path) as f:
            return cls.from_json(f.read())


def analytic_guard_mask(vac_measured, t_s_grid, t_l_grid, m_grid, rhos, *,
                        guard_rel: float, slot_us: float,
                        n_queues=(1,), slack_us: float = 0.0) -> np.ndarray:
    """True where a sweep point's measured mean vacation roughly agrees
    with the App-C closed form (``mean_vacation_general``); a
    disagreement beyond ``guard_rel`` (plus a couple of slots of
    quantization allowance) means the engine and the model describe
    different systems and the point must not be selected silently.

    ``vac_measured`` has the seed-averaged lattice shape
    ``(len(t_s_grid), len(t_l_grid), len(m_grid), len(n_queues),
    len(rhos))``.  ``n_queues`` is the grid's queue-count axis: the
    engines measure vacations *per queue*, and under uniform dispatch
    each of the ``nq`` queues carries ~``rho / nq`` while receiving only
    ~``1/nq`` of the claim events (each wake claims one queue), so the
    closed form is evaluated at the per-queue load and scaled by ``nq``
    (feeding it the aggregate rho — the old literal-``[0]`` placeholder
    — compared per-queue vacations against the wrong prediction for
    every multi-queue sweep).  ``slack_us`` widens the band additively
    for noisy-host sweeps — pass
    ``SimRunConfig.interference_slack_us()``, the expected mean-vacation
    shift of the environment's OS-interference injection (per-wake
    Bernoulli x Exp plus the stall process's E[W^2]/2 residual tail) —
    so contention-honest sweeps are not rejected against a quiet-host
    prediction.

    Shared by ``build_operating_table`` and the sweep-frontier
    benchmark's fixed baseline, so both sides filter candidates with the
    *same* rule (the calibrated-vs-fixed verdict compares argmins over
    one candidate set).
    """
    ts_ax = np.atleast_1d(np.asarray(t_s_grid, dtype=np.float64))
    tl_ax = np.atleast_1d(np.asarray(t_l_grid, dtype=np.float64))
    m_ax = np.atleast_1d(np.asarray(m_grid))
    nq_ax = np.atleast_1d(np.asarray(n_queues, dtype=np.float64))
    rhos = np.atleast_1d(np.asarray(rhos, dtype=np.float64))
    TS, TL, M, NQ, RHO = np.meshgrid(ts_ax, tl_ax, m_ax, nq_ax, rhos,
                                     indexing="ij")
    NQ = np.maximum(NQ, 1.0)
    vac_pred = NQ * analytics.mean_vacation_general(
        TS, TL, M, analytics.primary_prob(RHO / NQ))
    return np.abs(vac_measured - vac_pred) <= (guard_rel * vac_pred
                                               + 2.0 * slot_us
                                               + float(slack_us))


def _event_sim_point(p: OperatingPoint, cfg: SimRunConfig, rate_mpps: float):
    """Run one operating point through the exact event engine."""
    from repro.core.controller import MetronomeConfig

    from .policy import MetronomePolicy
    from .sim import simulate_run
    from .workload import PoissonWorkload

    policy = MetronomePolicy(
        MetronomeConfig(m=p.m, v_target_us=p.t_s_us, t_long_us=p.t_l_us,
                        ts_min_us=min(1.0, p.t_s_us)),
        adaptive=False)
    return simulate_run(policy, PoissonWorkload(rate_mpps), cfg)


def schedule_spot_check(table: OperatingTable, schedule, *,
                        cfg: SimRunConfig | None = None,
                        peak_rho: float | None = None,
                        window_us: float = 2_000.0,
                        max_violation: float = 0.5,
                        target_slack: float = 2.0):
    """Closed-loop, *nonstationary* validation of a calibrated table:
    run the exact event engine with the table installed as feed-forward
    while ``schedule`` modulates a Poisson load whose peak reaches the
    table's top calibrated rho, and judge the windowed tracking
    behavior.

    Raises ``CalibrationMismatch`` when the fraction of windows whose
    mean latency exceeds ``target_slack * table.target`` is above
    ``max_violation`` — a table that cannot keep latency within a
    generous multiple of its own target while the load moves across its
    calibrated range is not deployable as a feed-forward term, however
    good its per-load steady-state numbers look.  Returns the
    ``(RunStats, TrackingStats)`` pair for inspection.
    """
    from repro.core.controller import MetronomeConfig

    from .policy import MetronomePolicy
    from .sim import simulate_run
    from .workload import PoissonWorkload

    base = cfg or SimRunConfig(duration_us=60_000.0)
    run_cfg = replace(base, schedule=schedule, window_us=float(window_us))
    rho_peak = (float(peak_rho) if peak_rho is not None
                else float(np.max(table.rhos)))
    scales = schedule.segments(run_cfg.duration_us)[1]
    scale_max = float(np.max(scales)) if scales.size else 1.0
    base_rate = rho_peak * table.service_rate_mpps / max(scale_max, 1e-9)
    m = int(round(float(np.median([p.m for p in table.points]))))
    policy = MetronomePolicy(
        MetronomeConfig(m=m, t_long_us=float(table.points[-1].t_l_us)),
        operating_table=table)
    rs = simulate_run(policy, PoissonWorkload(base_rate), run_cfg)
    tk = rs.windows.tracking(
        schedule.transitions(run_cfg.duration_us),
        target_slack * table.target_mean_latency_us)
    if tk.violation_fraction > max_violation:
        raise CalibrationMismatch(
            f"operating table failed its schedule spot check: "
            f"{tk.violation_fraction:.0%} of {window_us:g}us windows "
            f"exceeded {target_slack:g}x the {table.target_mean_latency_us:g}us "
            f"calibration target under schedule "
            f"{schedule.descriptor()} (allowed {max_violation:.0%})")
    return rs, tk


def build_operating_table(
    *,
    rhos,
    target_mean_latency_us: float,
    t_s_grid,
    t_l_grid,
    m_grid=(2, 3, 4),
    cfg: SimRunConfig | None = None,
    seeds=(0, 1),
    slot_us: float = 0.5,
    max_loss: float = 1e-3,
    analytic_guard_rel: float = 0.6,
    spot_check: int = 0,
    spot_check_rel: float = 0.25,
    sweep=None,
    schedule_check=None,
    fleet=None,
    stepping: str = "fixed",
    objective: str = "cpu",
) -> OperatingTable:
    """Sweep (t_s x t_l x m x rho x seed) through the batched engine and
    distill an ``OperatingTable``: per load, the minimum-cost point whose
    seed-averaged mean latency meets ``target_mean_latency_us`` (and
    loses at most ``max_loss``).

    ``objective`` picks the cost that is minimized over the feasible set:
    ``"cpu"`` (default, the historical behavior) selects minimum
    ``cpu_fraction``; ``"energy"`` selects minimum ``energy_uj`` under
    ``cfg.energy_model``.  The two tables genuinely differ under deep
    C-states: CPU cost is monotone in the wake rate ``m / T_S``, so the
    CPU argmin always stretches T_S to the latency-feasible maximum —
    but the energy objective also pays ``m * P(state(T))`` C-state
    residency plus per-wake transitions, so when the latency target
    binds below a residency floor it ranks the remaining (shallow-band)
    points differently and lands on another (T_S, T_L, M) entirely
    (``benchmarks/power.py`` pins one such divergence).  Every point
    records its ``energy_uj`` either way.

    ``analytic_guard_rel`` drops points whose measured mean vacation
    strays that far (relative) from the App-C closed form — a
    disagreement that large means the engine and the model describe
    different systems, and such a point must not be *selected* silently
    (see ``analytic_guard_mask``; the prediction is evaluated at the
    per-queue load rho/n_queues, and noisy-host environments widen the
    band by ``cfg.interference_slack_us()``).  ``spot_check > 0`` re-
    runs that many selected points through the exact event engine — in
    the *same* environment the sweep ran in, interference and stalls
    included, never a quieted copy — and raises ``CalibrationMismatch``
    if mean sojourn or CPU disagree beyond ``spot_check_rel`` (plus an
    absolute floor matching the batched engine's documented parity band
    for that environment).  ``sweep`` accepts a precomputed
    ``BatchStats`` for exactly this grid (same axes, same cfg/slot_us —
    e.g. one the caller also uses for frontier analysis) so the batch
    isn't simulated twice; its grid shape is validated.

    ``fleet`` (a ``repro.runtime.simcore.FleetConfig``) calibrates a
    *per-host* table for fleet deployment: each table rung still labels
    a per-host rho (the host sweep is unchanged — LB shares decide how
    much of the fleet-aggregate load a host sees), but the latency
    budget a host is given shrinks by the fleet's share-weighted
    topology delay (``FleetConfig.mean_topo_delay_us`` — rack cost plus
    bottleneck-link M/M/1 wait) evaluated at the fleet-aggregate peak
    rate ``max(rhos) * mu * n_hosts``, so "host meets target" composes
    into "fleet request meets target" end to end.  The fleet config is
    recorded in the table's ``environment`` under ``"fleet"``.

    ``schedule_check`` (a ``repro.runtime.schedule.LoadSchedule``)
    additionally validates the finished table *closed-loop under
    nonstationary load*: the exact event engine replays the schedule
    with the table installed as feed-forward and
    ``schedule_spot_check`` raises ``CalibrationMismatch`` if the
    windowed latency violates a generous multiple of the target too
    often.  Calibration sweeps themselves must be stationary —
    ``cfg.schedule`` is rejected (a moving rate would mislabel every
    rho rung of the table).

    ``stepping`` selects the batched engine's scan kernel for the
    lattice sweep (``"adaptive"`` = event-jump macro-slots — the fast
    path for calibration lattices, whose rungs live at low-to-moderate
    rho where the speedup is largest).  The event-engine spot-checks
    are untouched either way: they remain the exact validator, so a
    stepping-mode regression fails calibration instead of silently
    shifting the table.  A precomputed ``sweep`` must have been run
    with the same ``stepping``.

    The returned table records ``cfg`` as its ``environment``.
    """
    cfg = cfg or SimRunConfig(duration_us=60_000.0)
    validate_batched_config(cfg)
    if objective not in ("cpu", "energy"):
        raise ValueError(
            f"objective must be 'cpu' or 'energy', got {objective!r}")
    if cfg.schedule is not None:
        raise ValueError(
            "calibration sweeps must run on stationary loads: each table "
            "rung is labeled with one rho, which a cfg.schedule would "
            "modulate mid-measurement.  Pass the schedule as "
            "schedule_check= to validate the finished table under "
            "nonstationary load instead")
    rhos = np.atleast_1d(np.asarray(rhos, dtype=np.float64))
    mu = cfg.service_rate_mpps
    if fleet is not None:
        fleet.validate()
        peak_fleet_mpps = float(np.max(rhos)) * mu * fleet.n_hosts
        topo_us = fleet.mean_topo_delay_us(peak_fleet_mpps)
        if topo_us >= target_mean_latency_us:
            raise ValueError(
                f"fleet topology delay ({topo_us:.2f}us at peak "
                f"{peak_fleet_mpps:.2f} Mpps) consumes the whole "
                f"{target_mean_latency_us:g}us latency target — no host "
                f"budget remains")
        target_mean_latency_us = target_mean_latency_us - topo_us
    grid = SweepGrid.product(t_s_us=t_s_grid, t_l_us=t_l_grid, m=m_grid,
                             n_queues=(cfg.n_queues,),
                             rate_mpps=rhos * mu, seeds=seeds)
    if sweep is None:
        bs = simulate_batch(grid, cfg, slot_us=slot_us,
                            stepping=stepping)
    else:
        # the precomputed sweep must be THIS lattice simulated in THIS
        # environment — matching shape alone would let metrics from one
        # grid be labeled with another grid's parameters
        same_axes = (sweep.grid.shape == grid.shape and all(
            np.array_equal(getattr(sweep.grid, f), getattr(grid, f))
            for f in ("t_s_us", "t_l_us", "m", "n_queues", "rate_mpps",
                      "seed")))
        if not (same_axes and sweep.cfg == cfg
                and sweep.slot_us == float(slot_us)
                and sweep.stepping == stepping):
            raise ValueError(
                "precomputed sweep does not match the requested lattice/"
                "environment (grid axes, SimRunConfig, slot_us and "
                "stepping must all be identical)")
        bs = sweep

    # seed-averaged metrics on the (ts, tl, m, nq, rho, seed) lattice
    lat = bs.reshaped("mean_latency_us").mean(axis=-1)
    cpu = bs.reshaped("cpu_fraction").mean(axis=-1)
    loss = bs.reshaped("loss_fraction").mean(axis=-1)
    vac = bs.reshaped("mean_vacation_us").mean(axis=-1)
    energy = bs.reshaped("energy_uj").mean(axis=-1)

    ts_ax = np.atleast_1d(np.asarray(t_s_grid, dtype=np.float64))
    tl_ax = np.atleast_1d(np.asarray(t_l_grid, dtype=np.float64))
    m_ax = np.atleast_1d(np.asarray(m_grid))
    # analytic guard: engine and closed form must roughly agree — at the
    # per-queue load, with the noisy-host slack for this environment
    valid = analytic_guard_mask(vac, ts_ax, tl_ax, m_ax, rhos,
                                guard_rel=analytic_guard_rel,
                                slot_us=slot_us,
                                n_queues=(cfg.n_queues,),
                                slack_us=cfg.interference_slack_us())
    feasible = valid & (lat <= target_mean_latency_us) & (loss <= max_loss)

    cost = cpu if objective == "cpu" else energy
    points = []
    big = np.inf
    for k, rho in enumerate(rhos):
        feas_k = feasible[..., k]
        if feas_k.any():
            cost_k = np.where(feas_k, cost[..., k], big)
            i, j, l, _ = np.unravel_index(int(np.argmin(cost_k)),
                                          cost_k.shape)
            met = True
        else:
            lat_k = np.where(valid[..., k], lat[..., k], big)
            if not np.isfinite(lat_k).any():
                lat_k = lat[..., k]                 # last resort: raw
            i, j, l, _ = np.unravel_index(int(np.argmin(lat_k)),
                                          lat_k.shape)
            met = False
        points.append(OperatingPoint(
            rho=float(rho), t_s_us=float(ts_ax[i]), t_l_us=float(tl_ax[j]),
            m=int(m_ax[l]), mean_latency_us=float(lat[i, j, l, 0, k]),
            cpu_fraction=float(cpu[i, j, l, 0, k]),
            loss_fraction=float(loss[i, j, l, 0, k]), meets_target=met,
            energy_uj=float(energy[i, j, l, 0, k])))

    env = asdict(cfg)
    if fleet is not None:
        env["fleet"] = asdict(fleet)
    # JSON-canonical from the start (tuples -> lists), so the recorded
    # environment survives a to_json/from_json round trip unchanged
    env = json.loads(json.dumps(env))
    table = OperatingTable(target_mean_latency_us=target_mean_latency_us,
                           service_rate_mpps=mu, points=tuple(points),
                           environment=env)

    if spot_check:
        # contention-honest: the exact engine re-examines selected points
        # in the environment the table claims to be calibrated for —
        # interference and stalls included.  (This used to quiet the
        # config first, laundering noisy-host tables through quiet-host
        # validation.)  Noisy environments get the batched engine's wider
        # documented parity floors.
        lat_floor, cpu_floor = (4.5, 0.04) if cfg.is_noisy else (2.0, 0.03)
        idxs = np.linspace(0, len(points) - 1,
                           min(spot_check, len(points))).astype(int)
        for i in sorted(set(idxs.tolist())):
            p = points[i]
            rs = _event_sim_point(p, cfg, p.rho * mu)
            lat_err = abs(rs.mean_sojourn_us - p.mean_latency_us)
            cpu_err = abs(rs.cpu_fraction - p.cpu_fraction)
            if (lat_err > spot_check_rel * p.mean_latency_us + lat_floor
                    or cpu_err > spot_check_rel * p.cpu_fraction
                    + cpu_floor):
                raise CalibrationMismatch(
                    f"operating point {p} failed its event-engine spot "
                    f"check: event mean sojourn {rs.mean_sojourn_us:.2f}us "
                    f"vs batched {p.mean_latency_us:.2f}us, event cpu "
                    f"{rs.cpu_fraction:.3f} vs batched "
                    f"{p.cpu_fraction:.3f}")
    if schedule_check is not None:
        schedule_spot_check(table, schedule_check, cfg=cfg)
    return table
