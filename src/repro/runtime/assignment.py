"""Thread↔queue assignment — *who* polls *which* Rx queue.

With N queues the paper's M sleep&wake threads can be organized three
ways, each a real deployment shape:

  - ``SharedAssignment``    every thread sweeps every queue in order —
    the paper's M threads generalized to N rings (and exactly what the
    threaded ``Runtime`` always did);
  - ``DedicatedAssignment`` one poller set *and one controller* per
    queue: each ring gets its own policy clone with its own M threads,
    the software analogue of per-ring interrupts (no cross-queue help,
    but per-queue timeouts adapt to per-queue load);
  - ``StealingAssignment``  threads are partitioned across home queues,
    drain their own ring first, then steal from the longest remaining
    backlog — dedicated's cache affinity with shared's tail behavior.

An assignment compiles ``(policy, n_queues)`` into ``ThreadSlot``s; both
execution backends (``repro.runtime.sim`` and ``repro.runtime.runtime``)
consume the same slots, so a strategy validated in simulation maps to OS
threads unchanged.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

__all__ = [
    "ThreadSlot",
    "Assignment",
    "SharedAssignment",
    "DedicatedAssignment",
    "StealingAssignment",
    "clone_policy",
]


def clone_policy(policy):
    """Independent copy of a policy with freshly-armed internal state
    (``DedicatedAssignment`` needs one controller per queue)."""
    p = copy.deepcopy(policy)
    p.reset()
    return p


@dataclass(frozen=True)
class ThreadSlot:
    """One poller thread's compiled assignment: the policy object it
    consults (possibly shared with other slots) and the queue indices it
    sweeps, in order.  ``steal=True`` lets it visit the longest unvisited
    backlog after its own queues run dry.  ``demote_on_miss=False`` keeps
    the primary cadence even when every lock was contended — right when
    the thread is its queue's *only* home poller (stealing), where a
    missed trylock means transient help, not a standing primary, and the
    paper's long backup timeout would abandon the ring."""

    policy: object
    queues: tuple[int, ...]
    steal: bool = False
    demote_on_miss: bool = True


@runtime_checkable
class Assignment(Protocol):
    name: str

    def slots(self, policy, n_queues: int) -> list[ThreadSlot]: ...


class SharedAssignment:
    """All ``policy.threads`` threads sweep all queues (one shared
    controller): today's ``Runtime._run`` behavior made explicit."""

    name = "shared"

    def slots(self, policy, n_queues: int) -> list[ThreadSlot]:
        order = tuple(range(n_queues))
        return [ThreadSlot(policy, order) for _ in range(policy.threads)]


class DedicatedAssignment:
    """One policy clone + one poller set per queue — per-ring interrupt
    semantics.  Total threads = ``policy.threads * n_queues``; each
    queue's controller adapts to that queue's load alone."""

    name = "dedicated"

    def slots(self, policy, n_queues: int) -> list[ThreadSlot]:
        out = []
        for q in range(n_queues):
            p = clone_policy(policy) if n_queues > 1 else policy
            out.extend(ThreadSlot(p, (q,)) for _ in range(p.threads))
        return out


class StealingAssignment:
    """``policy.threads`` threads with home queues ``i % n_queues``; a
    thread drains its home ring first, then steals from the longest
    backlog among rings it has not visited this wake."""

    name = "stealing"

    def slots(self, policy, n_queues: int) -> list[ThreadSlot]:
        homes = [i % n_queues for i in range(policy.threads)]
        # only demote threads whose home ring has redundant pollers; a
        # ring's sole home poller must keep its cadence (see ThreadSlot)
        return [ThreadSlot(policy, (h,), steal=True,
                           demote_on_miss=homes.count(h) > 1)
                for h in homes]
