from .engine import EngineConfig, InferenceEngine, Request  # noqa: F401
from .server import BusyPollServer, MetronomeServer, ServerStats  # noqa: F401
