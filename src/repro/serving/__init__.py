from .engine import EngineConfig, InferenceEngine, Request  # noqa: F401
from .server import (  # noqa: F401
    BusyPollServer,
    MetronomeServer,
    Server,
    ServerStats,
)
