"""Request ingress for the serving engine — the paper's architecture on
the serving path, expressed through the ``repro.runtime`` API.

One ``Server`` composes three pieces instead of hand-rolling a loop:

  - a ``BoundedQueue`` as the request ingress (the "NIC Rx ring");
  - any ``RetrievalPolicy`` deciding the retrieval cadence;
  - the generic threaded ``Runtime``, whose busy period drains ingress
    *and* keeps ``engine.pump()`` ticking until the engine goes idle.

So the exact policy object you validated in the simulator serves real
requests unchanged:

    srv = Server(engine, MetronomePolicy(cfg))
    srv.start(); srv.submit(req); ...; stats = srv.stop()

``MetronomeServer`` / ``BusyPollServer`` are deprecated aliases
(``Server`` + ``MetronomePolicy`` / ``BusyPollPolicy``); ``ServerStats``
is the unified ``repro.runtime.RunStats`` under its old name.  Stats
mirror the paper's evaluation: CPU fraction (awake-time), busy tries,
retrieval latency (enqueue -> retrieval), time-to-first-token.
"""

from __future__ import annotations

import warnings

from repro.core.controller import MetronomeConfig
from repro.core.hr_sleep import hr_sleep
from repro.runtime.policy import BusyPollPolicy, MetronomePolicy
from repro.runtime.queues import BoundedQueue
from repro.runtime.runtime import Runtime
from repro.runtime.stats import RunStats as ServerStats

from .engine import InferenceEngine, Request

__all__ = ["ServerStats", "Server", "MetronomeServer", "BusyPollServer"]

_DEFAULT_SERVING_CFG = dict(m=3, v_target_us=2_000.0, t_long_us=50_000.0)


class Server:
    """Serving ingress: ``Runtime`` + policy + engine, one class for every
    retrieval strategy."""

    def __init__(self, engine: InferenceEngine, policy, *,
                 queue_capacity: int = 1024, sleep_fn=hr_sleep):
        self.engine = engine
        self.policy = policy
        self.queue = BoundedQueue(queue_capacity)
        self._runtime = Runtime(
            [self.queue],
            process=self._ingest,
            policy=policy,
            sleep_fn=sleep_fn,
            # sample every retrieval: request rates are orders of magnitude
            # below packet rates, so the reservoir absorbs the cost
            latency_sample_every=1,
            idle_work=engine.pump,
        )

    def _ingest(self, reqs: list) -> None:
        self.engine.submit(reqs)

    # -- producer side ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        return self.queue.push(req)

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> None:
        self._runtime.start()
        self.stats.backend = "server"

    def stop(self, timeout: float = 10.0) -> ServerStats:
        return self._runtime.stop(timeout)

    @property
    def stats(self) -> ServerStats:
        return self._runtime.stats


class MetronomeServer(Server):
    """Deprecated alias for ``Server`` + ``MetronomePolicy``."""

    def __init__(self, engine: InferenceEngine,
                 cfg: MetronomeConfig | None = None,
                 *, queue_capacity: int = 1024, sleep_fn=hr_sleep):
        warnings.warn(
            "MetronomeServer is deprecated; use "
            "Server(engine, MetronomePolicy(cfg))",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg or MetronomeConfig(**_DEFAULT_SERVING_CFG)
        policy = MetronomePolicy(self.cfg)
        super().__init__(engine, policy, queue_capacity=queue_capacity,
                         sleep_fn=sleep_fn)
        self.controller = policy.controller


class BusyPollServer(Server):
    """Deprecated alias for ``Server`` + ``BusyPollPolicy`` (paper
    Listing 1 semantics: one dedicated spinning thread)."""

    def __init__(self, engine: InferenceEngine, *, queue_capacity: int = 1024):
        warnings.warn(
            "BusyPollServer is deprecated; use "
            "Server(engine, BusyPollPolicy())",
            DeprecationWarning, stacklevel=2)
        super().__init__(engine, BusyPollPolicy(), queue_capacity=queue_capacity)
