"""Request ingress for the serving engine — the paper's architecture on
the serving path, expressed through the ``repro.runtime`` API.

One ``Server`` composes three pieces instead of hand-rolling a loop:

  - ``n_queues`` ``BoundedQueue``s as the request ingress (the "NIC Rx
    rings"), fronted by a ``Dispatcher`` with request affinity (equal
    affinity keys always land in the same queue, like an RSS flow hash);
  - any ``RetrievalPolicy`` deciding the retrieval cadence;
  - the generic threaded ``Runtime``, whose busy period drains ingress
    *and* keeps ``engine.pump()`` ticking until the engine goes idle —
    with an ``Assignment`` deciding which threads sweep which queues.

So the exact policy object you validated in the simulator serves real
requests unchanged:

    srv = Server(engine, MetronomePolicy(cfg), n_queues=4)
    srv.start(); srv.submit(req); ...; stats = srv.stop()

``MetronomeServer`` / ``BusyPollServer`` are deprecated aliases
(``Server`` + ``MetronomePolicy`` / ``BusyPollPolicy``); ``ServerStats``
is the unified ``repro.runtime.RunStats`` under its old name.  Stats
mirror the paper's evaluation: CPU fraction (awake-time), busy tries,
retrieval latency (enqueue -> retrieval), time-to-first-token, and a
``per_queue`` breakdown when ingress is sharded.
"""

from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.core.controller import MetronomeConfig
from repro.core.hr_sleep import hr_sleep
from repro.runtime.dispatch import FlowHashDispatch, RoundRobinDispatch
from repro.runtime.policy import BusyPollPolicy, MetronomePolicy
from repro.runtime.queues import BoundedQueue
from repro.runtime.runtime import Runtime
from repro.runtime.stats import RunStats as ServerStats

from .engine import InferenceEngine, Request

__all__ = ["ServerStats", "Server", "MetronomeServer", "BusyPollServer"]

_DEFAULT_SERVING_CFG = dict(m=3, v_target_us=2_000.0, t_long_us=50_000.0)


def _affinity_key(req):
    """Stable per-request routing key: a session/user/flow attribute when
    the request carries one, else its id (unique => effectively random
    placement, still stable for the request's lifetime)."""
    for attr in ("session_id", "session", "user", "flow", "id"):
        key = getattr(req, attr, None)
        if key is not None:
            return key
    return None


class Server:
    """Serving ingress: ``Runtime`` + policy + engine, one class for every
    retrieval strategy.  ``n_queues > 1`` shards ingress across queues
    with affinity dispatch; ``assignment`` picks the thread↔queue
    strategy (shared / dedicated / stealing)."""

    def __init__(self, engine: InferenceEngine, policy, *,
                 queue_capacity: int = 1024, sleep_fn=hr_sleep,
                 n_queues: int = 1, dispatcher=None, assignment=None,
                 operating_table=None, app_load=None):
        """``app_load`` (an ``repro.runtime.apps.AppLoad``) co-runs a
        competing application on the serving host for the server's
        lifetime — the CPU-sharing deployment the paper argues
        sleep&wake retrieval enables; its progress lands in
        ``stats.app_ops`` / ``stats.app_cpu_ns``."""
        self.engine = engine
        self.policy = policy
        # calibrated operating table (repro.runtime.calibrate): accept a
        # ready table or a path to one saved by build_operating_table,
        # and install it as the policy controller's feed-forward term so
        # the server starts at pre-validated operating points
        if isinstance(operating_table, (str, bytes)) or hasattr(
                operating_table, "__fspath__"):
            from repro.runtime.calibrate import OperatingTable
            operating_table = OperatingTable.load(operating_table)
        self.operating_table = operating_table
        if operating_table is not None:
            ctl = getattr(policy, "controller", None)
            if ctl is None:
                raise ValueError(
                    f"policy {getattr(policy, 'name', policy)!r} has no "
                    "controller to install the operating table into")
            ctl.feedforward = operating_table
            ctl.__post_init__()        # re-derive T_S/T_L from the table
        self.queues = [BoundedQueue(queue_capacity)
                       for _ in range(max(n_queues, 1))]
        self.queue = self.queues[0]        # single-queue back-compat alias
        self.dispatcher = dispatcher or (
            FlowHashDispatch() if len(self.queues) > 1 else RoundRobinDispatch())
        self.dispatcher.reset(len(self.queues), np.random.default_rng(0))
        self._seq = 0
        self._submit_lock = threading.Lock()
        # With one queue the engine was implicitly serialized by the queue
        # lock (only its holder ingested/pumped).  Sharded ingress has
        # several lock holders at once, so the engine gets its own lock:
        # ingest blocks (it is short), pump try-locks — if a peer is
        # already pumping, this poller reports no progress and re-sleeps.
        self._engine_lock = threading.Lock()
        self._runtime = Runtime(
            self.queues,
            process=self._ingest,
            policy=policy,
            sleep_fn=sleep_fn,
            # sample every retrieval: request rates are orders of magnitude
            # below packet rates, so the reservoir absorbs the cost
            latency_sample_every=1,
            idle_work=self._pump,
            assignment=assignment,
            app_load=app_load,
        )
        self.app_load = app_load

    def _ingest(self, reqs: list) -> None:
        with self._engine_lock:
            self.engine.submit(reqs)

    def _pump(self) -> bool:
        if not self._engine_lock.acquire(blocking=False):
            return False
        try:
            return self.engine.pump()
        finally:
            self._engine_lock.release()

    # -- producer side ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        with self._submit_lock:
            seq = self._seq
            self._seq += 1
        backlogs = [len(q) for q in self.queues]
        i = self.dispatcher.pick(seq, backlogs, key=_affinity_key(req))
        return self.queues[i].push(req)

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> None:
        self._runtime.start()
        self.stats.backend = "server"

    def stop(self, timeout: float = 10.0) -> ServerStats:
        return self._runtime.stop(timeout)

    @property
    def stats(self) -> ServerStats:
        return self._runtime.stats

    # -- scheduled replay -------------------------------------------------------
    def replay(self, workload, *, duration_us: float, schedule=None,
               make_request=None, seed: int = 0,
               drain_timeout_s: float = 10.0) -> ServerStats:
        """Drive the server with a (possibly nonstationary) workload:
        start, submit one request per ``workload`` arrival at its
        scheduled wall-clock offset — ``schedule`` (a
        ``repro.runtime.schedule.LoadSchedule``) modulating the rate
        exactly as ``SimRunConfig.schedule`` does in simulation — then
        drain and stop.  ``make_request(i)`` builds the i-th request
        (default: a tiny 4-token prompt).  The returned stats carry the
        schedule descriptor, so live serving runs line up with
        simulated adaptation studies.
        """
        import time as _time

        # label with the BASE workload (the simulate_run / Runtime.run
        # convention): the schedule lands in stats.schedule, so rows
        # from every backend group by the same workload name
        base_wl = getattr(workload, "base", workload)
        workload_label = getattr(base_wl, "name", type(base_wl).__name__)
        if schedule is not None:
            from repro.runtime.workload import ScheduledWorkload
            workload = ScheduledWorkload(workload, schedule)
        if make_request is None:
            def make_request(i):
                return Request(prompt=[1, 2, 3, 4], max_new_tokens=4)
        rng = np.random.default_rng(seed)
        self.start()
        t0 = _time.monotonic_ns()
        n = 0
        max_lag_ns = 0
        for t_us in workload.iter_arrivals(duration_us, rng):
            gap_ns = t0 + int(t_us * 1e3) - _time.monotonic_ns()
            if gap_ns > 0:
                _time.sleep(gap_ns / 1e9)
            else:
                max_lag_ns = max(max_lag_ns, -gap_ns)
            self.submit(make_request(n))
            n += 1
        deadline = _time.monotonic() + drain_timeout_s
        while (any(len(q) for q in self.queues)
               and _time.monotonic() < deadline):
            _time.sleep(0.005)
        st = self.stop()
        st.workload = workload_label
        sched = schedule or getattr(workload, "schedule", None)
        st.schedule = sched.descriptor() if sched is not None else ""
        st.feeder_lag_us = max_lag_ns / 1e3
        return st


class MetronomeServer(Server):
    """Deprecated alias for ``Server`` + ``MetronomePolicy``."""

    def __init__(self, engine: InferenceEngine,
                 cfg: MetronomeConfig | None = None,
                 *, queue_capacity: int = 1024, sleep_fn=hr_sleep):
        warnings.warn(
            "MetronomeServer is deprecated; use "
            "Server(engine, MetronomePolicy(cfg))",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg or MetronomeConfig(**_DEFAULT_SERVING_CFG)
        policy = MetronomePolicy(self.cfg)
        super().__init__(engine, policy, queue_capacity=queue_capacity,
                         sleep_fn=sleep_fn)
        self.controller = policy.controller


class BusyPollServer(Server):
    """Deprecated alias for ``Server`` + ``BusyPollPolicy`` (paper
    Listing 1 semantics: one dedicated spinning thread)."""

    def __init__(self, engine: InferenceEngine, *, queue_capacity: int = 1024):
        warnings.warn(
            "BusyPollServer is deprecated; use "
            "Server(engine, BusyPollPolicy())",
            DeprecationWarning, stacklevel=2)
        super().__init__(engine, BusyPollPolicy(), queue_capacity=queue_capacity)
