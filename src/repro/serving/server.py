"""MetronomeServer — the paper's architecture deployed on the serving path.

The NIC Rx queue becomes the request ingress queue; "packet processing"
becomes engine.pump() (prefill + decode ticks).  M poller threads execute
the paper's Listing-2 loop verbatim: race for the queue lock via
trylock(), the winner drains ingress + runs the engine until idle (busy
period), losers instantly re-sleep; the adaptive controller (Eqs 10/12)
sets the primary timeout from the measured busy/vacation ratio so the
retrieval cadence tracks the offered request rate.

``BusyPollServer`` is the DPDK-classic baseline (Listing 1): one dedicated
thread spinning on the queue — same engine, 100% of a core.

Stats mirror the paper's evaluation: CPU fraction (awake-time), busy
tries, retrieval latency (enqueue -> retrieval), time-to-first-token.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core import (
    BoundedQueue,
    MetronomeConfig,
    MetronomeController,
    hr_sleep,
)
from .engine import InferenceEngine, Request

__all__ = ["ServerStats", "MetronomeServer", "BusyPollServer"]


@dataclass
class ServerStats:
    wakeups: int = 0
    busy_periods: int = 0
    busy_tries: int = 0
    awake_ns: int = 0
    started_ns: int = 0
    stopped_ns: int = 0
    retrieval_lat_us: list = field(default_factory=list)

    @property
    def cpu_fraction(self) -> float:
        dur = max(self.stopped_ns - self.started_ns, 1)
        return self.awake_ns / dur


class MetronomeServer:
    def __init__(self, engine: InferenceEngine,
                 cfg: MetronomeConfig | None = None,
                 *, queue_capacity: int = 1024,
                 sleep_fn=hr_sleep):
        self.engine = engine
        self.cfg = cfg or MetronomeConfig(
            m=3, v_target_us=2_000.0, t_long_us=50_000.0)
        self.controller = MetronomeController(self.cfg)
        self.queue = BoundedQueue(queue_capacity)
        self.sleep_fn = sleep_fn
        self.stats = ServerStats()
        self._stats_lock = threading.Lock()
        self._running = threading.Event()
        self._threads: list[threading.Thread] = []

    # -- producer side ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        return self.queue.push(req)

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> None:
        self.stats = ServerStats(started_ns=time.monotonic_ns())
        self._running.set()
        self._threads = [
            threading.Thread(target=self._run, name=f"metronome-srv-{i}",
                             daemon=True)
            for i in range(self.cfg.m)
        ]
        for t in self._threads:
            t.start()

    def stop(self, timeout: float = 10.0) -> ServerStats:
        self._running.clear()
        for t in self._threads:
            t.join(timeout)
        self.stats.stopped_ns = time.monotonic_ns()
        self.stats.busy_tries = self.queue.lock.busy_tries
        return self.stats

    # -- the paper's loop (Listing 2), serving edition ----------------------------
    def _run(self) -> None:
        ctrl = self.controller
        st = self.stats
        while self._running.is_set():
            t_wake = time.monotonic_ns()
            t_cpu0 = time.thread_time_ns()
            lock_taken = False
            if self.queue.lock.try_acquire():
                lock_taken = True
                try:
                    vacation_us = (t_wake - self.queue.last_busy_end_ns) / 1e3
                    # busy period: drain ingress + run engine until idle
                    while True:
                        burst = self.queue.poll(32)
                        if burst:
                            now = time.monotonic_ns()
                            lat = [(now - ts) / 1e3 for ts, _ in burst[:4]]
                            with self._stats_lock:
                                st.retrieval_lat_us.extend(lat)
                            self.engine.submit([r for _, r in burst])
                        did = self.engine.pump()
                        if not burst and not did:
                            break
                    t_busy_end = time.monotonic_ns()
                    self.queue.last_busy_end_ns = t_busy_end
                    ctrl.on_cycle_end((t_busy_end - t_wake) / 1e3,
                                      max(vacation_us, 1e-3))
                finally:
                    self.queue.lock.release()
            t_cpu1 = time.thread_time_ns()
            with self._stats_lock:
                st.wakeups += 1
                st.awake_ns += t_cpu1 - t_cpu0
                if lock_taken:
                    st.busy_periods += 1
            self.sleep_fn(ctrl.timeout_ns(primary=lock_taken))


class BusyPollServer:
    """Baseline: dedicated spinning thread (paper Listing 1 semantics)."""

    def __init__(self, engine: InferenceEngine, *, queue_capacity: int = 1024):
        self.engine = engine
        self.queue = BoundedQueue(queue_capacity)
        self.stats = ServerStats()
        self._running = threading.Event()
        self._thread: threading.Thread | None = None

    def submit(self, req: Request) -> bool:
        return self.queue.push(req)

    def start(self) -> None:
        self.stats = ServerStats(started_ns=time.monotonic_ns())
        self._running.set()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="busypoll-srv")
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> ServerStats:
        self._running.clear()
        if self._thread:
            self._thread.join(timeout)
        self.stats.stopped_ns = time.monotonic_ns()
        self.stats.awake_ns = self.stats.stopped_ns - self.stats.started_ns
        return self.stats

    def _run(self) -> None:
        st = self.stats
        while self._running.is_set():
            st.wakeups += 1
            burst = self.queue.poll(32)
            if burst:
                now = time.monotonic_ns()
                st.retrieval_lat_us.extend((now - ts) / 1e3
                                           for ts, _ in burst[:4])
                self.engine.submit([r for _, r in burst])
            self.engine.pump()
