"""Continuous-batching inference engine (slot-based KV cache).

The device side of the serving stack: a fixed pool of B cache slots; new
requests are prefillled (bucketed lengths to bound recompilation), their
KV inserted into a free slot, and one ``serve_step`` advances every active
slot per tick.  Host-side retrieval cadence — *when* ``pump()`` gets
called — is the paper's contribution and lives in server.py; the engine
itself is scheduler-agnostic.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

__all__ = ["Request", "EngineConfig", "InferenceEngine"]


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    id: int = field(default_factory=itertools.count().__next__)
    arrival_ns: int = field(default_factory=time.monotonic_ns)
    tokens: list[int] = field(default_factory=list)
    first_token_ns: int = 0
    done_ns: int = 0
    _done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout=None) -> bool:
        return self._done.wait(timeout)


@dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 128
    prefill_buckets: tuple = (16, 32, 64)
    eos_id: int = -1              # -1: run to max_new_tokens


class InferenceEngine:
    """Single-threaded engine: callers serialize via the server's trylock
    (paper Sec 3.2) — exactly one thread pumps at a time."""

    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        b, s = cfg.max_slots, cfg.max_len
        self.cache = model.init_cache(b, s)
        self.pos = np.zeros(b, np.int32)
        self.active: list[Request | None] = [None] * b
        self.pending: list[Request] = []
        self.steps = 0
        self.prefill_tokens = 0
        self.decoded_tokens = 0

        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._prefill = jax.jit(model.prefill)

        def insert_cache(cache, pre, slot):
            """Copy a B=1 prefill cache into batch-cache row `slot`.

            KV leaves (G, 1, S_pre, ...) land in positions [0, S_pre) of
            the slot row (rest zeroed); SSM/conv state leaves (shape equal
            to a slot row) are copied directly."""
            def put(c, p):
                row = c[:, slot]
                if p.shape[1] != 1:
                    return c
                src = p[:, 0]
                if row.ndim >= 3 and src.ndim == row.ndim and \
                        row.shape[0] == src.shape[0] and \
                        src.shape[1] <= row.shape[1]:
                    row = jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros_like(row), src.astype(row.dtype), 0, axis=1)
                else:
                    row = src.astype(row.dtype) if src.shape == row.shape \
                        else row
                return c.at[:, slot].set(row)
            return jax.tree.map(put, cache, pre)

        self._insert = jax.jit(insert_cache, donate_argnums=(0,),
                               static_argnums=(2,))

    # -- queue side -----------------------------------------------------------
    def submit(self, reqs: list[Request]) -> None:
        self.pending.extend(reqs)

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or any(r is not None for r in self.active)

    # -- engine tick ------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.cfg.prefill_buckets:
            if n <= b:
                return b
        return self.cfg.prefill_buckets[-1]

    def _admit(self) -> bool:
        if not self.pending:
            return False
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        req = self.pending.pop(0)
        prompt = req.prompt[-self.cfg.prefill_buckets[-1]:]
        bucket = self._bucket(len(prompt))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        logits, pre_cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        if isinstance(pre_cache, dict) and "self" in pre_cache:
            pre_cache = pre_cache["self"]       # encdec not served here
        self.cache = self._insert(self.cache, pre_cache, slot)
        next_tok = int(jnp.argmax(logits[0, len(prompt) - 1]))
        req.tokens.append(next_tok)
        req.first_token_ns = time.monotonic_ns()
        self.prefill_tokens += len(prompt)
        self.pos[slot] = len(prompt)
        self.active[slot] = req
        self._last_tok = getattr(self, "_last_tok",
                                 np.zeros(self.cfg.max_slots, np.int32))
        self._last_tok[slot] = next_tok
        return True

    def _decode_tick(self) -> bool:
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return False
        toks = jnp.asarray(self._last_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, toks, self.cache, pos)
        next_toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        for i in live:
            req = self.active[i]
            tok = int(next_toks[i])
            req.tokens.append(tok)
            self.decoded_tokens += 1
            self.pos[i] += 1
            self._last_tok[i] = tok
            if (len(req.tokens) >= req.max_new_tokens
                    or tok == self.cfg.eos_id
                    or self.pos[i] >= self.cfg.max_len - 1):
                req.done_ns = time.monotonic_ns()
                req._done.set()
                self.active[i] = None
        return True

    def pump(self) -> int:
        """Drain everything currently runnable (one busy period).
        Returns the number of engine ticks executed."""
        ticks = 0
        while True:
            admitted = self._admit()
            decoded = self._decode_tick()
            if not admitted and not decoded:
                return ticks
            ticks += 1
