"""DBRX-132B — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    moe_period=1,
    mlp_type="swiglu",
    tie_embeddings=False,
    rope_theta=500_000.0,
    moment_dtype="bfloat16",   # 132B total params: bf16 moments to fit 16GB/chip
    source="hf:databricks/dbrx-base; unverified",
))
