"""Assigned architecture pool (10 archs) + the paper's serving config.

Importing this package registers every config; use
``repro.configs.base.get_config(name)``.
"""

from .base import ModelConfig, ShapeConfig, SHAPES, cells, get_config, list_configs, register
from . import (  # noqa: F401  (registration side effects)
    internvl2_76b,
    dbrx_132b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    gemma_2b,
    gemma2_2b,
    starcoder2_15b,
    granite_3_8b,
    whisper_small,
    jamba_1_5_large_398b,
)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "cells", "get_config",
           "list_configs", "register"]
