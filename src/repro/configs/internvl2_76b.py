"""InternVL2-76B — InternViT + InternLM2 backbone [arXiv:2404.16821; unverified].

Per the assignment the modality frontend is a STUB: input_specs() provides
precomputed patch embeddings (``prefix_embeds``); this config is the
InternLM2-76B-style dense LM backbone.
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    mlp_type="swiglu",
    tie_embeddings=False,
    frontend="vision_stub",
    frontend_len=256,
    source="arXiv:2404.16821; unverified",
))
