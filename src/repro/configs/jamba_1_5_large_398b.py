"""Jamba-1.5-Large 398B — Mamba+attention 1:7 interleave, 16e top-2 MoE
[arXiv:2403.19887; hf].

Adaptation note (DESIGN.md): Jamba's SSM layers are Mamba-1; our SSM
substrate is the Mamba2/SSD block (the TPU-native chunked formulation),
with d_state=64.  Layer plan: attention on layer 0 of each 8-layer group,
MoE FFN every 2nd layer.
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_period=2,
    attn_period=8,          # 1 attention : 7 mamba
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    mlp_type="swiglu",
    tie_embeddings=False,
    use_rope=True,
    moment_dtype="bfloat16",  # 398B params: bf16 moments to fit 16GB/chip
    source="arXiv:2403.19887; hf",
))
