"""Mamba2-370M — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,               # mamba2 blocks have no separate FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
    use_rope=False,       # no attention; no positional encoding needed
    source="arXiv:2405.21060; unverified",
))
