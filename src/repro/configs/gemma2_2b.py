"""Gemma2-2B — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="geglu",
    local_global_period=2,
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    scale_embeddings=True,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
))
