"""Whisper-small — enc-dec, conv audio frontend (STUB per assignment)
[arXiv:2212.04356; unverified].

12 encoder + 12 decoder layers, learned positions (no RoPE); the audio
frontend is a stub — input_specs() provides precomputed frame embeddings.
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,            # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    use_rope=False,
    tie_embeddings=True,
    frontend="audio_stub",
    source="arXiv:2212.04356; unverified",
))
