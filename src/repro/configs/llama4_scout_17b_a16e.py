"""Llama-4 Scout 17B-active/16E — top-1 MoE + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    experts_per_token=1,
    n_shared_experts=1,
    moe_period=1,
    mlp_type="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
