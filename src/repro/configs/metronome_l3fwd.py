"""The paper's own evaluated configuration (Sec 5): the l3fwd testbed.

Not a neural architecture — the Metronome serving/retrieval config the
paper tunes and measures.  Exposed here as the canonical parameter set the
benchmarks and simulator default to, with the paper's tuning rationale.
"""

from repro.core import MetronomeConfig
from repro.core.simulator import HR_SLEEP_MODEL, SimConfig

# Sec 5 defaults: V-bar = 10us (first no-loss point, Table 2),
# T_L = 500us (>= 50x max T_S; Fig 7 knee), M = 3 (Fig 8/9: more threads
# waste wakeups and hurt tail latency), 1024-descriptor Rx ring.
PAPER_CONFIG = MetronomeConfig(
    m=3,
    v_target_us=10.0,
    t_long_us=500.0,
    alpha=0.125,
)

# 10GbE worst case: 64B packets = 14.88 Mpps; drain rate ~2x line rate
# (consistent with the paper's measured B ~= V at line rate, Table 2).
PAPER_SIM = SimConfig(
    m=PAPER_CONFIG.m,
    arrival_rate_mpps=14.88,
    service_rate_mpps=29.76,
    queue_capacity=1024,
    v_target_us=PAPER_CONFIG.v_target_us,
    t_long_us=PAPER_CONFIG.t_long_us,
    alpha=PAPER_CONFIG.alpha,
    adaptive=True,
    sleep_model=HR_SLEEP_MODEL,
)
