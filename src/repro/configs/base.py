"""Model/shape configuration system for the assigned architecture pool.

Every architecture is a ``ModelConfig``; the four assigned input shapes are
``ShapeConfig``s.  ``reduced()`` produces the family-preserving small config
used by CPU smoke tests (full configs are only ever lowered via the dry-run,
never allocated).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "register", "get_config", "list_configs"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1            # MoE FFN every `moe_period` layers
    n_shared_experts: int = 0      # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (jamba): attention layer every `attn_period` layers ---
    attn_period: int = 0           # 0 -> all attention (or all ssm if family=ssm)

    # --- attention / block features ---
    rope_theta: float = 10_000.0
    use_rope: bool = True
    mlp_type: str = "swiglu"       # swiglu | geglu | gelu
    logit_softcap: float = 0.0     # gemma2 final-logit softcap
    attn_softcap: float = 0.0      # gemma2 attention-logit softcap
    local_window: int = 0          # sliding-window size for local layers
    local_global_period: int = 0   # gemma2: local,global alternating (=2)
    scale_embeddings: bool = False # gemma family: embeds *= sqrt(d_model)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    qk_norm: bool = False

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0      # >0 => enc-dec; n_layers = decoder layers

    # --- modality frontend stub ---
    frontend: str = ""             # "" | "vision_stub" | "audio_stub"
    frontend_len: int = 0          # prefix embedding positions (vlm)

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # int8 KV cache (per-token-per-head symmetric scales): halves decode
    # cache residency + reads; scales factor out of both attention einsums
    # (beyond-paper serving optimization, EXPERIMENTS.md §Perf B2)
    kv_quant: bool = False
    # ring-buffer KV for local-window layers: cache length = window instead
    # of seq_len (gemma2's 13 local layers keep 4096 slots, not 32768)
    kv_ring: bool = False
    # Optimizer moment dtype; jamba/dbrx-scale models use bf16 moments so a
    # 16 GB/chip pod fits params+grads+moments (documented in EXPERIMENTS.md).
    moment_dtype: str = "float32"

    # --- source provenance (public literature tag from the assignment) ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def layer_plan(self) -> tuple[tuple[str, str], ...]:
        """Per-layer (mixer, ffn) plan for the decoder stack.

        mixer: 'attn' | 'attn_local' | 'ssm';  ffn: 'dense' | 'moe'.
        """
        plan = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "ssm"
            elif self.attn_period:
                mixer = "attn" if i % self.attn_period == 0 else "ssm"
            elif self.local_global_period:
                # gemma2 order: local first, then global (arXiv:2408.00118)
                mixer = "attn_local" if i % self.local_global_period == 0 else "attn"
            else:
                mixer = "attn"
            ffn = "moe" if (self.n_experts and i % self.moe_period == 0) else "dense"
            if self.family == "ssm":
                ffn = "none"  # mamba2 blocks have no separate FFN
            plan.append((mixer, ffn))
        return tuple(plan)

    def scan_unit(self) -> int:
        """Smallest repeating unit of the layer plan (scan over repeats)."""
        plan = self.layer_plan()
        n = len(plan)
        for p in range(1, n + 1):
            if n % p == 0 and all(plan[i] == plan[i % p] for i in range(n)):
                return p
        return n

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        unit = self.scan_unit()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(unit * 2, 2) if unit * 2 <= self.n_layers else unit,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 2,
            head_dim=16,
            d_ff=128,
            vocab_size=503,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # no-drop capacity so forward == prefill+decode exactly in tests
            # (capacity-based dropping is sequence-length dependent)
            capacity_factor=float(max(self.n_experts, 1)),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            local_window=min(self.local_window, 8) if self.local_window else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            frontend_len=min(self.frontend_len, 8) if self.frontend_len else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k only runs on sub-quadratic archs (DESIGN.md §Arch-applicability).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def cells(include_skips: bool = False):
    """All (arch, shape) cells; skips per DESIGN.md unless include_skips."""
    out = []
    for name in list_configs():
        cfg = _REGISTRY[name]
        for sname, shape in SHAPES.items():
            skip = (sname == "long_500k"
                    and cfg.family not in SUBQUADRATIC_FAMILIES)
            if skip and not include_skips:
                continue
            out.append((name, sname, skip))
    return out
