"""StarCoder2-15B — GQA (kv=4), RoPE, GELU FFN [arXiv:2402.19173; hf]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    tie_embeddings=False,
    source="arXiv:2402.19173; hf",
))
