from .analysis import (  # noqa: F401
    HW,
    CellReport,
    analyze_compiled,
    collective_bytes,
    model_flops,
    roofline_terms,
)
