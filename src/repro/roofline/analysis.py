"""Three-term roofline from the compiled dry-run artifact (spec §Roofline).

Per (arch x shape x mesh) cell, from the SPMD-partitioned (= per-device)
module:

    compute    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
    memory     = HLO_bytes_per_device   / HBM_byte/s_per_chip
    collective = coll_bytes_per_device  / ICI_byte/s_per_link

cost_analysis() on the partitioned module reports *per-device* numbers
(verified empirically: a (64,256)@(256,512) matmul over an 8-device 2x4
mesh reports 2.1 MFLOP = global/8), so no division by chip count.

collective_bytes parses the optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction contributes the byte size of its *operands* (looked up from an
instruction-name -> shape index, since operands print as bare %refs).

Hardware constants: TPU v5e-class chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (from the assignment).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.compat import cost_analysis_dict

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "link_bw": 50e9,          # bytes/s per ICI link
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

# '%name = type[dims]{layout} opcode(...)'   (also tuple results)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (sums tuple components)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type operand bytes (per device) + instruction counts."""
    shapes: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)
    out = {op: 0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        opcode = m.group(3)
        base = opcode.replace("-start", "").replace("-done", "")
        if base not in _COLL_OPS or opcode.endswith("-done"):
            continue
        counts[base] += 1
        # operands are inside the parens following the opcode
        paren = ln[ln.index(opcode + "(") + len(opcode) + 1:]
        depth, i = 1, 0
        while i < len(paren) and depth:
            if paren[i] == "(":
                depth += 1
            elif paren[i] == ")":
                depth -= 1
            i += 1
        ops = _OPERAND_RE.findall(paren[:i - 1])
        got = sum(_shape_bytes(shapes.get(o, "")) for o in ops)
        if got == 0:
            # operands printed with inline types (older format)
            got = _shape_bytes(paren[:i - 1])
        out[base] += got
    out["total"] = sum(out[o] for o in _COLL_OPS)
    out["counts"] = counts
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    t_c = flops_per_dev / HW["peak_flops"]
    t_m = bytes_per_dev / HW["hbm_bw"]
    t_x = coll_bytes_per_dev / HW["link_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["bound_s"] = max(t_c, t_m, t_x)
    # roofline fraction: how much of the binding resource the useful
    # (compute) work occupies if perfectly overlapped
    terms["roofline_fraction"] = t_c / max(terms["bound_s"], 1e-30)
    return terms


def count_params(params_tree) -> tuple[int, int]:
    """(total, active) parameter counts from an eval_shape params tree."""
    import jax

    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        n = int(np.prod(leaf.shape))
        total += n
    return total, active


def model_flops(cfg, shape, params_tree) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward) with N = active params.

    Active params: MoE expert weights count k/E of their size (top-k of E
    experts touched per token); everything else counts fully.
    """
    import jax

    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        names = [getattr(k, "key", "") for k in path]
        n = float(np.prod(leaf.shape))
        stacked = 1 if "blocks" in names else 0
        is_moe_w = (cfg.n_experts and leaf.ndim - stacked == 3
                    and names[-1] in ("w_gate", "w_up", "w_down"))
        if is_moe_w:
            n *= cfg.experts_per_token / cfg.n_experts
        if names[-1] in ("embed", "pos_embed") :
            continue  # gather, not matmul
        if names[-1] == "lm_head":
            pass
        total += n
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * total * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * total * tokens
    # decode: one token per sequence
    return 2.0 * total * shape.global_batch


@dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float = 0.0
    bytes_per_dev: float = 0.0
    coll_bytes_per_dev: float = 0.0
    coll_detail: dict = field(default_factory=dict)
    terms: dict = field(default_factory=dict)
    model_flops_global: float = 0.0
    arg_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0
    out_bytes: int = 0
    compile_s: float = 0.0
    n_devices: int = 0

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops aggregated over chips)."""
        agg = self.flops_per_dev * max(self.n_devices, 1)
        return self.model_flops_global / agg if agg else 0.0

    def row(self) -> dict:
        t = self.terms
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": t.get("compute_s", 0), "memory_s": t.get("memory_s", 0),
            "collective_s": t.get("collective_s", 0),
            "dominant": t.get("dominant", "?"),
            "roofline_fraction": t.get("roofline_fraction", 0),
            "useful_flops_ratio": self.useful_flops_ratio,
            "arg_gb": self.arg_bytes / 1e9, "temp_gb": self.temp_bytes / 1e9,
            "peak_gb": self.peak_bytes / 1e9,
            "compile_s": self.compile_s,
        }


def analyze_compiled(arch, shape_name, mesh_name, compiled, *,
                     model_flops_global: float, n_devices: int,
                     compile_s: float = 0.0) -> CellReport:
    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    rep = CellReport(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(coll["total"]),
        coll_detail=coll,
        model_flops_global=model_flops_global,
        n_devices=n_devices,
        compile_s=compile_s,
    )
    if ma is not None:
        rep.arg_bytes = int(getattr(ma, "argument_size_in_bytes", 0))
        rep.temp_bytes = int(getattr(ma, "temp_size_in_bytes", 0))
        rep.peak_bytes = int(getattr(ma, "peak_memory_in_bytes", 0))
        rep.out_bytes = int(getattr(ma, "output_size_in_bytes", 0))
    rep.terms = roofline_terms(rep.flops_per_dev, rep.bytes_per_dev,
                               rep.coll_bytes_per_dev)
    return rep
