"""Train / serve step functions (jit entry points for launcher + dry-run)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.sharding.logical import shard
from .optimizer import OptConfig, apply_updates

__all__ = ["make_loss_fn", "make_train_step", "make_prefill_step",
           "make_serve_step"]


def make_loss_fn(model: Model, *, remat: bool = True):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, remat=remat)
        extra = cfg.frontend_len if cfg.frontend else 0
        logits = logits[:, extra:]
        labels = batch["labels"]
        # lse - gold formulation: never materializes log-probs, and the
        # gold gather is a one-hot contraction (XLA fuses iota+eq+reduce)
        # rather than take_along_axis — a gather along the vocab-sharded
        # axis would force GSPMD to all-gather the full (B,S,V) logits.
        logits32 = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits32, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
        gold = jnp.einsum("bsv,bsv->bs", logits32, onehot)
        ce = (lse - gold).mean()
        loss = ce + cfg.router_aux_coef * aux["moe_aux"]
        return loss, {"ce": ce, "moe_aux": aux["moe_aux"]}

    return loss_fn


def make_train_step(model: Model, opt_cfg: OptConfig, *, remat: bool = True,
                    accum_steps: int = 1):
    """One optimizer step.  ``accum_steps > 1`` splits the global batch into
    microbatches and accumulates gradients in fp32 via lax.scan — the
    standard large-scale lever for growing effective batch beyond
    activation memory (each microbatch's backward frees before the next)."""
    loss_fn = make_loss_fn(model, remat=remat)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda v: v.reshape((accum_steps, v.shape[0] // accum_steps)
                                    + v.shape[1:]), batch)

            def body(carry, microbatch):
                gsum, lsum, asum = carry
                (l, mets), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, microbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, asum + mets["moe_aux"]), mets["ce"]

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, asum), ces = jax.lax.scan(
                body, (g0, jnp.float32(0.0), jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: (g / accum_steps), gsum)
            loss = lsum / accum_steps
            metrics = {"ce": ces.mean(), "moe_aux": asum / accum_steps}
        params, opt_state, gnorm = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok, logits, cache

    return prefill_step


def make_serve_step(model: Model):
    """One decode step: token in, token out, cache updated in place."""

    def serve_step(params, tokens, cache, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos)
        next_tok = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return next_tok, cache

    return serve_step
