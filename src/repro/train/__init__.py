from .checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .compression import (  # noqa: F401
    compressed_psum_int8,
    dequantize_int8,
    make_dp_grad_fn,
    quantize_int8,
)
from .data import HostPrefetcher, TokenDataset  # noqa: F401
from .loop import train_loop  # noqa: F401
from .optimizer import OptConfig, apply_updates, global_norm, init_opt  # noqa: F401
from .steps import (  # noqa: F401
    make_loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
