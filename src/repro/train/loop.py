"""Fault-tolerant training loop (checkpoint/restart, async saves,
deterministic resume).

``train_loop`` drives (model, optimizer, data) for N steps with:
  - restore-from-latest on entry (crash/preemption restart = rerun);
  - async checkpointing every ``save_every`` steps;
  - a ``failure_injector`` hook for tests (simulated preemption at step k
    raises, the next train_loop call resumes from the last checkpoint and
    must reproduce the uninterrupted loss trajectory bit-for-bit given the
    deterministic data pipeline);
  - straggler/hang mitigation at the host level: the step is wrapped in a
    watchdog that logs if a step exceeds ``step_timeout_s`` (on real pods
    this is where you'd fence the slow host and re-shard — single-process
    here, so it's observability only).
"""

from __future__ import annotations

import logging
import time

import jax
import numpy as np

from repro.models import Model
from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from .data import HostPrefetcher, TokenDataset
from .optimizer import OptConfig, init_opt
from .steps import make_train_step

log = logging.getLogger("repro.train")

__all__ = ["train_loop"]


def train_loop(cfg, *, steps: int, ckpt_dir: str, seed: int = 0,
               global_batch: int = 8, seq_len: int = 32,
               opt_cfg: OptConfig | None = None, save_every: int = 20,
               remat: bool = False, failure_injector=None,
               step_timeout_s: float = 120.0) -> dict:
    """Returns {'losses': [...], 'final_step': int, 'resumed_from': int}."""
    model = Model(cfg)
    opt_cfg = opt_cfg or OptConfig(lr=1e-3, moment_dtype=cfg.moment_dtype)
    ds = TokenDataset(cfg.vocab_size, seq_len, global_batch, seed=seed)
    step_fn = jax.jit(make_train_step(model, opt_cfg, remat=remat),
                      donate_argnums=(0, 1))

    start = latest_step(ckpt_dir)
    if start is not None:
        params_like = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(seed), max_seq=seq_len * 2))
        opt_like = jax.eval_shape(lambda p: init_opt(p, opt_cfg), params_like)
        state, meta = restore_checkpoint(
            ckpt_dir, start, {"params": params_like, "opt": opt_like})
        params, opt_state = state["params"], state["opt"]
        resumed_from = start
        first = start
        log.info("resumed from checkpoint step %d", start)
    else:
        params = model.init(jax.random.PRNGKey(seed), max_seq=seq_len * 2)
        opt_state = init_opt(params, opt_cfg)
        resumed_from = -1
        first = 0

    ckpt = AsyncCheckpointer(ckpt_dir)
    # host input overlap: the prefetcher synthesizes batches ahead of the
    # device step, idling Metronome-style rather than spinning (DESIGN §2)
    prefetch = HostPrefetcher(ds, start_step=first, depth=2)
    losses = []
    try:
        for step in range(first, steps):
            if failure_injector is not None:
                failure_injector(step)
            batch = jax.tree.map(jax.numpy.asarray, prefetch.get(step))
            t0 = time.monotonic()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if dt > step_timeout_s:
                log.warning("straggler: step %d took %.1fs (> %.1fs budget)",
                            step, dt, step_timeout_s)
            losses.append(loss)
            assert np.isfinite(loss), f"loss diverged at step {step}"
            if (step + 1) % save_every == 0 or step + 1 == steps:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"loss": loss})
    finally:
        prefetch.stop()
        # Drain the pending async save even on a crash/preemption exit, or
        # the restart resumes from an older checkpoint than was scheduled.
        ckpt.wait()
    return {"losses": losses, "final_step": steps, "resumed_from": resumed_from}
