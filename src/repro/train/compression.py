"""Gradient compression for cross-pod reduction (distributed-optimization
trick; DESIGN.md §4).

``compressed_psum_int8`` runs inside ``shard_map``: per-device gradient
shards are quantized to int8 with a per-tensor fp32 scale, summed via an
int32 ``psum`` on the wire... except a true int8 wire-sum overflows, so
the standard deployment (and ours) is all-gather(int8) + local dequant
sum: moved bytes drop 4x vs fp32 all-reduce (2x vs bf16), at ~0.4% grad
RMS error (stochastic rounding keeps it unbiased).

``make_dp_grad_fn`` builds a shard_map data-parallel gradient step using
the compressed reduction — the HLO-visible all-gather operand is int8,
which tests/test_train_substrate.py asserts from the lowered text.
"""

from __future__ import annotations

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_int8",
           "make_dp_grad_fn"]


def quantize_int8(x, key=None):
    """Per-tensor symmetric int8 with optional stochastic rounding."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-20) / 127.0
    y = x32 / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(tree, axis_name: str, key=None):
    """int8 all-gather + local dequant-sum over `axis_name` (in shard_map)."""
    def one(i, g):
        k = jax.random.fold_in(key, i) if key is not None else None
        q, scale = quantize_int8(g, k)
        qs = jax.lax.all_gather(q, axis_name)            # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)
        return jnp.sum(qs.astype(jnp.float32) *
                       ss.reshape((-1,) + (1,) * g.ndim),
                       axis=0).astype(g.dtype)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = [one(i, g) for i, g in enumerate(leaves)]
    return treedef.unflatten(out)


def make_dp_grad_fn(loss_fn, mesh, *, compress: bool = True,
                    data_axis: str = "data"):
    """Data-parallel gradient with (optionally compressed) reduction.

    loss_fn(params, batch) -> scalar.  Returns fn(params, batch) -> grads
    where params are replicated and batch is sharded on `data_axis`.
    """
    def local_grads(params, batch):
        g = jax.grad(loss_fn)(params, batch)
        n = jax.lax.psum(1, data_axis)
        if compress:
            g = compressed_psum_int8(g, data_axis)
        else:
            g = jax.tree.map(lambda x: jax.lax.psum(x, data_axis), g)
        return jax.tree.map(lambda x: x / n, g)

    return jax.jit(shard_map(
        local_grads, mesh=mesh,
        in_specs=(P(), P(data_axis)),
        out_specs=P(),
        check_vma=False,
    ))
