"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step) — counter-based Philox
bits, no stored iterator state — so checkpoint resume and elastic
restarts reproduce the exact token stream by construction (the resume
test asserts bit-equality).  A host prefetcher overlaps batch synthesis
with device compute; its idle behaviour is Metronome-style sleep&wake
rather than a spin loop (the paper's technique applied to the training
input path — DESIGN.md §2).
"""

from __future__ import annotations

import collections
import threading
from dataclasses import dataclass

import numpy as np

from repro.core import MetronomeConfig, MetronomeController, hr_sleep

__all__ = ["TokenDataset", "HostPrefetcher"]


@dataclass(frozen=True)
class TokenDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        """Batch for `step` — stateless, O(1) seek."""
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        tokens = rng.integers(0, self.vocab_size,
                              (self.global_batch, self.seq_len + 1),
                              dtype=np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class HostPrefetcher:
    """Depth-k batch prefetcher with Metronome sleep&wake idle behaviour."""

    def __init__(self, ds: TokenDataset, start_step: int, *, depth: int = 2,
                 v_target_us: float = 500.0):
        self.ds = ds
        self.depth = depth
        self._buf: collections.deque = collections.deque()
        self._next = start_step
        self._take = start_step
        self._lock = threading.Lock()
        self._running = threading.Event()
        self._running.set()
        self._ctrl = MetronomeController(
            MetronomeConfig(m=1, v_target_us=v_target_us,
                            t_long_us=v_target_us * 20))
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        import time
        while self._running.is_set():
            t0 = time.monotonic_ns()
            did = False
            with self._lock:
                room = self.depth - len(self._buf)
                nxt = self._next
            for _ in range(max(room, 0)):
                b = self.ds.batch(nxt)
                with self._lock:
                    self._buf.append((nxt, b))
                    self._next = nxt = nxt + 1
                did = True
            busy_us = (time.monotonic_ns() - t0) / 1e3
            self._ctrl.on_cycle_end(busy_us if did else 0.0,
                                    max(self._ctrl.timeout_us(primary=True), 1.0))
            hr_sleep(self._ctrl.timeout_ns(primary=did))

    def get(self, step: int) -> dict:
        """Batch for `step`; blocks briefly if the producer is behind."""
        while True:
            with self._lock:
                while self._buf and self._buf[0][0] < step:
                    self._buf.popleft()
                if self._buf and self._buf[0][0] == step:
                    return self._buf.popleft()[1]
                # seek (elastic restart onto a different step)
                if not self._buf and self._next != step:
                    self._next = step
            hr_sleep(100_000)

    def stop(self) -> None:
        self._running.clear()
        self._thread.join(1.0)
