"""Checkpointing: atomic, async, elastic (reshard-on-restore).

Layout:  <dir>/step_<N>/{leaves.npz, meta.json}
  - leaves.npz holds every pytree leaf under its '/'-joined key path;
  - meta.json records step + tree structure for validation.

Restore takes an optional ``shardings`` tree: leaves are device_put with
the *target* sharding, so a checkpoint written on one mesh restores onto
any other mesh (elastic scaling — a fresh jax.device_put reshards; the
full array is the interchange format).  AsyncCheckpointer snapshots to
host (one blocking device->host copy) then writes in a background thread,
keeping the train loop running during I/O; ``keep`` bounds disk usage.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]


def _keys(tree) -> list[str]:
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for key, leaf in zip(_keys(tree), jax.tree_util.tree_flatten(tree)[0]):
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":
            # ml_dtypes (bfloat16, fp8) don't round-trip through np.savez;
            # store as float32 (exact for bf16) and re-cast on restore.
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        leaves = _flatten(tree)
        np.savez(os.path.join(tmp, "leaves.npz"), **leaves)
        meta = {"step": step, "n_leaves": len(leaves),
                "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of `like` (a pytree or eval_shape tree).

    `shardings`: optional matching tree of Sharding — leaves are placed
    with the target sharding (elastic reshard-on-restore)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    keys = _keys(like)
    if set(keys) != set(data.files):
        missing = set(keys) ^ set(data.files)
        raise ValueError(f"checkpoint/model tree mismatch: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    restored = []
    for key, ref, shd in zip(keys, leaves_like, shard_leaves):
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        restored.append(jax.device_put(arr, shd) if shd is not None
                        else jax.device_put(arr))
    return treedef.unflatten(restored), meta


class AsyncCheckpointer:
    """Snapshot-to-host then write in the background; keeps last `keep`."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, *, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # blocking D2H snapshot

        def _write():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)
