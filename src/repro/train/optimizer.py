"""AdamW (from scratch, pure JAX) with global-norm clipping and
configurable moment dtype (bf16 moments let 132B/398B models fit
16 GB/chip pods — see configs).  Moments inherit the parameter sharding, so
with FSDP params this is ZeRO-1/2 automatically (XLA emits
reduce-scatter(grads) + all-gather(params) instead of all-reduce).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt", "apply_updates", "global_norm"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_opt(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - cfg.lr * step).astype(p.dtype)
        return newp, m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
