"""Userspace adaptation of the paper's ``hr_sleep()`` kernel service.

The paper's hr_sleep() is a Linux kernel module: it passes the sleep period
in a register (no cross-ring copy), keeps the timer entry on the kernel
stack (no allocator), and thereby starts the hrtimer with minimal preamble,
achieving ~15x better precision than nanosleep() for SCHED_OTHER threads
(paper Table 1).

We cannot load kernel modules here, so we implement the closest userspace
equivalent — a *hybrid* sleep:

  1. bulk:  ``time.sleep()`` (CPython -> clock_nanosleep(CLOCK_MONOTONIC))
            for ``target - margin`` where ``margin`` is the calibrated p99
            overshoot of the underlying timer on this host;
  2. tail:  a bounded spin on ``perf_counter_ns`` for the residual.

The API contract mirrors the paper: a single scalar (nanoseconds), no
per-call allocation on the hot path.  Like the paper's patched variant
(Sec 5.4) sub-microsecond requests may return immediately when
``sub_us_immediate=True``.

Precision is *measured*, never assumed: ``measure_precision`` reproduces the
structure of paper Table 1 (mean / p99 achieved sleep for a sweep of
targets) for both this hybrid sleep and the naive baseline, and
benchmarks/bench_sleep_precision.py reports it.

Trade-off vs the paper (documented in DESIGN.md): the spin tail burns CPU
for up to ``margin`` ns per call, whereas the kernel module sleeps the whole
interval.  ``margin`` is therefore calibrated as small as the host's timer
jitter allows, and callers that prefer zero spin (pure CPU saving, paper
semantics) can use ``naive_sleep`` or ``hr_sleep(..., spin_cap_ns=0)``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

__all__ = [
    "SleepCalibration",
    "calibrate",
    "naive_sleep",
    "hr_sleep",
    "make_hr_sleep",
    "measure_precision",
]

_NS = 1e-9


@dataclass(frozen=True)
class SleepCalibration:
    """Host timer characteristics measured at import/calibration time."""

    margin_ns: int          # p99 overshoot of time.sleep for us-scale targets
    min_sleep_ns: int       # mean achieved duration of time.sleep(probe_ns)
    spin_resolution_ns: int  # granularity of perf_counter_ns spin loop


def calibrate(samples: int = 200, probe_ns: int = 1_000) -> SleepCalibration:
    """Measure the naive timer's overshoot so the hybrid knows its margin.

    ``margin_ns`` is the p99 overshoot of ``time.sleep(probe_ns)``,
    floored at both the measured spin resolution (a margin the spin
    loop cannot even resolve buys no precision, it only burns CPU) and
    1us (the smallest bulk/spin split worth making);
    ``min_sleep_ns`` is the mean *achieved* duration of a
    ``time.sleep(probe_ns)`` request — the shortest sleep this host's
    timer can actually deliver at the probe scale, i.e. ``probe_ns``
    plus the mean overshoot."""
    overshoot = np.empty(samples)
    for i in range(samples):
        t0 = time.perf_counter_ns()
        time.sleep(probe_ns * _NS)
        overshoot[i] = time.perf_counter_ns() - t0 - probe_ns
    # Spin-loop granularity: consecutive perf_counter_ns deltas.
    t = [time.perf_counter_ns() for _ in range(64)]
    deltas = np.diff(t)
    res = int(max(np.median(deltas), 1))
    margin = int(np.percentile(overshoot, 99))
    return SleepCalibration(
        margin_ns=max(margin, res, 1_000),
        min_sleep_ns=int(np.mean(overshoot)) + probe_ns,
        spin_resolution_ns=res,
    )


_CAL: SleepCalibration | None = None


def _get_cal() -> SleepCalibration:
    global _CAL
    if _CAL is None:
        _CAL = calibrate()
    return _CAL


def naive_sleep(duration_ns: int) -> None:
    """Baseline: plain clock_nanosleep — the paper's ``nanosleep()`` arm."""
    time.sleep(duration_ns * _NS)


def hr_sleep(
    duration_ns: int,
    *,
    sub_us_immediate: bool = False,
    spin_cap_ns: int | None = None,
) -> None:
    """Precise hybrid sleep for ``duration_ns`` nanoseconds.

    ``spin_cap_ns`` bounds the CPU-burning tail; ``None`` uses the calibrated
    margin, ``0`` degenerates to the naive timer (paper-pure CPU semantics).
    """
    if sub_us_immediate and duration_ns < 1_000:
        return  # paper Sec 5.4: patched immediate return for sub-us requests
    cal = _get_cal()
    deadline = time.perf_counter_ns() + duration_ns
    margin = cal.margin_ns if spin_cap_ns is None else spin_cap_ns
    bulk = duration_ns - margin
    if bulk > 0:
        time.sleep(bulk * _NS)
    if margin == 0:
        if bulk <= 0:
            time.sleep(duration_ns * _NS)
        return
    while time.perf_counter_ns() < deadline:
        pass  # bounded by `margin` ns


def make_hr_sleep(**kwargs):
    """Bind hr_sleep options once; returns a 1-arg callable for hot loops."""
    def _sleep(duration_ns: int) -> None:
        hr_sleep(duration_ns, **kwargs)
    return _sleep


def measure_precision(sleep_fn, targets_ns, samples: int = 300):
    """Paper Table 1 methodology: wall-clock between invoke and resume.

    Returns {target_ns: (mean_ns, p99_ns)} of the *achieved* sleep length.
    """
    out = {}
    for tgt in targets_ns:
        achieved = np.empty(samples)
        for i in range(samples):
            t0 = time.perf_counter_ns()
            sleep_fn(int(tgt))
            achieved[i] = time.perf_counter_ns() - t0
        out[int(tgt)] = (float(np.mean(achieved)), float(np.percentile(achieved, 99)))
    return out
