"""Deprecated shim: the discrete-event simulator lives in ``repro.runtime``.

The engine (``repro.runtime.sim.simulate_run``) now executes any
``RetrievalPolicy`` against any ``Workload``; this module keeps the
original paper-specific surface — ``SimConfig`` (one flat dataclass of
paper knobs), ``SimResult``, ``simulate``, ``simulate_busy_poll`` — as a
thin translation layer:

    SimConfig(adaptive=..., equal_timeouts=...)  ->  MetronomePolicy /
                                                     EqualTimeoutsPolicy
    SimConfig(arrival_rate_mpps / arrival_profile) -> PoissonWorkload
    everything else                              ->  SimRunConfig

Prefer the new API for new code:

    from repro.runtime import MetronomePolicy, PoissonWorkload, simulate_run
    stats = simulate_run(MetronomePolicy(cfg), PoissonWorkload(14.88))
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.runtime.policy import EqualTimeoutsPolicy, MetronomePolicy
from repro.runtime.sim import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimRunConfig,
    SleepModel,
    simulate_run,
)
from repro.runtime.stats import RunStats
from repro.runtime.workload import PoissonWorkload

from .controller import MetronomeConfig

__all__ = [
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimConfig",
    "SimResult",
    "simulate",
    "simulate_busy_poll",
]


@dataclass(frozen=True)
class SimConfig:
    """Legacy flat knob set (paper Sec 5 defaults) — see module docstring."""

    m: int = 3
    arrival_rate_mpps: float = 14.88          # lambda  (packets / us)
    service_rate_mpps: float = 29.76          # mu      (packets / us)
    queue_capacity: int = 1024                # Rx descriptors (paper default)
    duration_us: float = 1_000_000.0
    v_target_us: float = 10.0                 # V-bar
    t_long_us: float = 500.0                  # T_L
    alpha: float = 0.125                      # EWMA (Eq 10)
    adaptive: bool = True                     # Eq 12 on/off (off => T_S = V-bar)
    equal_timeouts: bool = False              # T_L := T_S (Fig 5/7 scenarios)
    sleep_model: SleepModel = HR_SLEEP_MODEL
    wake_cost_us: float = 1.0                 # poll+return CPU cost per wake
    interference_prob: float = 0.0
    interference_mean_us: float = 0.0
    stall_rate_per_us: float = 0.0
    stall_mean_us: float = 0.0
    arrival_profile: Callable[[float], float] | None = None
    seed: int = 0
    ts_min_us: float = 1.0
    timeseries_bin_us: float = 0.0            # >0: emit binned time series

    # -- new-API decomposition -------------------------------------------------
    def policy(self):
        mcfg = MetronomeConfig(m=self.m, v_target_us=self.v_target_us,
                               t_long_us=self.t_long_us, alpha=self.alpha,
                               ts_min_us=self.ts_min_us)
        cls = EqualTimeoutsPolicy if self.equal_timeouts else MetronomePolicy
        return cls(mcfg, adaptive=self.adaptive)

    def workload(self) -> PoissonWorkload:
        return PoissonWorkload(self.arrival_rate_mpps,
                               profile=self.arrival_profile)

    def run_config(self) -> SimRunConfig:
        return SimRunConfig(
            duration_us=self.duration_us,
            service_rate_mpps=self.service_rate_mpps,
            queue_capacity=self.queue_capacity,
            sleep_model=self.sleep_model,
            wake_cost_us=self.wake_cost_us,
            interference_prob=self.interference_prob,
            interference_mean_us=self.interference_mean_us,
            stall_rate_per_us=self.stall_rate_per_us,
            stall_mean_us=self.stall_mean_us,
            seed=self.seed,
            timeseries_bin_us=self.timeseries_bin_us,
        )


@dataclass
class SimResult:
    vacations_us: np.ndarray
    busies_us: np.ndarray
    n_v: np.ndarray                     # backlog found at each busy start
    offered: int
    dropped: int
    serviced: int
    busy_tries: int
    wakeups: int
    cpu_fraction: float                 # total awake time / duration (sums threads)
    mean_latency_us: float
    p99_latency_us: float
    worst_latency_us: float
    rho_series: np.ndarray = field(default_factory=lambda: np.empty(0))
    ts_series: np.ndarray = field(default_factory=lambda: np.empty(0))
    tput_series_mpps: np.ndarray = field(default_factory=lambda: np.empty(0))
    offered_series_mpps: np.ndarray = field(default_factory=lambda: np.empty(0))
    series_t_us: np.ndarray = field(default_factory=lambda: np.empty(0))

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)

    @property
    def mean_vacation_us(self) -> float:
        return float(np.mean(self.vacations_us)) if self.vacations_us.size else 0.0

    @property
    def mean_busy_us(self) -> float:
        return float(np.mean(self.busies_us)) if self.busies_us.size else 0.0

    @property
    def mean_nv(self) -> float:
        return float(np.mean(self.n_v)) if self.n_v.size else 0.0

    @classmethod
    def from_run_stats(cls, rs: RunStats) -> "SimResult":
        return cls(
            vacations_us=rs.vacations_us, busies_us=rs.busies_us, n_v=rs.n_v,
            offered=rs.offered, dropped=rs.dropped, serviced=rs.items,
            busy_tries=rs.busy_tries, wakeups=rs.wakeups,
            cpu_fraction=rs.cpu_fraction,
            mean_latency_us=rs.mean_latency_us,
            p99_latency_us=rs.p99_latency_us,
            worst_latency_us=rs.worst_latency_us,
            rho_series=rs.rho_series, ts_series=rs.ts_series,
            tput_series_mpps=rs.tput_series_mpps,
            offered_series_mpps=rs.offered_series_mpps,
            series_t_us=rs.series_t_us,
        )


def simulate(cfg: SimConfig) -> SimResult:
    rs = simulate_run(cfg.policy(), cfg.workload(), cfg.run_config())
    return SimResult.from_run_stats(rs)


def simulate_busy_poll(cfg: SimConfig) -> SimResult:
    """Baseline: classic DPDK continuous polling (paper Listing 1)."""
    from repro.runtime.policy import BusyPollPolicy

    rs = simulate_run(BusyPollPolicy(), cfg.workload(), cfg.run_config())
    return SimResult.from_run_stats(rs)
