"""Discrete-event simulator of the Metronome renewal system (paper Sec 4/5).

Reproduces the paper's experimental apparatus in a hardware-independent way:
M pollers share one Rx queue; packets arrive (Poisson or CBR, optionally
time-varying); a waking poller races for the queue lock; the winner drains
at deterministic rate mu; losers re-sleep T_L.  Sleep overshoot follows a
*measured-from-the-paper* affine model (Table 1): hr_sleep ~ +3.5us,
nanosleep ~ +58us — so the simulator can answer "what if Metronome ran on
nanosleep?" (paper Table 3) without kernel patches.

Aggregate-exact accounting: between events arrivals are Poisson *counts*
(no per-packet events), busy periods use the standard sub-busy-period
recursion (serve backlog, collect arrivals meanwhile, repeat), so a 10s
line-rate simulation costs O(#cycles), not O(#packets).

Outputs per run (SimResult): cycle samples (V, B, N_V), loss fraction,
CPU usage (awake-time fraction, the paper's getrusage proxy), busy tries,
mean/worst latency, and time series for the adaptation plots (Fig 11).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .analytics import adaptive_ts, ewma_rho

__all__ = [
    "SleepModel",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimConfig",
    "SimResult",
    "simulate",
    "simulate_busy_poll",
]


@dataclass(frozen=True)
class SleepModel:
    """actual = target + base + slope*target + |N(0, sigma)|
              + Exp(tail_mean) w.p. tail_prob            (us units).

    Fitted to paper Table 1 (mean/p99):
      hr_sleep :  base ~ 2.8us, slope ~ 0.027, sigma ~ 0.5   (mean +3.5..8.4)
      nanosleep:  base ~ 57.5us, slope ~ 0.003, sigma ~ 3.0  (mean +58 flat)
    The nanosleep arm additionally carries a heavy preemption tail —
    without it the simulator under-loses vs the paper's Table 3 (a +58us
    mean backlogs < 1024 descriptors; the paper still lost 3.9% at a 4096
    ring, implying rare multi-hundred-us pile-ups).  Tail parameters chosen
    so the q=1024..4096 loss ladder brackets the paper's.
    """

    base_us: float
    slope: float
    sigma_us: float
    tail_prob: float = 0.0
    tail_mean_us: float = 0.0

    def sample(self, target_us: np.ndarray | float, rng: np.random.Generator):
        t = np.asarray(target_us, dtype=np.float64)
        noise = np.abs(rng.normal(0.0, self.sigma_us, size=t.shape))
        out = t + self.base_us + self.slope * t + noise
        if self.tail_prob:
            hit = rng.random(size=t.shape) < self.tail_prob
            out = out + hit * rng.exponential(self.tail_mean_us, size=t.shape)
        return out


HR_SLEEP_MODEL = SleepModel(base_us=2.8, slope=0.027, sigma_us=0.5)
NANOSLEEP_MODEL = SleepModel(base_us=57.5, slope=0.003, sigma_us=3.0,
                             tail_prob=0.01, tail_mean_us=400.0)
PERFECT_SLEEP_MODEL = SleepModel(base_us=0.0, slope=0.0, sigma_us=0.0)


@dataclass(frozen=True)
class SimConfig:
    m: int = 3
    arrival_rate_mpps: float = 14.88          # lambda  (packets / us)
    service_rate_mpps: float = 29.76          # mu      (packets / us)
    queue_capacity: int = 1024                # Rx descriptors (paper default)
    duration_us: float = 1_000_000.0
    v_target_us: float = 10.0                 # V-bar
    t_long_us: float = 500.0                  # T_L
    alpha: float = 0.125                      # EWMA (Eq 10)
    adaptive: bool = True                     # Eq 12 on/off (off => T_S = V-bar)
    equal_timeouts: bool = False              # T_L := T_S (Fig 5/7 scenarios)
    sleep_model: SleepModel = HR_SLEEP_MODEL
    wake_cost_us: float = 1.0                 # poll+return CPU cost per wake
    # OS interference (paper Sec 5.6): each wake delayed by Exp(mean) w.p. q.
    interference_prob: float = 0.0
    interference_mean_us: float = 0.0
    # Correlated stalls: Poisson system-wide freeze events delaying EVERY
    # wake that falls inside them (kernel timer-wheel/preemption pile-ups).
    # Needed to reproduce the paper's Table-3 weak queue-size dependence:
    # uncorrelated per-thread tails are absorbed by the backup threads
    # (Metronome's own resilience), so only correlated stalls overflow a
    # 4096-descriptor ring.
    stall_rate_per_us: float = 0.0
    stall_mean_us: float = 0.0
    # Time-varying load for adaptation runs: t_us -> lambda (packets/us).
    arrival_profile: Callable[[float], float] | None = None
    seed: int = 0
    ts_min_us: float = 1.0
    timeseries_bin_us: float = 0.0            # >0: emit binned time series


@dataclass
class SimResult:
    vacations_us: np.ndarray
    busies_us: np.ndarray
    n_v: np.ndarray                     # backlog found at each busy start
    offered: int
    dropped: int
    serviced: int
    busy_tries: int
    wakeups: int
    cpu_fraction: float                 # total awake time / duration (sums threads)
    mean_latency_us: float
    p99_latency_us: float
    worst_latency_us: float
    rho_series: np.ndarray = field(default_factory=lambda: np.empty(0))
    ts_series: np.ndarray = field(default_factory=lambda: np.empty(0))
    tput_series_mpps: np.ndarray = field(default_factory=lambda: np.empty(0))
    offered_series_mpps: np.ndarray = field(default_factory=lambda: np.empty(0))
    series_t_us: np.ndarray = field(default_factory=lambda: np.empty(0))

    @property
    def loss_fraction(self) -> float:
        return self.dropped / max(self.offered, 1)

    @property
    def mean_vacation_us(self) -> float:
        return float(np.mean(self.vacations_us)) if self.vacations_us.size else 0.0

    @property
    def mean_busy_us(self) -> float:
        return float(np.mean(self.busies_us)) if self.busies_us.size else 0.0

    @property
    def mean_nv(self) -> float:
        return float(np.mean(self.n_v)) if self.n_v.size else 0.0


def _drain(backlog: float, lam: float, mu: float, rng: np.random.Generator,
           max_rounds: int = 64) -> tuple[float, int]:
    """Busy-period recursion: serve `backlog`, Poisson arrivals meanwhile.

    Returns (busy_duration_us, packets_served).  Guaranteed to terminate for
    lam < mu; at saturation the round cap bounds the step (callers loop).
    """
    total_t = 0.0
    served = 0.0
    rounds = 0
    while backlog >= 1.0 and rounds < max_rounds:
        dt = backlog / mu
        served += backlog
        total_t += dt
        backlog = rng.poisson(lam * dt) if lam > 0 else 0.0
        rounds += 1
    return total_t, int(served)


def simulate(cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    m = cfg.m
    mu = cfg.service_rate_mpps
    lam_of = cfg.arrival_profile or (lambda t: cfg.arrival_rate_mpps)

    # Thread state: next wake time; whether it last acted as primary.
    t_s = cfg.v_target_us if not cfg.adaptive else float(
        adaptive_ts(cfg.v_target_us, 0.5, m, ts_min=cfg.ts_min_us,
                    ts_max=m * cfg.v_target_us))
    rho = 0.5
    # Threads are launched actively (paper Sec 5): first wakes land within
    # one short timeout, not spread over T_L (that would fabricate a startup
    # backlog transient the real system does not have).
    wake_at = rng.uniform(0.0, t_s, size=m)

    backlog = 0.0
    last_advanced = 0.0      # arrivals accounted up to here
    busy_until = 0.0         # lock held until this time
    last_busy_end = 0.0

    offered = dropped = serviced = busy_tries = wakeups = 0
    vac, bus, nvs = [], [], []
    lat_samples: list[float] = []
    awake_us = 0.0

    nbins = int(cfg.duration_us / cfg.timeseries_bin_us) if cfg.timeseries_bin_us else 0
    b_rho = np.zeros(max(nbins, 1)); b_ts = np.zeros(max(nbins, 1))
    b_srv = np.zeros(max(nbins, 1)); b_off = np.zeros(max(nbins, 1))
    b_cnt = np.zeros(max(nbins, 1))

    def advance_arrivals(to_t: float) -> None:
        """Accumulate Poisson arrivals on [last_advanced, to_t); count drops."""
        nonlocal backlog, offered, dropped, last_advanced
        dt = to_t - last_advanced
        if dt <= 0:
            return
        lam = lam_of(last_advanced)
        n = int(rng.poisson(lam * dt)) if lam > 0 else 0
        offered += n
        room = cfg.queue_capacity - backlog
        if n > room:
            dropped += int(n - max(room, 0))
            n = int(max(room, 0))
        backlog += n
        if nbins:
            b = min(int(last_advanced / cfg.timeseries_bin_us), nbins - 1)
            b_off[b] += n + 0.0
        last_advanced = to_t

    # correlated stall windows (lazy Poisson process)
    next_stall = (rng.exponential(1.0 / cfg.stall_rate_per_us)
                  if cfg.stall_rate_per_us else np.inf)
    stall_end = -1.0

    while True:
        i = int(np.argmin(wake_at))
        t = float(wake_at[i])
        if t >= cfg.duration_us:
            break
        if cfg.stall_rate_per_us:
            while next_stall <= t:
                stall_end = max(stall_end,
                                next_stall + rng.exponential(cfg.stall_mean_us))
                next_stall += rng.exponential(1.0 / cfg.stall_rate_per_us)
            if t < stall_end:
                wake_at[i] = stall_end + rng.uniform(0.0, 1.0)
                continue
        wakeups += 1
        awake_us += cfg.wake_cost_us
        advance_arrivals(t)

        if t < busy_until:
            # trylock failed: another poller is draining => backup role.
            busy_tries += 1
            t_l = t_s if cfg.equal_timeouts else cfg.t_long_us
            delay = float(cfg.sleep_model.sample(t_l, rng))
            if cfg.interference_prob and rng.random() < cfg.interference_prob:
                delay += rng.exponential(cfg.interference_mean_us)
            wake_at[i] = t + delay
            continue

        # trylock won: primary. Vacation ended at t.
        v = t - last_busy_end
        n_v = backlog
        lam_now = lam_of(t)
        b_time, srv = _drain(backlog, min(lam_now, 0.98 * mu), mu, rng)
        backlog = 0.0
        # arrivals during the busy period were consumed by _drain: account them.
        offered += max(srv - int(n_v), 0)
        serviced += srv
        last_advanced = max(last_advanced, t + b_time)
        busy_until = t + b_time
        last_busy_end = busy_until
        awake_us += b_time

        vac.append(v); bus.append(b_time); nvs.append(n_v)
        # Latency: packets found at busy start waited (uniform arrival in V)
        # V/2 on average + their drain position; packets arriving during B
        # wait ~ residual drain.  Sample a handful per cycle for percentiles.
        if n_v >= 1:
            k = min(int(n_v), 8)
            arr = rng.uniform(0.0, max(v, 1e-9), size=k)         # age at t
            pos = np.sort(rng.uniform(0.0, n_v, size=k)) / mu    # drain slot
            lat_samples.extend((max(v, 1e-9) - arr + pos).tolist())

        if cfg.adaptive:
            rho = float(ewma_rho(rho, b_time, max(v, 1e-9), cfg.alpha))
            t_s = float(adaptive_ts(cfg.v_target_us, rho, m,
                                    ts_min=cfg.ts_min_us,
                                    ts_max=m * cfg.v_target_us))
        if nbins:
            b = min(int(t / cfg.timeseries_bin_us), nbins - 1)
            b_rho[b] += rho; b_ts[b] += t_s; b_srv[b] += srv; b_cnt[b] += 1

        delay = float(cfg.sleep_model.sample(t_s, rng))
        if cfg.interference_prob and rng.random() < cfg.interference_prob:
            delay += rng.exponential(cfg.interference_mean_us)
        wake_at[i] = busy_until + delay

    lat = np.asarray(lat_samples) if lat_samples else np.zeros(1)
    nbins_eff = max(nbins, 1)
    cnt = np.maximum(b_cnt, 1)
    return SimResult(
        vacations_us=np.asarray(vac),
        busies_us=np.asarray(bus),
        n_v=np.asarray(nvs),
        offered=offered, dropped=dropped, serviced=serviced,
        busy_tries=busy_tries, wakeups=wakeups,
        cpu_fraction=awake_us / cfg.duration_us,
        mean_latency_us=float(np.mean(lat)),
        p99_latency_us=float(np.percentile(lat, 99)),
        worst_latency_us=float(np.max(lat)),
        rho_series=b_rho / cnt if nbins else np.empty(0),
        ts_series=b_ts / cnt if nbins else np.empty(0),
        tput_series_mpps=(b_srv / cfg.timeseries_bin_us) if nbins else np.empty(0),
        offered_series_mpps=(b_off / cfg.timeseries_bin_us) if nbins else np.empty(0),
        series_t_us=(np.arange(nbins_eff) * cfg.timeseries_bin_us) if nbins else np.empty(0),
    )


def simulate_busy_poll(cfg: SimConfig) -> SimResult:
    """Baseline: classic DPDK continuous polling (paper Listing 1).

    One dedicated core spins; CPU is 100% by construction; latency is just
    the drain position (no vacations); loss only beyond saturation.
    """
    rng = np.random.default_rng(cfg.seed)
    lam_of = cfg.arrival_profile or (lambda t: cfg.arrival_rate_mpps)
    # Closed form per small step: stable M/D/1-ish; we only need the summary.
    step = 10.0
    t = 0.0
    offered = dropped = serviced = 0
    backlog = 0.0
    lat_num = 0.0
    while t < cfg.duration_us:
        lam = lam_of(t)
        n = int(rng.poisson(lam * step))
        offered += n
        cap = cfg.service_rate_mpps * step
        do = min(backlog + n, cap)
        serviced += int(do)
        backlog = backlog + n - do
        if backlog > cfg.queue_capacity:
            dropped += int(backlog - cfg.queue_capacity)
            backlog = float(cfg.queue_capacity)
        lat_num += backlog * step        # area under queue curve (Little)
        t += step
    mean_lat = lat_num / max(serviced, 1)
    return SimResult(
        vacations_us=np.zeros(1), busies_us=np.asarray([cfg.duration_us]),
        n_v=np.zeros(1), offered=offered, dropped=dropped, serviced=serviced,
        busy_tries=0, wakeups=0, cpu_fraction=1.0,
        mean_latency_us=float(mean_lat + 1.0 / cfg.service_rate_mpps),
        p99_latency_us=float(mean_lat * 3 + 1.0 / cfg.service_rate_mpps),
        worst_latency_us=float(cfg.queue_capacity / cfg.service_rate_mpps),
    )
