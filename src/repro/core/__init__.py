"""Metronome core: the paper's contribution as a reusable library.

- analytics:  closed-form renewal model (Eqs 1-13)
- controller: EWMA load estimate + adaptive T_S rule (Eqs 10/12)
- hr_sleep:   precise userspace hybrid sleep (paper Sec 3.1 adaptation)
- trylock:    non-blocking queue ownership (paper Sec 3.2)
- pollers:    real-thread runtime (paper Listing 2) + busy-poll baseline
- simulator:  discrete-event renewal simulator (paper Sec 5 apparatus)
"""

from . import analytics
from .controller import MetronomeConfig, MetronomeController
from .hr_sleep import calibrate, hr_sleep, make_hr_sleep, measure_precision, naive_sleep
from .pollers import BoundedQueue, BusyPollLoop, MetronomePollers, PollerStats
from .simulator import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimConfig,
    SimResult,
    SleepModel,
    simulate,
    simulate_busy_poll,
)
from .trylock import TryLock

__all__ = [
    "analytics",
    "MetronomeConfig",
    "MetronomeController",
    "calibrate",
    "hr_sleep",
    "make_hr_sleep",
    "measure_precision",
    "naive_sleep",
    "BoundedQueue",
    "BusyPollLoop",
    "MetronomePollers",
    "PollerStats",
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimConfig",
    "SimResult",
    "SleepModel",
    "simulate",
    "simulate_busy_poll",
    "TryLock",
]
