"""Metronome core: the paper's contribution as a reusable library.

- analytics:  closed-form renewal model (Eqs 1-13)
- controller: EWMA load estimate + adaptive T_S rule (Eqs 10/12)
- hr_sleep:   precise userspace hybrid sleep (paper Sec 3.1 adaptation)
- trylock:    non-blocking queue ownership (paper Sec 3.2)
- pollers:    DEPRECATED shims over repro.runtime (paper Listing 2 loop)
- simulator:  DEPRECATED shims over repro.runtime.sim (paper Sec 5)

The retrieval loops and the simulator moved to ``repro.runtime`` (one
pluggable policy × workload API with sim/real parity); their old names
are still importable from here and resolve lazily to the new package, so
``from repro.core import MetronomePollers, simulate`` keeps working.
"""

from . import analytics
from .controller import MetronomeConfig, MetronomeController
from .hr_sleep import calibrate, hr_sleep, make_hr_sleep, measure_precision, naive_sleep
from .trylock import TryLock

# Names re-exported lazily (PEP 562) from the modules that now shim onto
# repro.runtime.  Lazy so that `import repro.runtime` -> policy ->
# repro.core.controller doesn't re-enter a half-initialized repro.runtime.
_POLLERS = ("BoundedQueue", "BusyPollLoop", "MetronomePollers", "PollerStats")
_SIMULATOR = (
    "HR_SLEEP_MODEL",
    "NANOSLEEP_MODEL",
    "PERFECT_SLEEP_MODEL",
    "SimConfig",
    "SimResult",
    "SleepModel",
    "simulate",
    "simulate_busy_poll",
)

__all__ = [
    "analytics",
    "MetronomeConfig",
    "MetronomeController",
    "calibrate",
    "hr_sleep",
    "make_hr_sleep",
    "measure_precision",
    "naive_sleep",
    "TryLock",
    *_POLLERS,
    *_SIMULATOR,
]


def __getattr__(name: str):
    if name in _POLLERS:
        from . import pollers
        return getattr(pollers, name)
    if name in _SIMULATOR:
        from . import simulator
        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
