"""Non-blocking queue-ownership lock — the paper's userspace ``trylock()``.

The paper builds trylock from the x86 CMPXCHG read-modify-write.  CPython's
``threading.Lock.acquire(blocking=False)`` bottoms out in a futex fast path
using the same compare-and-exchange hardware primitive, so the semantics
(single winner, losers return immediately, no syscall on the fast path) are
preserved.

The lock also keeps the two counters the paper's evaluation relies on:
``busy_tries`` (failed acquisitions — paper Fig 7/8) and ``acquisitions``.
Counters are approximate under contention by design (they are telemetry,
not synchronization).
"""

from __future__ import annotations

import threading

__all__ = ["TryLock"]


class TryLock:
    __slots__ = ("_lock", "busy_tries", "acquisitions")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.busy_tries = 0
        self.acquisitions = 0

    def try_acquire(self) -> bool:
        """Single atomic attempt; never blocks (paper Listing 2, line 4)."""
        ok = self._lock.acquire(blocking=False)
        if ok:
            self.acquisitions += 1
        else:
            self.busy_tries += 1
        return ok

    def release(self) -> None:
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def reset_stats(self) -> None:
        self.busy_tries = 0
        self.acquisitions = 0
