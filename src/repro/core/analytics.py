"""Closed-form renewal analytics from the Metronome paper (Sec 4 + App C).

Every public function implements a numbered equation from the paper.  All
functions are pure and accept scalars or numpy arrays; they are used by the
adaptive controller (host control plane), the discrete-event simulator, and
the property tests that cross-validate simulation against analysis.

Notation (paper Fig 3/4):
  V        vacation period — all M pollers asleep, arrivals accumulate
  B        busy period     — one poller (the trylock winner) drains the queue
  rho      offered load lambda/mu
  T_S      "short" wake timeout used by *primary* threads
  T_L      "long"  wake timeout used by *backup*  threads (T_L >> T_S)
  M        number of deployed Metronome pollers
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "busy_period_mean",
    "rho_from_periods",
    "ewma_rho",
    "vacation_cdf_high",
    "vacation_pdf_high",
    "mean_vacation_high",
    "backup_success_prob",
    "vacation_cdf_low",
    "mean_vacation_low",
    "mean_vacation_general",
    "mean_vacation_general_approx",
    "adaptive_ts",
    "primary_prob",
    "second_moment_vacation_high",
    "mean_sojourn_high",
]

_EPS = 1e-12


def busy_period_mean(v, rho):
    """Eq (3): E[B|V] = V * rho / (1 - rho), the vacation fixed point.

    Derived from B = (N_V + N_B)/mu with N ~ lambda*T (Little).  Diverges as
    rho -> 1 (saturation); callers must keep rho < 1.
    """
    rho = np.asarray(rho, dtype=np.float64)
    return np.asarray(v, dtype=np.float64) * rho / np.maximum(1.0 - rho, _EPS)


def rho_from_periods(b, v):
    """Eq (4): rho = E[B|V] / (V + E[B|V]) — the observable load estimator."""
    b = np.asarray(b, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    return b / np.maximum(v + b, _EPS)


def ewma_rho(rho_prev, b, v, alpha):
    """Eq (10): rho(i) = (1-alpha) rho(i-1) + alpha * B(i)/(V(i)+B(i))."""
    return (1.0 - alpha) * rho_prev + alpha * rho_from_periods(b, v)


# ---------------------------------------------------------------------------
# High-load regime: 1 primary, M-1 decorrelated backups (Sec 4.2.2)
# ---------------------------------------------------------------------------

def vacation_cdf_high(x, t_s, t_l, m):
    """Eq (5): CDF of V = min(T_S, U_1..U_{M-1}), U ~ Uniform(0, T_L).

    Valid under the decorrelation assumption (verified in paper Fig 5 and in
    tests/test_core_simulator.py against the discrete-event simulator).
    """
    x = np.asarray(x, dtype=np.float64)
    cdf = 1.0 - (1.0 - np.clip(x / t_l, 0.0, 1.0)) ** (m - 1)
    return np.where(x >= t_s, 1.0, cdf)


def vacation_pdf_high(x, t_s, t_l, m):
    """Eq (9): density of Eq (5) on x < T_S (excludes the atom at T_S)."""
    x = np.asarray(x, dtype=np.float64)
    pdf = (m - 1) / t_l * (1.0 - np.clip(x / t_l, 0.0, 1.0)) ** (m - 2)
    return np.where(x < t_s, pdf, 0.0)


def mean_vacation_high(t_s, t_l, m):
    """Eq (6): E[V] = T_L/M * (1 - (1 - T_S/T_L)^M)."""
    return t_l / m * (1.0 - (1.0 - t_s / t_l) ** m)


def backup_success_prob(t_s, t_l, m):
    """Eq (7): P(a backup wakes inside the primary's T_S window).

    NOTE — the paper's printed right-hand side reads
    ``(1 - T_S/T_L)^{M-1} / (M-1)`` which does not equal its own integral
    (check M=2: integral = T_S/T_L).  We implement the integral:
        P = (1 - (1 - T_S/T_L)^{M-1}) / (M-1).
    """
    if m < 2:
        raise ValueError("backup_success_prob needs M >= 2")
    return (1.0 - (1.0 - t_s / t_l) ** (m - 1)) / (m - 1)


# ---------------------------------------------------------------------------
# Low-load regime: all threads primary (Sec 4.2.3)
# ---------------------------------------------------------------------------

def vacation_cdf_low(x, t_s, m):
    """Eq (8): Eq (5) with T_L = T_S and M competitors.

    NOTE — integrating this CDF yields E[V] = T_S/(M+1) exactly (min of M
    uniforms); the paper's stated low-load mean T_S/M instead follows from
    the App C general form at p=1 (M-1 uniforms plus the finishing
    primary's deterministic T_S).  The adaptation rule (Eq 11/12) uses the
    T_S/M convention, which `mean_vacation_low` returns.
    """
    x = np.asarray(x, dtype=np.float64)
    return 1.0 - (1.0 - np.clip(x / t_s, 0.0, 1.0)) ** m


def mean_vacation_low(t_s, m):
    """Sec 4.2.3 (paper convention, used by Eq 11/12): E[V] = T_S / M."""
    return t_s / m


# ---------------------------------------------------------------------------
# General load (Appendix C)
# ---------------------------------------------------------------------------

def primary_prob(rho):
    """App C: p = 1 - rho — probability a thread last saw the queue idle."""
    return 1.0 - np.asarray(rho, dtype=np.float64)


def mean_vacation_general(t_s, t_l, m, p):
    """App C exact E[V] (before the T_L >> T_S approximation).

    E[V] = [1 - ((1-p)(1 - T_S/T_L))^M] / [M * (p/T_S + (1-p)/T_L)]

    NOTE — the paper's printed denominator swaps T_S and T_L; the printed
    form fails its own high-load limit (p->0 must recover Eq (6)).  The
    version here satisfies both limits:
      p -> 0:  E[V] -> T_L/M (1 - (1 - T_S/T_L)^M)   == Eq (6)
      p -> 1:  E[V] -> T_S/M * (1 - 0)/1 ... -> T_S/M == Eq (8) mean
    (verified in tests/test_core_analytics.py).
    """
    p = np.asarray(p, dtype=np.float64)
    num = 1.0 - ((1.0 - p) * (1.0 - t_s / t_l)) ** m
    den = m * (p / t_s + (1.0 - p) / t_l)
    return num / np.maximum(den, _EPS)


def mean_vacation_general_approx(t_s, m, p):
    """Eq (13): E[V] ~= T_S/M * (1 - (1-p)^M)/p   (assumes T_L >> T_S)."""
    p = np.asarray(p, dtype=np.float64)
    safe_p = np.maximum(p, _EPS)
    val = t_s / m * (1.0 - (1.0 - safe_p) ** m) / safe_p
    # p -> 0 limit is T_S (high load: vacation == primary timeout).
    return np.where(p < _EPS, float(t_s), val)


def adaptive_ts(v_target, rho, m, ts_min=0.0, ts_max=np.inf):
    """Eq (12): T_S = M * V_bar * (1-rho)/(1-rho^M), clamped.

    Computed via the geometric-series sum T_S = M*V_bar / (1+rho+...+
    rho^{M-1}) which is exact, stable at rho -> 1 (limit V_bar) and
    rho -> 0 (limit M*V_bar), and never divides by zero.  Fully
    vectorized: every argument (including ``m``) broadcasts, so the
    batched sweep / calibration layer can evaluate whole grids at once.
    """
    rho = np.clip(np.asarray(rho, dtype=np.float64), 0.0, 1.0)
    m = np.asarray(m, dtype=np.float64)
    # geometric sum sum_{k<M} rho^k, switched to its M limit at rho ~ 1
    near_one = np.abs(1.0 - rho) < 1e-9
    safe_rho = np.where(near_one, 0.5, rho)
    denom = np.where(near_one, m,
                     (1.0 - safe_rho**m) / (1.0 - safe_rho))
    return np.clip(m * np.asarray(v_target, dtype=np.float64) / denom,
                   ts_min, ts_max)


# ---------------------------------------------------------------------------
# Latency closed forms (cross-validation targets for the batched engine)
# ---------------------------------------------------------------------------

def second_moment_vacation_high(t_s, t_l, m):
    """E[V^2] for the high-load vacation V = min(T_S, U_1..U_{M-1}).

    From E[V^2] = 2 * int_0^{T_S} x * (1 - F(x)) dx with the Eq (5)
    survival (1 - x/T_L)^{M-1}; substituting u = 1 - x/T_L gives the
    closed form (c = 1 - T_S/T_L):

        E[V^2] = 2 T_L^2 [ (1 - c^M)/M - (1 - c^{M+1})/(M+1) ]

    M = 1 reduces to T_S^2 (deterministic vacation).
    """
    t_s = np.asarray(t_s, dtype=np.float64)
    t_l = np.asarray(t_l, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    c = 1.0 - t_s / t_l
    return 2.0 * t_l**2 * ((1.0 - c**m) / m - (1.0 - c**(m + 1)) / (m + 1))


def mean_sojourn_high(t_s, t_l, m):
    """All-packet mean time in system, high-load regime: E[V^2]/(2 E[V]).

    Renewal-reward over one (V, B) cycle with fluid drain at mu: the
    queue-depth integral per cycle is lam*V^2 / (2(1-rho)) and the
    packets per cycle are lam*V/(1-rho), so the load terms cancel and
    Little's law leaves the residual-vacation form E[V^2]/(2 E[V]) —
    independent of rho while the system is stable.  This is exactly the
    quantity the simulation engines measure as ``mean_sojourn_us``
    (sampled ``mean_latency_us`` is the vacation-found-packet estimator
    instead, higher by ~(1+rho)).
    """
    ev = mean_vacation_high(t_s, t_l, m)
    return second_moment_vacation_high(t_s, t_l, m) / np.maximum(
        2.0 * ev, _EPS)
