"""Adaptive Metronome controller — the paper's Sec 4.3 control law.

One ``MetronomeController`` is shared by the M pollers of a queue.  After
every renewal cycle (vacation V followed by busy period B) the finishing
primary calls ``on_cycle_end(B, V)``; the controller updates the EWMA load
estimate (Eq 10) and re-derives the primary timeout T_S from the
constant-vacation-target rule (Eq 12).  Backups always sleep T_L.

A calibrated *feed-forward* term can ride alongside the Eq 10/12 loop: any
object with ``timeouts_us(rho) -> (t_s_us, t_l_us)`` (duck-typed so the
controller doesn't import the calibration layer — in practice an
``repro.runtime.calibrate.OperatingTable`` built from a batched sweep)
maps the EWMA load estimate straight to a pre-validated operating point,
and ``cfg.feedforward_weight`` blends it with the analytic Eq 12 timeout
(1.0 = trust the table, 0.0 = pure paper behavior).  Eq 10 still supplies
rho either way; the table replaces only the rho -> T_S mapping, which is
exactly the part the closed form gets wrong when sleep overshoot / role
churn matter.

The controller is deliberately lock-free-ish: rho/T_S are plain Python
floats updated by whichever thread ends a cycle; stale reads by other
threads are harmless (the control law is a fixed point, and the paper's own
threads race the same way).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from . import analytics

__all__ = ["MetronomeConfig", "MetronomeController"]


@dataclass(frozen=True)
class MetronomeConfig:
    """Tuning knobs, defaults = the paper's evaluation settings (Sec 5)."""

    m: int = 3                   # deployed pollers (paper: 3)
    v_target_us: float = 10.0    # constant vacation target V-bar (paper: 10us)
    t_long_us: float = 500.0     # backup timeout T_L (paper: 500us)
    alpha: float = 0.125         # EWMA smoothing for rho (Eq 10)
    rho_init: float = 0.5
    ts_min_us: float = 1.0       # clamp: never spin faster than 1us cadence
    ts_max_us: float | None = None  # default M * v_target (the rho->0 limit)
    # weight of the calibrated feed-forward timeout when an operating
    # table is installed (0.0 = ignore it, 1.0 = replace Eq 12 with it)
    feedforward_weight: float = 1.0
    # record the (cycle, rho, T_S, T_L) trajectory on every cycle end —
    # the control-plane trace adaptation studies compare feed-forward vs
    # pure-Eq-12 behavior on (bounded; off by default: the hot path
    # should not grow a list per cycle unless asked to)
    record_trajectory: bool = False
    trajectory_cap: int = 65_536

    def resolved_ts_max(self) -> float:
        return self.ts_max_us if self.ts_max_us is not None else self.m * self.v_target_us


@dataclass
class MetronomeController:
    cfg: MetronomeConfig = field(default_factory=MetronomeConfig)
    # calibrated feed-forward: any object with timeouts_us(rho) ->
    # (t_s_us, t_l_us), e.g. repro.runtime.calibrate.OperatingTable
    feedforward: object | None = None

    def __post_init__(self) -> None:
        self.rho: float = self.cfg.rho_init
        self.t_long_us: float = float(self.cfg.t_long_us)
        self.t_short_us: float = self._derive_ts()
        self.cycles: int = 0
        # (cycle, rho, t_s_us, t_l_us) per on_cycle_end when
        # cfg.record_trajectory — the rho/T_S trace that lets
        # feed-forward and pure-Eq-12 control be compared point by point
        self.trajectory: list[tuple[int, float, float, float]] = []

    def _derive_ts(self) -> float:
        """rho -> T_S: Eq 12, blended with the calibrated table if one
        is installed (both clamped to the configured band)."""
        ts = float(
            analytics.adaptive_ts(
                self.cfg.v_target_us, self.rho, self.cfg.m,
                ts_min=self.cfg.ts_min_us, ts_max=self.cfg.resolved_ts_max(),
            )
        )
        tl = float(self.cfg.t_long_us)
        if self.feedforward is not None:
            w = min(max(self.cfg.feedforward_weight, 0.0), 1.0)
            ts_ff, tl_ff = self.feedforward.timeouts_us(self.rho)
            ts = (1.0 - w) * ts + w * float(ts_ff)
            tl = (1.0 - w) * self.cfg.t_long_us + w * float(tl_ff)
            # table points are pre-validated against the latency target,
            # so only the safety floor applies (the Eq-12 upper clamp
            # would undo the table's low-load CPU savings)
            ts = max(ts, self.cfg.ts_min_us)
        # T_L >= T_S, always: the role split only works if backups fire
        # *after* primaries.  A calibrated table rung (or a pathological
        # config) with T_L below the derived T_S would invert the
        # backup/primary timeouts, so the backup timeout rises to meet
        # T_S (re-derived each cycle, so it falls back once T_S does).
        self.t_long_us = max(tl, ts)
        return ts

    # -- control-plane updates ------------------------------------------------
    def on_cycle_end(self, busy_us: float, vacation_us: float) -> float:
        """Feed one (B, V) observation; returns the new T_S in us."""
        self.rho = float(
            analytics.ewma_rho(self.rho, busy_us, vacation_us, self.cfg.alpha)
        )
        self.t_short_us = self._derive_ts()
        self.cycles += 1
        if (self.cfg.record_trajectory
                and len(self.trajectory) < self.cfg.trajectory_cap):
            self.trajectory.append((self.cycles, self.rho,
                                    self.t_short_us, self.t_long_us))
        return self.t_short_us

    # -- data-plane reads -----------------------------------------------------
    def timeout_us(self, *, primary: bool) -> float:
        """Paper Listing 2 lines 11-14: T_S for primaries, T_L for backups."""
        return self.t_short_us if primary else self.t_long_us

    def timeout_ns(self, *, primary: bool) -> int:
        return int(self.timeout_us(primary=primary) * 1_000)
