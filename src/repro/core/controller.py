"""Adaptive Metronome controller — the paper's Sec 4.3 control law.

One ``MetronomeController`` is shared by the M pollers of a queue.  After
every renewal cycle (vacation V followed by busy period B) the finishing
primary calls ``on_cycle_end(B, V)``; the controller updates the EWMA load
estimate (Eq 10) and re-derives the primary timeout T_S from the
constant-vacation-target rule (Eq 12).  Backups always sleep T_L.

The controller is deliberately lock-free-ish: rho/T_S are plain Python
floats updated by whichever thread ends a cycle; stale reads by other
threads are harmless (the control law is a fixed point, and the paper's own
threads race the same way).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from . import analytics

__all__ = ["MetronomeConfig", "MetronomeController"]


@dataclass(frozen=True)
class MetronomeConfig:
    """Tuning knobs, defaults = the paper's evaluation settings (Sec 5)."""

    m: int = 3                   # deployed pollers (paper: 3)
    v_target_us: float = 10.0    # constant vacation target V-bar (paper: 10us)
    t_long_us: float = 500.0     # backup timeout T_L (paper: 500us)
    alpha: float = 0.125         # EWMA smoothing for rho (Eq 10)
    rho_init: float = 0.5
    ts_min_us: float = 1.0       # clamp: never spin faster than 1us cadence
    ts_max_us: float | None = None  # default M * v_target (the rho->0 limit)

    def resolved_ts_max(self) -> float:
        return self.ts_max_us if self.ts_max_us is not None else self.m * self.v_target_us


@dataclass
class MetronomeController:
    cfg: MetronomeConfig = field(default_factory=MetronomeConfig)

    def __post_init__(self) -> None:
        self.rho: float = self.cfg.rho_init
        self.t_short_us: float = float(
            analytics.adaptive_ts(
                self.cfg.v_target_us, self.rho, self.cfg.m,
                ts_min=self.cfg.ts_min_us, ts_max=self.cfg.resolved_ts_max(),
            )
        )
        self.cycles: int = 0

    # -- control-plane updates ------------------------------------------------
    def on_cycle_end(self, busy_us: float, vacation_us: float) -> float:
        """Feed one (B, V) observation; returns the new T_S in us."""
        self.rho = float(
            analytics.ewma_rho(self.rho, busy_us, vacation_us, self.cfg.alpha)
        )
        self.t_short_us = float(
            analytics.adaptive_ts(
                self.cfg.v_target_us, self.rho, self.cfg.m,
                ts_min=self.cfg.ts_min_us, ts_max=self.cfg.resolved_ts_max(),
            )
        )
        self.cycles += 1
        return self.t_short_us

    # -- data-plane reads -----------------------------------------------------
    def timeout_us(self, *, primary: bool) -> float:
        """Paper Listing 2 lines 11-14: T_S for primaries, T_L for backups."""
        return self.t_short_us if primary else self.cfg.t_long_us

    def timeout_ns(self, *, primary: bool) -> int:
        return int(self.timeout_us(primary=primary) * 1_000)
