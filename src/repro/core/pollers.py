"""Real-thread Metronome runtime — paper Listing 2, deployed.

``MetronomePollers`` runs M OS threads against one or more shared bounded
queues.  Each thread executes the paper's loop verbatim:

    while running:
        lock_taken = False
        for q in queues:
            if not trylock(q):   continue
            lock_taken = True
            while burst := q.poll(BURST):  process(burst)   # busy period
            unlock(q)
        hr_sleep(T_S if lock_taken else T_L)                 # Listing 2 l.11-14

with the adaptive controller (Eq 10/12) updating T_S after every cycle.
``BusyPollLoop`` is the classic DPDK baseline (Listing 1) for comparisons.

This runtime fronts the serving engine (serving/server.py): the "packets"
are inference requests and ``process`` hands batches to the
continuous-batching scheduler.  CPU accounting uses per-thread CPU time
(time.thread_time_ns around the loop body) — the userspace analogue of the
paper's getrusage() methodology, immune to descheduling on shared hosts.
"""

from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from .controller import MetronomeConfig, MetronomeController
from .hr_sleep import hr_sleep
from .trylock import TryLock

__all__ = ["BoundedQueue", "PollerStats", "MetronomePollers", "BusyPollLoop"]


class BoundedQueue:
    """Bounded MPSC-ish queue standing in for the NIC Rx descriptor ring.

    ``push`` drops (and counts) on overflow — Rx-ring semantics, paper
    Table 2/3 loss accounting.  ``poll`` is only called under the queue's
    TryLock, so a plain deque suffices (append is GIL-atomic for pushers).
    """

    __slots__ = ("_q", "capacity", "dropped", "offered", "lock", "last_busy_end_ns")

    def __init__(self, capacity: int = 1024):
        self._q: collections.deque = collections.deque()
        self.capacity = capacity
        self.dropped = 0
        self.offered = 0
        self.lock = TryLock()
        self.last_busy_end_ns = time.monotonic_ns()

    def push(self, item: Any) -> bool:
        self.offered += 1
        if len(self._q) >= self.capacity:
            self.dropped += 1
            return False
        self._q.append((time.monotonic_ns(), item))
        return True

    def poll(self, max_items: int) -> list[tuple[int, Any]]:
        out = []
        q = self._q
        for _ in range(min(max_items, len(q))):
            try:
                out.append(q.popleft())
            except IndexError:  # racing pushers can't cause this; be safe
                break
        return out

    def __len__(self) -> int:
        return len(self._q)


@dataclass
class PollerStats:
    wakeups: int = 0
    cycles: int = 0
    busy_tries: int = 0
    items: int = 0
    awake_ns: int = 0
    started_ns: int = 0
    stopped_ns: int = 0
    latency_samples_us: list = field(default_factory=list)

    @property
    def duration_ns(self) -> int:
        return max(self.stopped_ns - self.started_ns, 1)

    @property
    def cpu_fraction(self) -> float:
        """Sum of thread awake time over wall duration (can exceed 1.0)."""
        return self.awake_ns / self.duration_ns


class MetronomePollers:
    def __init__(
        self,
        queues: list[BoundedQueue],
        process: Callable[[list], None],
        cfg: MetronomeConfig | None = None,
        *,
        burst_size: int = 32,
        sleep_fn: Callable[[int], None] = hr_sleep,
        latency_sample_every: int = 16,
    ):
        self.queues = queues
        self.process = process
        self.cfg = cfg or MetronomeConfig()
        self.controller = MetronomeController(self.cfg)
        self.burst_size = burst_size
        self.sleep_fn = sleep_fn
        self.stats = PollerStats()
        self._stats_lock = threading.Lock()
        self._running = threading.Event()
        self._threads: list[threading.Thread] = []
        self._lat_every = latency_sample_every

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> None:
        self.stats = PollerStats(started_ns=time.monotonic_ns())
        self._running.set()
        self._threads = [
            threading.Thread(target=self._run, name=f"metronome-{i}", daemon=True)
            for i in range(self.cfg.m)
        ]
        for t in self._threads:
            t.start()

    def stop(self, timeout: float = 5.0) -> PollerStats:
        self._running.clear()
        for t in self._threads:
            t.join(timeout)
        self.stats.stopped_ns = time.monotonic_ns()
        for q in self.queues:
            self.stats.busy_tries = sum(qq.lock.busy_tries for qq in self.queues)
        return self.stats

    # -- the paper's loop --------------------------------------------------------
    def _run(self) -> None:
        ctrl = self.controller
        st = self.stats
        wake = 0
        while self._running.is_set():
            t_wake = time.monotonic_ns()
            t_cpu0 = time.thread_time_ns()
            lock_taken = False
            items = 0
            for q in self.queues:
                if not q.lock.try_acquire():
                    continue
                lock_taken = True
                try:
                    vacation_ns = t_wake - q.last_busy_end_ns
                    busy_start = time.monotonic_ns()
                    while True:
                        burst = q.poll(self.burst_size)
                        if not burst:
                            break
                        items += len(burst)
                        if wake % self._lat_every == 0 and burst:
                            now = time.monotonic_ns()
                            sample = [(now - ts) / 1e3 for ts, _ in burst[:4]]
                            with self._stats_lock:
                                st.latency_samples_us.extend(sample)
                        self.process([it for _, it in burst])
                    busy_end = time.monotonic_ns()
                    q.last_busy_end_ns = busy_end
                    ctrl.on_cycle_end((busy_end - busy_start) / 1e3,
                                      max(vacation_ns / 1e3, 1e-3))
                finally:
                    q.lock.release()
            t_cpu1 = time.thread_time_ns()
            with self._stats_lock:
                st.wakeups += 1
                st.awake_ns += t_cpu1 - t_cpu0
                st.items += items
                if lock_taken:
                    st.cycles += 1
            wake += 1
            self.sleep_fn(ctrl.timeout_ns(primary=lock_taken))


class BusyPollLoop:
    """Classic DPDK loop (paper Listing 1): one dedicated spinning thread."""

    def __init__(self, queues: list[BoundedQueue], process: Callable[[list], None],
                 *, burst_size: int = 32):
        self.queues = queues
        self.process = process
        self.burst_size = burst_size
        self.stats = PollerStats()
        self._running = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self.stats = PollerStats(started_ns=time.monotonic_ns())
        self._running.set()
        self._thread = threading.Thread(target=self._run, name="busypoll", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> PollerStats:
        self._running.clear()
        if self._thread:
            self._thread.join(timeout)
        self.stats.stopped_ns = time.monotonic_ns()
        # By construction the loop never sleeps: CPU fraction is ~1.0.
        self.stats.awake_ns = self.stats.duration_ns
        return self.stats

    def _run(self) -> None:
        st = self.stats
        while self._running.is_set():
            st.wakeups += 1
            for q in self.queues:
                burst = q.poll(self.burst_size)
                if not burst:
                    continue
                st.items += len(burst)
                now = time.monotonic_ns()
                st.latency_samples_us.extend((now - ts) / 1e3 for ts, _ in burst[:2])
                self.process([it for _, it in burst])
