"""Deprecated shims: the real-thread loops now live in ``repro.runtime``.

``MetronomePollers`` / ``BusyPollLoop`` used to hand-roll the paper's
Listing-2 / Listing-1 loops here; both are now thin wrappers over the
generic ``repro.runtime.Runtime`` parameterized by a ``RetrievalPolicy``
(``MetronomePolicy`` / ``BusyPollPolicy``).  Prefer the new API:

    from repro.runtime import Runtime, MetronomePolicy
    rt = Runtime([queue], process, MetronomePolicy(cfg))

These names are kept so existing imports keep working; they emit a
``DeprecationWarning`` on construction.  ``PollerStats`` is the unified
``repro.runtime.RunStats`` under its old name (all old field names —
wakeups, cycles, busy_tries, items, awake_ns, cpu_fraction,
latency_samples_us — resolve on it).
"""

from __future__ import annotations

import warnings
from typing import Callable

from repro.runtime.policy import BusyPollPolicy, MetronomePolicy
from repro.runtime.queues import BoundedQueue
from repro.runtime.runtime import Runtime
from repro.runtime.stats import RunStats as PollerStats

from .controller import MetronomeConfig
from .hr_sleep import hr_sleep

__all__ = ["BoundedQueue", "PollerStats", "MetronomePollers", "BusyPollLoop"]


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.pollers.{old} is deprecated; use {new} from "
        "repro.runtime instead",
        DeprecationWarning, stacklevel=3)


class MetronomePollers(Runtime):
    """Deprecated alias for ``Runtime`` + ``MetronomePolicy``."""

    def __init__(
        self,
        queues: list[BoundedQueue],
        process: Callable[[list], None],
        cfg: MetronomeConfig | None = None,
        *,
        burst_size: int = 32,
        sleep_fn: Callable[[int], None] = hr_sleep,
        latency_sample_every: int = 16,
    ):
        _warn("MetronomePollers", "Runtime(queues, process, MetronomePolicy(cfg))")
        self.cfg = cfg or MetronomeConfig()
        policy = MetronomePolicy(self.cfg)
        super().__init__(queues, process, policy, burst_size=burst_size,
                         sleep_fn=sleep_fn,
                         latency_sample_every=latency_sample_every)
        self.controller = policy.controller


class BusyPollLoop(Runtime):
    """Deprecated alias for ``Runtime`` + ``BusyPollPolicy``."""

    def __init__(self, queues: list[BoundedQueue],
                 process: Callable[[list], None], *, burst_size: int = 32):
        _warn("BusyPollLoop", "Runtime(queues, process, BusyPollPolicy())")
        super().__init__(queues, process, BusyPollPolicy(),
                         burst_size=burst_size)
