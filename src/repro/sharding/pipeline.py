"""GPipe-style pipeline parallelism over a "pipe" mesh axis (DESIGN.md §4).

The production mesh for this assignment is DP×TP (16×16 / 2×16×16), so PP
ships as an optional substrate: ``gpipe`` runs a layer stack split into
S = |pipe| stages over M microbatches using shard_map + lax.ppermute —
the schedule is the classic (M + S - 1)-step ramp/drain with bubbles
masked.  Stage i holds layers [i·L/S, (i+1)·L/S); activations stream
stage→stage over collective-permute (ICI-neighbor traffic only, the reason
PP is the cross-pod axis of choice at 1000+ nodes).

Validated against the sequential reference in an 8-device subprocess
(tests/test_pipeline.py), including grads through the pipeline.
"""

from __future__ import annotations

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["gpipe"]


def gpipe(block_fn, stacked_params, x, mesh, *, pipe_axis: str = "pipe",
          n_microbatches: int):
    """Run ``y = block_fn(params_l, y)`` for every layer l, pipelined.

    stacked_params: pytree with leading layer dim L on every leaf
                    (L % n_stages == 0);
    x: (B, ...) with B % n_microbatches == 0.
    Returns y with x's shape.  Differentiable (jax.grad streams the
    backward pipeline in reverse automatically).
    """
    s = mesh.shape[pipe_axis]
    m = n_microbatches
    b = x.shape[0]
    assert b % m == 0, (b, m)
    lead = jax.tree.leaves(stacked_params)[0].shape[0]
    assert lead % s == 0, (lead, s)
    per_stage = lead // s

    # (L, ...) -> (S, L/S, ...): dim 0 shards over the pipe axis.
    staged = jax.tree.map(
        lambda p: p.reshape((s, per_stage) + p.shape[1:]), stacked_params)
    xmb = x.reshape((m, b // m) + x.shape[1:])

    def stage_fn(params, mb):
        # params: (1, L/S, ...) local stage slice;  mb: (M, mbs, ...) full.
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(pipe_axis)
        carry = jnp.zeros_like(mb[0])
        outs = jnp.zeros_like(mb)
        for t in range(m + s - 1):
            mb_idx = t - stage                      # microbatch at this stage
            active = (mb_idx >= 0) & (mb_idx < m)
            inp = jnp.where(stage == 0,
                            mb[jnp.clip(jnp.asarray(t), 0, m - 1)], carry)
            y = inp
            for l in range(per_stage):
                y = block_fn(jax.tree.map(lambda p: p[l], params), y)
            y = jnp.where(active, y, inp)
            idx = jnp.clip(mb_idx, 0, m - 1)
            store = active & (stage == s - 1)
            outs = outs.at[idx].set(jnp.where(store, y, outs[idx]))
            carry = jax.lax.ppermute(
                y, pipe_axis, [(i, (i + 1) % s) for i in range(s)])
        # broadcast final outputs from the last stage to all stages
        outs = jax.lax.psum(
            jnp.where(stage == s - 1, outs, jnp.zeros_like(outs)), pipe_axis)
        return outs

    other = tuple(a for a in mesh.axis_names if a != pipe_axis)
    fn = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(pipe_axis), staged), P()),
        out_specs=P(),
        check_vma=False)
    del other
    outs = fn(staged, xmb)
    return outs.reshape(x.shape)
