"""Parameter/optimizer/activation sharding policy.

Strategy (DESIGN.md §4):
  - TP over "model": attention head projections, FFN hidden, SSM inner dim.
  - FSDP/ZeRO over ("pod","data") in train mode: the non-TP dim of every
    2-D weight; optimizer moments inherit the param sharding (ZeRO-1+2 come
    for free; XLA emits all-gather-on-use / reduce-scatter-on-grad).
  - EP: MoE expert dim over "data" (16 experts / 16 rows), expert-internal
    hidden over "model".
  - Serve mode: no FSDP (params TP-only + EP) to avoid per-token
    all-gathers; decode KV caches shard batch over data and sequence over
    "model" (flash-decoding style partial-softmax, resolved by GSPMD).

Every rule degrades to replication when a dim is not divisible by the mesh
axis (e.g. vocab 50280 % 16 != 0) — correctness first, the roofline shows
the cost.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

__all__ = ["param_pspecs", "param_shardings", "logical_rules", "batch_pspec"]


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh, axes):
    """axes if dim divides evenly over them, else None (replicate)."""
    return axes if axes and dim % _axis_size(mesh, axes) == 0 else None


def logical_rules(mesh, mode: str, overrides: dict | None = None) -> dict:
    """Logical-axis -> physical mesh axes for activation annotations.

    `seq` (the residual-stream sequence dim between blocks) is None in the
    baseline (Megatron replicated residual: wo/w_down emit an all-reduce).
    Overriding it to "model" enables sequence parallelism (Korthikanti et
    al.): GSPMD turns the per-layer all-reduce into reduce-scatter +
    all-gather, halving residual collective bytes — a §Perf lever.
    """
    bx = batch_axes(mesh)
    rules = {
        "batch": bx if len(bx) > 1 else (bx[0] if bx else None),
        "model": "model",
        "expert": "data",
        "expert_capacity": None,
        "kv_seq": None,
        "seq": None,
    }
    if mode == "decode":
        rules["kv_seq"] = "model"
    if overrides:
        rules.update(overrides)
    return rules


def batch_pspec(mesh) -> P:
    bx = batch_axes(mesh)
    return P(bx if len(bx) > 1 else (bx[0] if bx else None))


def _leaf_spec(path: tuple[str, ...], leaf, cfg: ModelConfig, mesh,
               mode: str) -> P:
    """PartitionSpec for one parameter, by name pattern (see module doc)."""
    name = path[-1]
    shape = leaf.shape
    in_blocks = "blocks" in path
    # strip the stacked scan-group dim for rule matching
    dims = shape[1:] if in_blocks else shape
    mdl = "model"
    fsdp = batch_axes(mesh) if mode == "train" else None
    if fsdp is not None and len(fsdp) == 1:
        fsdp = fsdp[0]

    def fit(i, ax):
        return _fits(dims[i], mesh, ax)

    if name in ("embed",):
        # Tied embeddings double as the vocab-parallel output head: vocab
        # over "model" so the logits matmul contracts a replicated D and
        # emits vocab-sharded logits with no giant collective.  Untied
        # input-only tables shard vocab over FSDP in train (masked gather +
        # one activation all-reduce over data); in serve mode (no FSDP)
        # shard D over "model" instead — a replicated 2 GB embed table per
        # chip was the internvl2 decode peak-memory offender (§Perf B1).
        if cfg.tie_embeddings:
            spec = (fit(0, mdl), fit(1, fsdp))
        elif mode == "train":
            spec = (fit(0, fsdp), fit(1, None))
        else:
            spec = (fit(0, None), fit(1, mdl))
    elif name == "lm_head":                     # (D, V): vocab-parallel
        spec = (fit(0, fsdp), fit(1, mdl))
    elif name == "pos_embed":                   # (S, D)
        spec = (None, fit(1, mdl))
    elif name in ("wq", "wk", "wv", "wz", "wx", "wdt"):   # (D, X)
        spec = (fit(0, fsdp), fit(1, mdl))
    elif name in ("wB", "wC"):                  # (D, ds): ds small
        spec = (fit(0, fsdp), fit(1, None))
    elif name in ("wo", "out"):                 # (X, D)
        spec = (fit(0, mdl), fit(1, fsdp))
    elif name == "router":                      # (D, E): tiny, replicate
        spec = (None, None)
    elif name in ("w_gate", "w_up"):
        if len(dims) == 3:                      # (E, D, F): EP + TP
            spec = (fit(0, "data"), None, fit(2, mdl))
        else:                                   # (D, F)
            spec = (fit(0, fsdp), fit(1, mdl))
    elif name == "w_down":
        if len(dims) == 3:                      # (E, F, D)
            spec = (fit(0, "data"), fit(1, mdl), None)
        else:                                   # (F, D)
            spec = (fit(0, mdl), fit(1, fsdp))
    elif name == "conv_w":                      # (W, convdim)
        spec = (None, fit(1, mdl))
    elif name in ("conv_b", "gate_norm"):       # (convdim,) / (d_in,)
        spec = (fit(0, mdl),)
    else:                                       # norms, biases, A_log, ...
        spec = (None,) * len(dims)

    if in_blocks:
        spec = (None,) + tuple(spec)
    return P(*spec)


def param_pspecs(cfg: ModelConfig, params, mesh, mode: str):
    """Pytree of PartitionSpec matching `params` (or its eval_shape tree)."""
    def visit(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "idx", None))
                      for k in path)
        return _leaf_spec(names, leaf, cfg, mesh, mode)
    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(cfg: ModelConfig, params, mesh, mode: str):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, params, mesh, mode))


def _cache_leaf_spec(name: str, leaf, mesh, mode: str) -> P:
    """KV / SSM cache sharding.

    Decode: batch over data(+pod), KV sequence over "model" (flash-decoding
    style partial softmax — GSPMD inserts the max/sum reductions).  Prefill
    outputs use the same layout so the engine can hand them to decode
    without a reshard.
    """
    bx = batch_axes(mesh)
    bax = bx if len(bx) > 1 else (bx[0] if bx else None)
    b = _fits(leaf.shape[1], mesh, bax)
    if name in ("k", "v"):            # (G, B, S, KV, hd)
        return P(None, b, _fits(leaf.shape[2], mesh, "model"), None, None)
    if name in ("k_scale", "v_scale"):  # (G, B, S, KV)
        return P(None, b, _fits(leaf.shape[2], mesh, "model"), None)
    if name == "conv":                # (G, B, W-1, conv_dim)
        return P(None, b, None, _fits(leaf.shape[3], mesh, "model"))
    if name == "ssm":                 # (G, B, nh, hd, N)
        return P(None, b, _fits(leaf.shape[2], mesh, "model"), None, None)
    return P(*([None] * leaf.ndim))


def cache_pspecs(cache, mesh, mode: str = "decode"):
    def visit(path, leaf):
        name = getattr(path[-1], "key", None)
        return _cache_leaf_spec(name, leaf, mesh, mode)
    return jax.tree_util.tree_map_with_path(visit, cache)


def cache_shardings(cache, mesh, mode: str = "decode"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(cache, mesh, mode))
