"""Logical-axis sharding annotations (MaxText-style logical->physical rules).

Model code annotates activations with *logical* axis names
(``shard(x, ("batch", None, "model"))``); the launcher installs a rule set
mapping logical names to physical mesh axes for the active parallelism
strategy.  Outside any rule context the annotations are identity, so model
code runs unchanged in single-device tests.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["logical_axis_rules", "shard", "current_rules", "to_pspec"]

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None), getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_axis_rules(mesh, rules: dict[str, tuple[str, ...] | str | None]):
    """rules: logical axis name -> physical mesh axis (or tuple, or None).

    Active during *tracing*: wrap the ``jit(...).lower(...)`` call.
    """
    prev = current_rules()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def to_pspec(spec: tuple, rules: dict) -> P:
    return P(*[None if ax is None else rules.get(ax) for ax in spec])


def _divisible(shape, pspec, mesh) -> bool:
    for dim, ax in zip(shape, tuple(pspec) + (None,) * len(shape)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n:
            return False
    return True


def shard(x, spec: tuple):
    """Apply with_sharding_constraint if logical rules are active."""
    rules, mesh = current_rules()
    if rules is None or mesh is None:
        return x
    pspec = to_pspec(spec, rules)
    if not _divisible(x.shape, pspec, mesh):
        return x  # replicate rather than force uneven sharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
