from .logical import logical_axis_rules, shard  # noqa: F401
