"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

Dispatch strategy (baseline): tokens are scattered into per-expert buffers
of capacity ``C = tokens*k/E * capacity_factor`` (GShard/Switch-style,
"dropping" implementation — the standard MaxText formulation).  Expert and
buffer dims carry logical sharding annotations so GSPMD lowers the dispatch
to all-to-all on the expert axis under expert parallelism; the roofline
§Perf iterations on the MoE archs start from this baseline.

FLOPs scale with *active* experts (k/E of dense-all-experts), which is what
the MODEL_FLOPS/HLO_FLOPs roofline ratio checks.
"""

from __future__ import annotations

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.logical import current_rules, shard
from .layers import dense_init, mlp_init, mlp_apply

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig, dtype):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.n_shared_experts, "swiglu", dtype)
    return p


def _route(p, cfg: ModelConfig, xf):
    """Shared router math. xf: (T, D) -> (gate (T,k), idx (T,k), aux)."""
    t = xf.shape[0]
    e, k = cfg.n_experts, cfg.experts_per_token
    router_logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    return probs, gate, idx


def _positions(idx, e: int):
    """Slot positions via cumsum over the flat (T*k,) assignment order."""
    t, k = idx.shape
    assign = jax.nn.one_hot(idx, e, dtype=jnp.float32)
    flat = assign.reshape(t * k, e)
    pos = ((jnp.cumsum(flat, axis=0) - flat) * flat).sum(-1).astype(jnp.int32)
    return assign, pos


def _expert_mlp(cfg: ModelConfig, p, buf):
    """Per-expert GLU MLP on a dispatch buffer (E_loc, C, D)."""
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        h = act(g) * h_up
    else:
        h = jax.nn.gelu(h_up, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _moe_shard_map(p, cfg: ModelConfig, x, mesh, rules):
    """Partition-local EP dispatch (§Perf): local scatter -> all_to_all(E)
    -> local expert GEMMs -> psum(model) -> all_to_all back -> local
    combine.  No data-dependent global scatter ever crosses the mesh, so
    the only collectives are the canonical MoE all-to-alls + one psum.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    data_ax = rules["expert"]                 # expert exchange axis
    model_ax = rules["model"]
    bx = rules["batch"]
    batch_axes = bx if isinstance(bx, tuple) else ((bx,) if bx else ())
    n_tok_shards = 1
    for a in batch_axes:
        n_tok_shards *= mesh.shape[a]
    t = b * s
    t_loc = t // n_tok_shards
    cap_loc = max(int(t_loc * k / e * cfg.capacity_factor), 1)
    n_data = mesh.shape[data_ax]

    def local_fn(xl, router, wg, wu, wd, shared):
        # xl: (t_loc, d); wg/wu: (1, d, f_loc); wd: (1, f_loc, d)
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        probs, gate, idx = _route(pl, cfg, xl)
        assign, pos = _positions(idx, e)
        f_e = jax.lax.psum(assign.sum(axis=(0, 1)), batch_axes) / (t * k)
        p_e = jax.lax.psum(probs.sum(axis=0), batch_axes) / t
        aux = e * jnp.sum(f_e * p_e)

        eid = idx.reshape(t_loc * k)
        keep = pos < cap_loc
        slot = jnp.minimum(pos, cap_loc - 1)
        xk = jnp.repeat(xl[:, None, :], k, axis=1).reshape(t_loc * k, d)
        contrib = jnp.where(keep[:, None], xk, 0).astype(x.dtype)
        buf = jnp.zeros((e, cap_loc, d), x.dtype).at[eid, slot].add(contrib)

        # exchange: every shard sends expert j's slice to shard j
        buf = jax.lax.all_to_all(buf, data_ax, split_axis=0, concat_axis=1,
                                 tiled=True)          # (e_loc, C, d)
        y = _expert_mlp(cfg, pl, buf)                 # partial over f_loc
        y = jax.lax.psum(y.astype(xl.dtype), model_ax)  # bf16 on the wire
        y = jax.lax.all_to_all(y, data_ax, split_axis=1, concat_axis=0,
                               tiled=True)            # (e, cap_loc, d)

        w = (gate.reshape(t_loc * k) * keep).astype(x.dtype)
        out = (y[eid, slot] * w[:, None]).reshape(t_loc, k, d).sum(axis=1)
        if shared is not None:
            sh_up = xl @ shared["w_up"]
            sh_g = jax.nn.silu(xl @ shared["w_gate"])
            out = out + jax.lax.psum((sh_g * sh_up) @ shared["w_down"],
                                     model_ax)
        return out, aux

    tok_spec = P(bx) if batch_axes else P()
    shared_specs = ({"w_gate": P(None, model_ax), "w_up": P(None, model_ax),
                     "w_down": P(model_ax, None)}
                    if cfg.n_shared_experts else None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bx, None), P(None, None),
                  P(data_ax, None, model_ax), P(data_ax, None, model_ax),
                  P(data_ax, model_ax, None), shared_specs),
        out_specs=(P(bx, None), P()),
        check_vma=False)
    y, aux = fn(x.reshape(t, d), p["router"], p["w_gate"], p["w_up"],
                p["w_down"], p.get("shared") if cfg.n_shared_experts else None)
    return y.reshape(b, s, d), aux


def _moe_sharding_ok(cfg: ModelConfig, x, mesh, rules) -> bool:
    """shard_map path needs even divisibility everywhere."""
    if rules is None or mesh is None:
        return False
    data_ax, model_ax, bx = rules.get("expert"), rules.get("model"), rules.get("batch")
    if rules.get("moe") != "shard_map" or not data_ax or not model_ax:
        return False
    batch_axes = bx if isinstance(bx, tuple) else ((bx,) if bx else ())
    n_tok = 1
    for a in batch_axes:
        n_tok *= mesh.shape[a]
    t = x.shape[0] * x.shape[1]
    # partition-local capacity must stay statistically safe: with too few
    # tokens per shard (decode), local top-k skew would drop tokens, so
    # fall back to the global-dispatch path there.
    enough = t // max(n_tok, 1) * cfg.experts_per_token >= 4 * cfg.n_experts
    return (n_tok > 0 and t % n_tok == 0 and enough
            and cfg.n_experts % mesh.shape[data_ax] == 0
            and cfg.d_ff % mesh.shape[model_ax] == 0)


def moe_apply(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (y, aux_loss).  Top-k routing, renormalized weights."""
    rules, mesh = current_rules()
    if _moe_sharding_ok(cfg, x, mesh, rules):
        return _moe_shard_map(p, cfg, x, mesh, rules)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    router_logits = xf.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                            # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)      # renorm

    # Load-balancing aux loss (Switch §2.2): E * sum_e f_e * P_e.
    assign = jax.nn.one_hot(idx, e, dtype=jnp.float32)             # (T, k, E)
    f_e = assign.sum(axis=(0, 1)) / (t * k)
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)

    # --- capacity-based scatter dispatch ---------------------------------
    cap = max(int(t * k / e * cfg.capacity_factor), 1)
    flat_assign = assign.reshape(t * k, e)
    pos = ((jnp.cumsum(flat_assign, axis=0) - flat_assign) * flat_assign).sum(-1)
    pos = pos.astype(jnp.int32)                                    # (T*k,)
    eid = idx.reshape(t * k)
    keep = (pos < cap)
    slot = jnp.minimum(pos, cap - 1)

    xk = jnp.repeat(xf[:, None, :], k, axis=1).reshape(t * k, d)
    contrib = jnp.where(keep[:, None], xk, 0).astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype).at[eid, slot].add(contrib)
    # Dispatch-buffer layout is a perf lever (EXPERIMENTS.md §Perf):
    #   baseline  expert->data, expert_capacity->None : buffer sharded on E
    #     — the token->buffer scatter crosses the data axis and GSPMD
    #     lowers it to full-buffer all-reduces;
    #   optimized expert->None, expert_capacity->data : buffer sharded on C
    #     — the scatter is local and the expert einsum reshard lowers to
    #     all-to-all (canonical MoE EP dispatch).
    buf = shard(buf, ("expert", "expert_capacity", None))

    # --- expert computation (per-expert GLU MLP) -------------------------
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        h = act(g) * h_up
    else:
        h = jax.nn.gelu(h_up, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = shard(out_buf, ("expert", "expert_capacity", None))

    # --- combine ----------------------------------------------------------
    gathered = out_buf[eid, slot]                                  # (T*k, D)
    w = (gate.reshape(t * k) * keep).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xf, "swiglu")
    return y.reshape(b, s, d), aux
