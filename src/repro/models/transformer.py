"""Composable decoder/enc-dec stack covering all 10 assigned architectures.

The layer plan (configs.base.ModelConfig.layer_plan) is compressed to its
smallest repeating unit and the stack is executed as ``jax.lax.scan`` over
stacked per-group parameters — constant-size HLO regardless of depth (80L
internvl2 and 72L jamba compile as fast as 2 layers), which is what makes
the 512-device dry-run tractable.

Supported plans:
  dense        unit=1:  (attn, dense)
  moe          unit=1:  (attn, moe)
  gemma2       unit=2:  (attn_local, dense), (attn, dense)
  ssm          unit=1:  (ssm, none)
  jamba hybrid unit=8:  (attn, moe?), (ssm, ...)x7 with moe every 2nd layer
  whisper      encoder stack (bidirectional) + decoder w/ cross-attention

Three entry points per model: ``forward`` (full sequence, train),
``prefill`` (full sequence -> logits + KV/SSM cache), ``decode_step``
(one token, cache update).  VLM/audio frontends are stubs per the
assignment: ``prefix_embeds`` / ``enc_frames`` arrive precomputed.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.logical import shard
from . import attention as attn
from . import mamba2 as ssm
from .layers import embed_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init, softcap
from .moe import moe_apply, moe_init

__all__ = ["Model"]


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, mixer: str, ffn: str, dtype,
                *, with_cross: bool, bidir: bool = False):
    ks = jax.random.split(key, 4)
    p: dict = {}
    if mixer.startswith("attn"):
        p["mixer_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = attn.attn_init(ks[0], cfg, dtype)
    elif mixer == "ssm":
        p["mixer_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = ssm.ssm_init(ks[0], cfg, dtype)
    if with_cross:
        p["cross_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn.attn_init(ks[1], cfg, dtype, cross=True)
    if ffn == "dense":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    elif ffn == "moe":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe_init(ks[3], cfg, dtype)
    return p


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    # Fully unroll the layer scans (roofline probes: XLA's HloCostAnalysis
    # counts while-loop bodies once, so exact FLOP/byte/collective counts
    # need loop-free HLO; see roofline/analysis.py).
    unroll: bool = False

    # ---- construction -----------------------------------------------------
    def init(self, key, *, max_seq: int = 4096):
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        unit = cfg.scan_unit()
        plan = cfg.layer_plan()[:unit]
        groups = cfg.n_layers // unit
        k_emb, k_blocks, k_head, k_enc = jax.random.split(key, 4)

        def init_group(gkey):
            lks = jax.random.split(gkey, unit)
            return {f"layer{j}": _init_layer(
                        lks[j], cfg, plan[j][0], plan[j][1], dtype,
                        with_cross=cfg.is_encdec)
                    for j in range(unit)}

        params = {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "blocks": jax.vmap(init_group)(jax.random.split(k_blocks, groups)),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
        has_attn = any(m.startswith("attn") for m, _ in cfg.layer_plan())
        if not cfg.use_rope and has_attn:
            # learned absolute positions (whisper); attention-free stacks
            # (mamba2) need no positional encoding at all
            params["pos_embed"] = embed_init(
                jax.random.fold_in(k_emb, 1), max_seq, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype).T
        if cfg.is_encdec:
            def init_enc_group(gkey):
                return {"layer0": _init_layer(gkey, cfg, "attn", "dense",
                                              dtype, with_cross=False, bidir=True)}
            eg = cfg.n_encoder_layers
            params["encoder"] = {
                "pos_embed": embed_init(jax.random.fold_in(k_enc, 0),
                                        max_seq, cfg.d_model, dtype),
                "blocks": jax.vmap(init_enc_group)(jax.random.split(k_enc, eg)),
                "final_norm": rmsnorm_init(cfg.d_model, dtype),
            }
        return params

    # ---- shared pieces -----------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
        if cfg.scale_embeddings:
            x *= jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            # einsum instead of `@ embed.T`: the transpose folds into the
            # dot instead of materializing a copied table (§Perf C2)
            logits = jnp.einsum("bsd,vd->bsv", x,
                                params["embed"].astype(x.dtype))
        else:
            logits = x @ params["lm_head"].astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        return shard(logits, ("batch", None, "model"))

    def _unit_plan(self):
        unit = self.cfg.scan_unit()
        return self.cfg.layer_plan()[:unit]

    # ---- encoder (whisper) --------------------------------------------------
    def encode(self, params, enc_frames):
        """enc_frames: (B, T, D) precomputed stub frontend embeddings."""
        cfg = self.cfg
        enc = params["encoder"]
        t = enc_frames.shape[1]
        x = enc_frames.astype(_dtype(cfg.compute_dtype))
        x = x + enc["pos_embed"][:t].astype(x.dtype)
        positions = jnp.arange(t)

        def body(carry, gp):
            h = carry
            sub = gp["layer0"]
            a = attn.attn_apply(sub["mixer"], cfg,
                                rmsnorm(h, sub["mixer_norm"], cfg.norm_eps),
                                positions, causal=False)
            h = h + a
            f = mlp_apply(sub["ffn"],
                          rmsnorm(h, sub["ffn_norm"], cfg.norm_eps), cfg.mlp_type)
            h = h + f
            h = shard(h, ("batch", "seq", None))
            return h, None

        x, _ = jax.lax.scan(body, x, enc["blocks"], unroll=self.unroll)
        return rmsnorm(x, enc["final_norm"], cfg.norm_eps)

    # ---- full-sequence decoder (train / prefill core) ------------------------
    def _stack(self, params, x, positions, memory, *, collect_cache: bool,
               remat: bool = False):
        cfg = self.cfg
        plan = self._unit_plan()

        def body(carry, gp):
            h, aux = carry
            cache_out = {}
            for j, (mixer, ffn) in enumerate(plan):
                sub = gp[f"layer{j}"]
                if mixer.startswith("attn"):
                    hin = rmsnorm(h, sub["mixer_norm"], cfg.norm_eps)
                    if collect_cache:
                        a, entry = attn.attn_prefill(
                            sub["mixer"], cfg, hin, positions,
                            local=(mixer == "attn_local"))
                        cache_out[f"layer{j}"] = entry
                    else:
                        a = attn.attn_apply(sub["mixer"], cfg, hin, positions,
                                            local=(mixer == "attn_local"))
                    h = h + a
                elif mixer == "ssm":
                    hin = rmsnorm(h, sub["mixer_norm"], cfg.norm_eps)
                    a, state = ssm.ssm_forward(sub["mixer"], cfg, hin)
                    if collect_cache:
                        cache_out[f"layer{j}"] = state
                    h = h + a
                if cfg.is_encdec:
                    hin = rmsnorm(h, sub["cross_norm"], cfg.norm_eps)
                    c = attn.attn_apply(sub["cross"], cfg, hin, positions,
                                        causal=False, xkv=memory)
                    h = h + c
                if ffn == "dense":
                    f = mlp_apply(sub["ffn"],
                                  rmsnorm(h, sub["ffn_norm"], cfg.norm_eps),
                                  cfg.mlp_type)
                    h = h + f
                elif ffn == "moe":
                    f, a_loss = moe_apply(sub["ffn"], cfg,
                                          rmsnorm(h, sub["ffn_norm"], cfg.norm_eps))
                    h = h + f
                    aux = aux + a_loss
                h = shard(h, ("batch", "seq", None))
            return (h, aux), cache_out if collect_cache else None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        params["blocks"], unroll=self.unroll)
        return x, aux, caches

    def forward(self, params, batch, *, remat: bool = False):
        """Full-sequence logits. batch: dict(tokens, prefix_embeds?, enc_frames?)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.frontend and "prefix_embeds" in batch:
            pre = batch["prefix_embeds"].astype(x.dtype)
            x = jnp.concatenate([pre, x], axis=1)
        s = x.shape[1]
        positions = batch.get("positions", jnp.arange(s))
        if not cfg.use_rope and "pos_embed" in params:
            x = x + params["pos_embed"][:s].astype(x.dtype)
        memory = self.encode(params, batch["enc_frames"]) if cfg.is_encdec else None
        x = shard(x, ("batch", "seq", None))
        x, aux, _ = self._stack(params, x, positions, memory,
                                collect_cache=False, remat=remat)
        return self._logits(params, x), {"moe_aux": aux}

    # ---- serving: prefill + decode -------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        """Zeroed cache pytree with leaves stacked over scan groups."""
        cfg = self.cfg
        dtype = _dtype(cfg.compute_dtype)
        plan = self._unit_plan()
        groups = cfg.n_layers // cfg.scan_unit()

        def one_group():
            c = {}
            for j, (mixer, _) in enumerate(plan):
                if mixer.startswith("attn"):
                    c[f"layer{j}"] = attn.init_kv_cache(
                        cfg, batch, max_len, dtype,
                        local=(mixer == "attn_local"))
                elif mixer == "ssm":
                    c[f"layer{j}"] = ssm.init_ssm_state(cfg, batch, dtype)
            return c

        cache = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (groups,) + leaf.shape),
            one_group())
        return cache

    def prefill(self, params, batch):
        """Returns (logits_full, cache).  Cache holds S_prefill positions;
        callers pass it (padded to max_len by the engine) to decode_step."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.frontend and "prefix_embeds" in batch:
            x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], 1)
        s = x.shape[1]
        positions = batch.get("positions", jnp.arange(s))
        if not cfg.use_rope and "pos_embed" in params:
            x = x + params["pos_embed"][:s].astype(x.dtype)
        memory = self.encode(params, batch["enc_frames"]) if cfg.is_encdec else None
        x = shard(x, ("batch", "seq", None))
        x, aux, cache = self._stack(params, x, positions, memory,
                                    collect_cache=True)
        if cfg.is_encdec:
            cache = {"self": cache, "cross": self._cross_cache(params, memory)}
        return self._logits(params, x), cache

    def _cross_cache(self, params, memory):
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def body(_, gp):
            sub = gp["layer0"]
            k = (memory @ sub["cross"]["wk"]).reshape(*memory.shape[:-1], -1, hd)
            v = (memory @ sub["cross"]["wv"]).reshape(*memory.shape[:-1], -1, hd)
            return None, {"k": k, "v": v}

        _, cross = jax.lax.scan(body, None, params["blocks"], unroll=self.unroll)
        return cross

    def decode_step(self, params, tokens, cache, pos):
        """tokens: (B,) int32; pos: (B,) int32 current positions.

        Returns (logits: (B, vocab), new_cache)."""
        cfg = self.cfg
        plan = self._unit_plan()
        x = self._embed(params, tokens[:, None])
        if not cfg.use_rope and "pos_embed" in params:
            x = x + params["pos_embed"][pos][:, None].astype(x.dtype)
        self_cache = cache["self"] if cfg.is_encdec else cache
        cross_cache = cache.get("cross") if cfg.is_encdec else None
        scanned = (params["blocks"], self_cache) + (
            (cross_cache,) if cross_cache is not None else ())

        def body(h, gp_gc):
            gp, gc = gp_gc[0], gp_gc[1]
            xc = gp_gc[2] if len(gp_gc) > 2 else None
            new_gc = {}
            for j, (mixer, ffn) in enumerate(plan):
                sub = gp[f"layer{j}"]
                if mixer.startswith("attn"):
                    hin = rmsnorm(h, sub["mixer_norm"], cfg.norm_eps)
                    a, kv = attn.attn_decode(sub["mixer"], cfg, hin,
                                             gc[f"layer{j}"], pos,
                                             local=(mixer == "attn_local"))
                    new_gc[f"layer{j}"] = kv
                    h = h + a
                elif mixer == "ssm":
                    hin = rmsnorm(h, sub["mixer_norm"], cfg.norm_eps)
                    a, st = ssm.ssm_decode(sub["mixer"], cfg, hin, gc[f"layer{j}"])
                    new_gc[f"layer{j}"] = st
                    h = h + a
                if cfg.is_encdec:
                    hin = rmsnorm(h, sub["cross_norm"], cfg.norm_eps)
                    b = h.shape[0]
                    q = (hin @ sub["cross"]["wq"]).reshape(
                        b, 1, cfg.n_heads, cfg.resolved_head_dim)
                    o = attn._sdpa(cfg, q, xc["k"], xc["v"], None)
                    h = h + o.reshape(b, 1, -1) @ sub["cross"]["wo"]
                if ffn == "dense":
                    h = h + mlp_apply(sub["ffn"],
                                      rmsnorm(h, sub["ffn_norm"], cfg.norm_eps),
                                      cfg.mlp_type)
                elif ffn == "moe":
                    f, _ = moe_apply(sub["ffn"], cfg,
                                     rmsnorm(h, sub["ffn_norm"], cfg.norm_eps))
                    h = h + f
            return h, new_gc

        x, new_self = jax.lax.scan(body, x, scanned, unroll=self.unroll)
        logits = self._logits(params, x)[:, 0]
        if cfg.is_encdec:
            return logits, {"self": new_self, "cross": cross_cache}
        return logits, new_self
