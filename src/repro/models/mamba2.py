"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD forward for train/prefill (quadratic within a chunk, linear
recurrence across chunks) and an O(1)-per-token recurrent decode step.
The intra-chunk core can route through the Pallas kernel
(kernels/ssd_scan); this module is the pure-jnp reference path.

Math (per head h, state dim N):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t  x_t^T      (A < 0 scalar/head)
    y_t = C_t . h_t + D x_t
Chunked over Q-length chunks with inclusive in-chunk log-decay cumsum
``cum``:
    y_intra[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) dt_j x_j
    y_inter[i] = exp(cum_i) C_i . h_chunk_start
    S_chunk    = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    h_next     = exp(cum_last) h_prev + S_chunk
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import dense_init, rmsnorm, rmsnorm_init

__all__ = ["ssm_init", "ssm_dims", "ssm_forward", "ssm_decode", "init_ssm_state",
           "ssd_chunked"]


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, nh, conv_dim


def ssm_init(key, cfg: ModelConfig, dtype):
    d, ds = cfg.d_model, cfg.ssm_state
    d_in, nh, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 8)
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (mamba2 reference init)
    dt = np.exp(np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), nh))
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "wz": dense_init(ks[0], (d, d_in), dtype),
        "wx": dense_init(ks[1], (d, d_in), dtype),
        "wB": dense_init(ks[2], (d, ds), dtype),
        "wC": dense_init(ks[3], (d, ds), dtype),
        "wdt": dense_init(ks[4], (d, nh), dtype),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "A_log": jnp.log(jnp.asarray(
            np.random.RandomState(1).uniform(1.0, 16.0, nh), jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (cfg.ssm_conv_width, conv_dim), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "gate_norm": rmsnorm_init(d_in, dtype),
        "out": dense_init(ks[6], (d_in, d), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted sums. x: (B,L,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b[None, None, :])


def _conv_tail(x, width):
    """Last (W-1) raw inputs — the decode-time conv state."""
    b, length, c = x.shape
    pad = jnp.pad(x, ((0, 0), (max(width - 1 - length, 0), 0), (0, 0)))
    return pad[:, -(width - 1):, :]


def _segsum_exp(cum):
    """exp(cum_i - cum_j) masked to i >= j. cum: (..., Q). -> (..., Q, Q)."""
    q = cum.shape[-1]
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(seg), 0.0)


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int, h0=None):
    """Chunked SSD scan (fp32 internals).

    x:    (B, L, nh, hd)   inputs
    dt:   (B, L, nh)       positive step sizes
    a:    (nh,)            negative decay rates (A = -exp(A_log))
    bmat: (B, L, N)        input  projections (G=1 group, shared over heads)
    cmat: (B, L, N)        output projections
    h0:   (B, nh, hd, N)   initial state (None -> zeros)
    Returns (y: (B,L,nh,hd), h_final: (B,nh,hd,N)).
    """
    bsz, length, nh, hd = x.shape
    n = bmat.shape[-1]
    assert length % chunk == 0, (length, chunk)
    nc = length // chunk
    f32 = jnp.float32
    xc = x.reshape(bsz, nc, chunk, nh, hd).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, nh).astype(f32)
    bc = bmat.reshape(bsz, nc, chunk, n).astype(f32)
    cc = cmat.reshape(bsz, nc, chunk, n).astype(f32)
    da = dtc * a[None, None, None, :]                      # (B,nc,Q,nh) log-decay
    cum = jnp.cumsum(da, axis=2)                           # inclusive

    # Intra-chunk (the quadratic, attention-like term).
    decay = _segsum_exp(jnp.moveaxis(cum, -1, 2))          # (B,nc,nh,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,nc,Q,Q)
    att = scores[:, :, None] * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhd->bcihd", att, xc)

    # Chunk summary states.
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,Q,nh)
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhd->bchdn",
                         decay_out * dtc, bc, xc)          # (B,nc,nh,hd,N)
    total = jnp.exp(cum[:, :, -1, :])                      # (B,nc,nh)

    # Inter-chunk recurrence (sequential scan over chunks).
    hinit = (jnp.zeros((bsz, nh, hd, n), f32) if h0 is None
             else h0.astype(f32))

    def step(h, inp):
        s_c, tot = inp
        return tot[..., None, None] * h + s_c, h           # emit state *before*

    (h_final, h_prevs) = jax.lax.scan(
        step, hinit,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,nh,hd,N)

    y_inter = jnp.einsum("bcqn,bchdn->bcqhd", cc, h_prevs) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, length, nh, hd)
    return y.astype(x.dtype), h_final


def ssm_forward(p, cfg: ModelConfig, x):
    """Full-sequence Mamba2 block. x: (B,L,D) -> (y, state_dict)."""
    d_in, nh, conv_dim = ssm_dims(cfg)
    hd, ds, width = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv_width
    z = x @ p["wz"]
    raw = jnp.concatenate([x @ p["wx"], x @ p["wB"], x @ p["wC"]], axis=-1)
    conv_out = _causal_conv(raw, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(*xs.shape[:-1], nh, hd)
    y, h_final = ssd_chunked(xh.astype(jnp.float32), dt, a, bmat, cmat,
                             min(cfg.ssm_chunk, x.shape[1]))
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(*x.shape[:-1], d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    state = {"conv": _conv_tail(raw, width), "ssm": h_final.astype(jnp.float32)}
    return y @ p["out"], state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype):
    d_in, nh, conv_dim = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }


def ssm_decode(p, cfg: ModelConfig, x, state):
    """Single-token recurrent step. x: (B,1,D) -> (y: (B,1,D), new state)."""
    d_in, nh, conv_dim = ssm_dims(cfg)
    hd, ds = cfg.ssm_head_dim, cfg.ssm_state
    b = x.shape[0]
    z = x @ p["wz"]                                         # (B,1,d_in)
    raw = jnp.concatenate([x @ p["wx"], x @ p["wB"], x @ p["wC"]], axis=-1)
    window = jnp.concatenate([state["conv"].astype(raw.dtype), raw], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]            # (B,1,convdim)
    xs, bmat, cmat = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) +
                         p["dt_bias"][None, None, :])[:, 0]  # (B,nh)
    a = -jnp.exp(p["A_log"])
    xh = xs[:, 0].reshape(b, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])                        # (B,nh)
    h = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhd->bhdn", dt, bmat[:, 0].astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhdn->bhd", cmat[:, 0].astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out"], {"conv": window[:, 1:, :], "ssm": h}
