from .transformer import Model  # noqa: F401
