"""Stub modality frontends (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; the frontend provides precomputed
frame/patch embeddings).

These helpers synthesize deterministic embeddings with the right shapes —
what a real ViT patchifier (internvl2) or log-mel conv stack (whisper)
would emit — for tests, examples, and the serving path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["vision_patches", "audio_frames"]


def vision_patches(cfg: ModelConfig, batch: int, *, key=None):
    """(B, frontend_len, d_model) patch embeddings (InternViT stand-in)."""
    assert cfg.frontend == "vision_stub", cfg.name
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.random.normal(
        key, (batch, cfg.frontend_len, cfg.d_model),
        dtype=jnp.dtype(cfg.compute_dtype)) * 0.02


def audio_frames(cfg: ModelConfig, batch: int, n_frames: int, *, key=None):
    """(B, T, d_model) encoder frame embeddings (conv frontend stand-in)."""
    assert cfg.frontend == "audio_stub", cfg.name
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.random.normal(
        key, (batch, n_frames, cfg.d_model),
        dtype=jnp.dtype(cfg.compute_dtype)) * 0.02
