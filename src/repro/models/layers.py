"""Shared neural net layers (pure JAX, functional, dict params)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init", "rmsnorm_init", "rmsnorm", "softcap", "rope_freqs",
    "apply_rope", "mlp_init", "mlp_apply", "embed_init",
]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Lecun-normal by fan-in (first dim for (in, out) matrices)."""
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d, dtype):
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    """gemma2-style logit soft capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs          # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN): swiglu / geglu / gelu
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[2], (d_ff, d_model), dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype)
    return p


def mlp_apply(p, x, mlp_type: str):
    up = x @ p["w_up"]
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * up
    elif mlp_type == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(mlp_type)
    return h @ p["w_down"]
