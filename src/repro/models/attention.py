"""Attention: MHA/GQA/MQA, causal + local-window, softcap, KV-cache decode.

Full-sequence paths can route through the Pallas flash-attention kernel
(kernels/flash_attention) when ``use_kernel`` is set; the default is the
pure-jnp reference path (identical math — the kernel is validated against
it in tests/test_kernels_*.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.logical import current_rules
from .layers import apply_rope, dense_init, rmsnorm, rmsnorm_init, softcap

__all__ = ["attn_init", "attn_apply", "attn_prefill", "attn_decode", "init_kv_cache"]

NEG_INF = -2.3819763e38  # bf16-safe large negative


def attn_init(key, cfg: ModelConfig, dtype, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, (cfg.n_heads if cross else cfg.n_kv_heads)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, h * hd), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, kv * hd), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, xq, xkv):
    hd = cfg.resolved_head_dim
    q = (xq @ p["wq"]).reshape(*xq.shape[:-1], -1, hd)
    k = (xkv @ p["wk"]).reshape(*xkv.shape[:-1], -1, hd)
    v = (xkv @ p["wv"]).reshape(*xkv.shape[:-1], -1, hd)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask, *, k_scale=None, v_scale=None):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask: (B,1,1,S,T) or None.

    k_scale/v_scale: (B,T,KV) dequant scales for int8 KV — they factor out
    of the contraction over hd (k) and fold into probs (v), so the int8
    codes feed the MXU directly and no dequantized cache is materialized.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst",
                        qg, k.astype(q.dtype)).astype(jnp.float32)
    if k_scale is not None:
        logits *= k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    logits *= hd ** -0.5
    if cfg.attn_softcap:
        logits = softcap(logits, cfg.attn_softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    probs = probs.astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(q.dtype))
    return out.reshape(b, s, h, hd)


def _sdpa_chunked(cfg: ModelConfig, q, k, v, *, causal: bool, window: int,
                  chunk: int = 1024):
    """Flash-style online-softmax attention: lax.scan over KV chunks, never
    materializing the (S, T) score matrix.  Pure-jnp twin of
    kernels/flash_attention (same math, XLA-visible memory savings on the
    dry-run; the Pallas kernel is the on-TPU fast path).  Selected via the
    logical rule ``attn=chunked`` (§Perf lever)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    ck = min(chunk, t)
    if t % ck:
        return None                                   # caller falls back
    nc = t // ck
    f32 = jnp.float32
    qg = q.reshape(b, s, kvh, g, hd).astype(f32) * hd ** -0.5
    kc = jnp.moveaxis(k.reshape(b, nc, ck, kvh, hd), 1, 0).astype(f32)
    vc = jnp.moveaxis(v.reshape(b, nc, ck, kvh, hd), 1, 0).astype(f32)
    qpos = jnp.arange(s)

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        logits = jnp.einsum("bskgd,bckd->bkgsc", qg, kj)
        if cfg.attn_softcap:
            logits = softcap(logits, cfg.attn_softcap)
        kpos = j * ck + jnp.arange(ck)
        mask = jnp.ones((s, ck), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgsc,bckd->bkgsd", p, vj)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, s), NEG_INF, f32)
    l0 = jnp.zeros((b, kvh, g, s), f32)
    a0 = jnp.zeros((b, kvh, g, s, hd), f32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    out = jnp.moveaxis(out, -2, 1).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def _attention(cfg: ModelConfig, q, k, v, mask, *, causal: bool, window: int):
    """Dispatch on the `attn` logical rule:
      chunked — lax.scan online-softmax (flash twin, §Perf A3)
      pallas  — the actual Pallas kernel (interpret off-TPU)
      default — straightforward masked sdpa (paper-faithful baseline)."""
    rules, _ = current_rules()
    impl = rules.get("attn") if rules is not None else None
    if impl == "chunked" and causal:
        out = _sdpa_chunked(cfg, q, k, v, causal=causal, window=window)
        if out is not None:
            return out
    if impl == "pallas" and causal:
        s, t = q.shape[1], k.shape[1]
        bq, bk = min(512, s), min(512, t)
        if s % bq == 0 and t % bk == 0:
            from repro.kernels.flash_attention import flash_attention
            return flash_attention(
                q, k, v, causal=True, window=window,
                softcap=cfg.attn_softcap, block_q=bq, block_k=bk,
                interpret=jax.default_backend() != "tpu")
    return _sdpa(cfg, q, k, v, mask)


def _causal_mask(s: int, t: int, q_offset, local_window: int):
    """(s,t) bool mask; q position i attends kv position j<=i (+window)."""
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    m = kpos[None, :] <= qpos[:, None]
    if local_window:
        m &= kpos[None, :] > qpos[:, None] - local_window
    return m


def attn_apply(p, cfg: ModelConfig, x, positions, *, local: bool = False,
               causal: bool = True, xkv=None, kv_positions=None):
    """Full-sequence attention (train / encoder / cross)."""
    xkv = x if xkv is None else xkv
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if cfg.use_rope and xkv is x:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    mask = None
    window = cfg.local_window if local else 0
    if causal:
        mask = _causal_mask(x.shape[1], xkv.shape[1], 0, window)
        mask = mask[None, None, None]                     # (1,1,1,S,T)
    out = _attention(cfg, q, k, v, mask, causal=causal, window=window)
    return out.reshape(*x.shape[:-1], -1) @ p["wo"]


# ---------------------------------------------------------------------------
# KV-cache paths
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  *, local: bool = False):
    hd = cfg.resolved_head_dim
    if local and cfg.kv_ring and cfg.local_window:
        max_len = min(max_len, cfg.local_window)
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x):
    """x: (..., hd) -> (int8 codes, per-row scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def attn_prefill(p, cfg: ModelConfig, x, positions, *, local: bool = False):
    """Like attn_apply but also returns the cache entry for decode."""
    q, k, v = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.local_window if local else 0
    mask = _causal_mask(x.shape[1], x.shape[1], 0, window)[None, None, None]
    out = _attention(cfg, q, k, v, mask, causal=True, window=window)
    y = out.reshape(*x.shape[:-1], -1) @ p["wo"]
    if cfg.kv_quant:
        k8, ks = _quantize_kv(k)
        v8, vs = _quantize_kv(v)
        return y, {"k": k8, "v": v8, "k_scale": ks, "v_scale": vs}
    return y, {"k": k, "v": v}


def attn_decode(p, cfg: ModelConfig, x, cache, pos, *, local: bool = False):
    """Single-token decode. x: (B,1,D); pos: (B,) int32; cache k/v (B,T,KV,hd).

    Returns (y, new_cache).  The KV write is a per-sequence dynamic scatter
    so ragged batches (continuous batching) are supported.  Supports int8
    caches (cfg.kv_quant) and ring-buffer local-window caches
    (cfg.kv_ring: cache length == window, writes at pos % window).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, x)                   # q: (B,1,H,hd)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    bi = jnp.arange(b)
    t = cache["k"].shape[1]
    ring = local and cfg.kv_ring and cfg.local_window and t == cfg.local_window
    wpos = pos % t if ring else pos
    new_cache = {}
    if cfg.kv_quant:
        k8, ks = _quantize_kv(k[:, 0])
        v8, vs = _quantize_kv(v[:, 0])
        ck = cache["k"].at[bi, wpos].set(k8)
        cv = cache["v"].at[bi, wpos].set(v8)
        cks = cache["k_scale"].at[bi, wpos].set(ks)
        cvs = cache["v_scale"].at[bi, wpos].set(vs)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        scales = {"k_scale": cks, "v_scale": cvs}
    else:
        ck = cache["k"].at[bi, wpos].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bi, wpos].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        scales = {}
    kpos = jnp.arange(t)[None, :]                          # (1,T)
    if ring:
        # every slot holds the latest position congruent to it (<= pos);
        # before the window fills, only slots <= pos are valid.  Stored k
        # carry their absolute-position RoPE, so order doesn't matter.
        mask = (kpos <= pos[:, None]) | (pos[:, None] >= t)
    else:
        mask = kpos <= pos[:, None]
        if local and cfg.local_window:
            mask &= kpos > (pos[:, None] - cfg.local_window)
    out = _sdpa(cfg, q, ck, cv, mask[:, None, None, None, :],
                k_scale=scales.get("k_scale"), v_scale=scales.get("v_scale"))
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, new_cache
