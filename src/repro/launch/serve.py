"""Serving launcher CLI — Metronome retrieval in front of the
continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --requests 20 --rate 40

Drives a Poisson request load and reports the paper's metrics (host CPU
fraction, TTFT, retrieval latency) for Metronome vs the busy-poll
baseline.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs
from repro.core import MetronomeConfig
from repro.models import Model
from repro.runtime import BusyPollPolicy, FixedPeriodPolicy, MetronomePolicy
from repro.serving import EngineConfig, InferenceEngine, Request, Server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--pollers", type=int, default=3)
    ap.add_argument("--v-target-us", type=float, default=3_000.0)
    ap.add_argument("--policy", default="metronome",
                    choices=("metronome", "busy-poll", "fixed-period"),
                    help="retrieval policy (repro.runtime)")
    ap.add_argument("--busy-poll", action="store_true",
                    help="deprecated alias for --policy busy-poll")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=args.max_len)
    engine = InferenceEngine(model, params,
                             EngineConfig(max_slots=args.slots,
                                          max_len=args.max_len,
                                          prefill_buckets=(8, 16)))
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    engine.submit([warm])
    engine.pump()

    if args.busy_poll and args.policy != "metronome":
        ap.error("--busy-poll (deprecated) conflicts with an explicit "
                 "--policy; pass --policy busy-poll instead")
    mode = "busy-poll" if args.busy_poll else args.policy
    if mode == "busy-poll":
        policy = BusyPollPolicy()
    elif mode == "fixed-period":
        policy = FixedPeriodPolicy(args.v_target_us, threads=1)
    else:
        policy = MetronomePolicy(
            MetronomeConfig(m=args.pollers, v_target_us=args.v_target_us,
                            t_long_us=args.v_target_us * 20))
    server = Server(engine, policy)
    server.start()
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(prompt=[(i % (cfg.vocab_size - 3)) + 1, 2, 3],
                    max_new_tokens=args.max_new)
        server.submit(r)
        reqs.append(r)
        time.sleep(rng.exponential(1.0 / args.rate))
    ok = all(r.wait(60.0) for r in reqs)
    stats = server.stop()
    ttft = np.median([(r.first_token_ns - r.arrival_ns) / 1e6 for r in reqs])
    print(f"arch={cfg.name} mode={mode} "
          f"completed={sum(len(r.tokens) == args.max_new for r in reqs)}/{len(reqs)} "
          f"cpu={stats.cpu_fraction:.3f} ttft_ms={ttft:.2f}")
    if mode == "metronome":
        ctrl = policy.controller
        print(f"controller: rho={ctrl.rho:.3f} T_S={ctrl.t_short_us:.0f}us "
              f"cycles={ctrl.cycles}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
