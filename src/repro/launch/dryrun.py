import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the ONLY place the 512 placeholder devices exist;
# tests/benches see the real single device.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.compat import cost_analysis_dict               # noqa: E402
from repro.configs.base import SHAPES, cells, get_config  # noqa: E402
from repro.launch.inputs import build_cell                # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.roofline.analysis import analyze_compiled, model_flops  # noqa: E402

"""Multi-pod dry-run (spec deliverable e).

For every (architecture x input shape) cell, lower + compile the step
function for the production mesh — single-pod 16x16 and multi-pod 2x16x16 —
and print memory_analysis() / cost_analysis() plus the parsed collective
schedule.  A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --json out.json
"""


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, extra: dict | None = None,
             probes: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, **(extra or {}))
    lowered = cell.lower()
    compiled = lowered.compile()
    dt = time.time() - t0

    import functools
    from repro.models import Model
    from repro.roofline.analysis import collective_bytes
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    params_sds = jax.eval_shape(
        functools.partial(Model(cfg).init,
                          max_seq=shape.seq_len if not cfg.use_rope else 4096),
        jax.random.PRNGKey(0))
    mf = model_flops(cfg, shape, params_sds)

    rep = analyze_compiled(arch, shape_name, mesh_name, compiled,
                           model_flops_global=mf,
                           n_devices=mesh.devices.size, compile_s=dt)

    if probes:
        # XLA's HloCostAnalysis counts while-loop bodies ONCE (not x trip
        # count), so the scan-over-layers module under-reports.  Compile
        # two scan-UNROLLED probes with k=1 and k=2 layer groups (full
        # width, same mesh/shapes) and extrapolate linearly:
        #   F(G) = F(1) + (G-1) * (F(2) - F(1))
        # — exact, since cost is affine in the group count.
        groups = cfg.n_layers // cfg.scan_unit()

        def probe(k):
            c = build_cell(arch, shape_name, mesh, probe_groups=k,
                           **(extra or {}))
            comp = c.lower().compile()
            ca = cost_analysis_dict(comp)
            coll = collective_bytes(comp.as_text())
            return (float(ca.get("flops", 0.0)),
                    float(ca.get("bytes accessed", 0.0)),
                    float(coll["total"]))

        f1, b1, c1 = probe(1)
        f2, b2, c2 = probe(2)
        rep.flops_per_dev = f1 + (groups - 1) * (f2 - f1)
        rep.bytes_per_dev = b1 + (groups - 1) * (b2 - b1)
        rep.coll_bytes_per_dev = c1 + (groups - 1) * (c2 - c1)
        from repro.roofline.analysis import roofline_terms
        rep.terms = roofline_terms(rep.flops_per_dev, rep.bytes_per_dev,
                                   rep.coll_bytes_per_dev)
    if verbose:
        print(f"== {arch} x {shape_name} @ {mesh_name} "
              f"(compile {dt:.1f}s) ==")
        print("   memory_analysis:", compiled.memory_analysis())
        ca = cost_analysis_dict(compiled)
        print(f"   cost_analysis: flops/dev={ca.get('flops', 0):.3e} "
              f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
        print(f"   collectives/dev: {rep.coll_detail}")
        t = rep.terms
        print(f"   roofline: compute={t['compute_s']:.4f}s "
              f"memory={t['memory_s']:.4f}s collective={t['collective_s']:.4f}s "
              f"-> dominant={t['dominant']} "
              f"fraction={t['roofline_fraction']:.3f} "
              f"useful_flops_ratio={rep.useful_flops_ratio:.3f}")
        sys.stdout.flush()
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", help="append JSONL reports here")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    metavar="LOGICAL=PHYSICAL",
                    help="logical-axis rule override for perf experiments, "
                         "e.g. --override seq=model (sequence parallelism)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (perf experiment B2)")
    ap.add_argument("--kv-ring", action="store_true",
                    help="ring-buffer local-window KV (perf experiment C1)")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override SSD chunk length (perf experiment D1)")
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v in ("", "none", "None") else v

    todo = []
    if args.all:
        todo = [(a, s) for a, s, skip in cells() if not skip]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    reports = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                extra = {"remat": not args.no_remat} \
                    if SHAPES[shape].kind == "train" else {}
                if overrides:
                    extra["rule_overrides"] = overrides
                cfg_ov = {}
                if args.kv_quant:
                    cfg_ov["kv_quant"] = True
                if args.kv_ring:
                    cfg_ov["kv_ring"] = True
                if args.ssm_chunk:
                    cfg_ov["ssm_chunk"] = args.ssm_chunk
                if cfg_ov:
                    extra["cfg_overrides"] = cfg_ov
                rep = run_cell(arch, shape, multi_pod=mp, extra=extra)
                reports.append(rep)
                if args.json:
                    with open(args.json, "a") as f:
                        row = rep.row()
                        row["coll_detail"] = {
                            k: v for k, v in rep.coll_detail.items()}
                        f.write(json.dumps(row) + "\n")
            except Exception:
                failures.append((arch, shape, mp))
                print(f"!! FAILED {arch} x {shape} multi_pod={mp}")
                traceback.print_exc()

    print(f"\n{len(reports)} cells compiled OK, {len(failures)} failed")
    for f in failures:
        print("  FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
