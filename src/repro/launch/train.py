"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --steps 100 --batch 8 --seq 256 --ckpt /tmp/run1        # resumable

Any --arch from the assigned pool works; --smoke uses the reduced config
(CPU-sized).  The loop is fault tolerant: rerunning the same command after
a crash resumes from the latest checkpoint and reproduces the
uninterrupted loss trajectory (deterministic data pipeline).
"""

from __future__ import annotations

import argparse

from repro.configs import get_config, list_configs
from repro.train import OptConfig, train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    res = train_loop(cfg, steps=args.steps, ckpt_dir=args.ckpt,
                     global_batch=args.batch, seq_len=args.seq,
                     save_every=args.save_every, remat=args.remat,
                     opt_cfg=OptConfig(lr=args.lr,
                                       moment_dtype=cfg.moment_dtype))
    print(f"arch={cfg.name} steps={res['final_step']} "
          f"resumed_from={res['resumed_from']} "
          f"loss {res['losses'][0]:.4f} -> {res['losses'][-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
