"""Production mesh factories (spec: single-pod 16x16, multi-pod 2x16x16).

Functions, not module-level constants — importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "fsdp_axes", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (batch/FSDP sharding)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh) -> tuple[str, ...]:
    return batch_axes(mesh)
