"""Cell builder: (architecture x input-shape x mesh) -> step fn + abstract
sharded inputs (ShapeDtypeStructs — no allocation; spec §Multi-pod dry-run).

``build_cell`` returns everything the dry-run (and the roofline harness)
needs to ``jax.jit(step).lower(*abstract_inputs).compile()``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.models import Model
from repro.sharding.logical import logical_axis_rules
from repro.sharding.policy import (
    cache_shardings,
    logical_rules,
    param_shardings,
)
from repro.train import OptConfig, init_opt, make_prefill_step, make_serve_step, make_train_step

__all__ = ["build_cell", "Cell", "input_specs"]

# Encoder context length for whisper decode cells (the self-attn KV is the
# graded seq_len; the cross-attention memory is one fixed audio window).
WHISPER_DECODE_ENC_LEN = 4096


@dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    step: Callable
    args: tuple                      # ShapeDtypeStructs with shardings
    donate: tuple = ()
    rules: dict = field(default_factory=dict)
    mesh: Any = None
    meta: dict = field(default_factory=dict)

    def lower(self):
        with logical_axis_rules(self.mesh, self.rules):
            return jax.jit(self.step, donate_argnums=self.donate).lower(*self.args)


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))


def _batch_axis(mesh, b: int):
    """Batch sharding axes, degraded to replication if b doesn't divide
    (long_500k has global_batch=1)."""
    from repro.launch.mesh import batch_axes
    bx = batch_axes(mesh)
    n = 1
    for a in bx:
        n *= mesh.shape[a]
    if not bx or b % n:
        return None
    return bx if len(bx) > 1 else bx[0]


def _with_shardings(tree_sds, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, shardings)


def _token_batch_sds(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                     labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    bax = _batch_axis(mesh, b)
    extra = cfg.frontend_len if cfg.frontend == "vision_stub" else 0
    batch = {"tokens": _sds((b, s - extra), jnp.int32, mesh, P(bax))}
    if labels:
        batch["labels"] = _sds((b, s - extra), jnp.int32, mesh, P(bax))
    if extra:
        batch["prefix_embeds"] = _sds((b, extra, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype), mesh,
                                      P(bax, None, None))
    if cfg.is_encdec:
        batch["enc_frames"] = _sds((b, s, cfg.d_model),
                                   jnp.dtype(cfg.compute_dtype), mesh,
                                   P(bax, None, None))
    return batch


def input_specs(arch: str, shape_name: str, mesh):
    """Abstract inputs for the cell (spec step 2). Returns (step, args)."""
    cell = build_cell(arch, shape_name, mesh)
    return cell.step, cell.args


def build_cell(arch: str, shape_name: str, mesh, *,
               remat: bool = True, probe_groups: int | None = None,
               rule_overrides: dict | None = None,
               cfg_overrides: dict | None = None) -> Cell:
    """probe_groups=k builds a k-group, scan-unrolled variant of the arch
    (same width/shape) whose HLO is loop-free — the roofline probes.
    cfg_overrides: dataclasses.replace fields (e.g. kv_quant=True)."""
    import dataclasses
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if probe_groups is not None:
        unit = cfg.scan_unit()
        groups = cfg.n_layers // unit
        enc_ratio = cfg.n_encoder_layers // groups if cfg.is_encdec else 0
        cfg = dataclasses.replace(
            cfg, n_layers=unit * probe_groups,
            n_encoder_layers=enc_ratio * probe_groups)
    model = Model(cfg, unroll=probe_groups is not None)
    mode = shape.kind
    rules = logical_rules(mesh, mode, overrides=rule_overrides)

    max_seq = shape.seq_len if not cfg.use_rope else 4096
    params_sds = jax.eval_shape(
        functools.partial(model.init, max_seq=max_seq), jax.random.PRNGKey(0))
    p_shard = param_shardings(cfg, params_sds, mesh,
                              "train" if mode == "train" else "serve")
    params_in = _with_shardings(params_sds, p_shard)

    if mode == "train":
        opt_cfg = OptConfig(moment_dtype=cfg.moment_dtype)
        opt_sds = jax.eval_shape(functools.partial(init_opt, cfg=opt_cfg),
                                 params_sds)
        opt_shard = {
            "m": jax.tree.map(lambda s, sh: sh, opt_sds["m"], p_shard),
            "v": jax.tree.map(lambda s, sh: sh, opt_sds["v"], p_shard),
            "count": NamedSharding(mesh, P()),
        }
        opt_in = _with_shardings(opt_sds, opt_shard)
        batch = _token_batch_sds(cfg, shape, mesh, labels=True)
        step = make_train_step(model, opt_cfg, remat=remat)
        return Cell(arch, shape_name, cfg, step,
                    (params_in, opt_in, batch), donate=(0, 1),
                    rules=rules, mesh=mesh,
                    meta={"mode": mode, "opt": opt_cfg})

    if mode == "prefill":
        batch = _token_batch_sds(cfg, shape, mesh, labels=False)
        step = make_prefill_step(model)
        return Cell(arch, shape_name, cfg, step, (params_in, batch),
                    rules=rules, mesh=mesh, meta={"mode": mode})

    # decode: one new token against a KV cache of seq_len
    b, s = shape.global_batch, shape.seq_len
    cache_sds = jax.eval_shape(
        functools.partial(model.init_cache, b, s))
    if cfg.is_encdec:
        hd = cfg.resolved_head_dim
        groups = cfg.n_layers // cfg.scan_unit()
        cross = {"k": jax.ShapeDtypeStruct(
                    (groups, b, WHISPER_DECODE_ENC_LEN, cfg.n_heads, hd),
                    jnp.dtype(cfg.compute_dtype)),
                 "v": jax.ShapeDtypeStruct(
                    (groups, b, WHISPER_DECODE_ENC_LEN, cfg.n_heads, hd),
                    jnp.dtype(cfg.compute_dtype))}
        cache_sds = {"self": cache_sds, "cross": cross}
    cache_in = _with_shardings(cache_sds, cache_shardings(cache_sds, mesh))
    bax = _batch_axis(mesh, b)
    tokens = _sds((b,), jnp.int32, mesh, P(bax))
    pos = _sds((b,), jnp.int32, mesh, P(bax))
    step = make_serve_step(model)
    return Cell(arch, shape_name, cfg, step,
                (params_in, tokens, cache_in, pos), donate=(2,),
                rules=rules, mesh=mesh, meta={"mode": "decode"})
