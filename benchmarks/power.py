"""Power & energy verdicts: the paper's CPU story, priced in joules.

Metronome's pitch translated to energy: a sleep&wake poller's package
power tracks the offered load (cores are awake roughly in proportion to
rho, and between bursts they sit in a C-state), while a busy-poll core
burns its dvfs-pinned active power flat — so busy-poll's energy *per
packet* explodes exactly where Metronome's stays put: at low load.
This suite measures two claims under ``DEEP_CSTATE_ENERGY_MODEL`` (the
aggressive-deep-idle part where the effects are visible):

  - ``power/rho<r>/energy_per_packet_nj``  metronome nJ/packet at each
    load on a ladder, with busy-poll's nJ/packet and their ratio in the
    derived fields.  Verdict inputs: busy-poll inflates >= 5x from the
    high- to the low-load rung while metronome stays within 2.5x
    (roughly flat), and busy-poll costs >= 5x metronome at low load;
  - ``objective/rho<r>/ts_shift_us``       the energy-optimal table's
    T_S minus the CPU-optimal table's, both distilled by
    ``build_operating_table`` from ONE batched sweep whose T_S grid
    straddles the model's 40us deep-state residency floor, under a
    latency target that *binds* below that floor.  Verdict input: the
    two tables pick genuinely different operating points — the CPU
    argmin always stretches T_S to the feasible maximum (its cost is
    monotone in the wake rate m/T_S), while the energy argmin prices
    the C-state residency the governor charges per armed target and
    lands elsewhere (here: a shorter T_S in the same shallow band plus
    the deep-state T_L), spending strictly less energy at the same
    latency target — the latency/power frontier genuinely differs from
    the latency/CPU one;
  - ``verdict/ok``                          all of the above.

CLI: ``python -m benchmarks.power [--smoke]`` — ``--smoke`` runs the
quick ladder and exits nonzero on a failed verdict (the CI job).
"""

from __future__ import annotations

import sys

import numpy as np

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
RHOS = (0.1, 0.3, 0.5, 0.7)
LOW_RHO, HIGH_RHO = RHOS[0], RHOS[-1]
# verdict floors/ceilings
MIN_BUSY_INFLATION = 5.0    # busy nJ/pkt, low vs high load
MAX_MET_INFLATION = 2.5     # metronome stays within this (roughly flat)
MIN_LOW_LOAD_RATIO = 5.0    # busy vs metronome nJ/pkt at the low rung
# ladder operating point: both timeouts sit past the deep model's
# residency floors (T_S >= 40us, T_L >= 400us), so an idle metronome
# core actually reaches the cheap states the model offers
LADDER_T_S_US, LADDER_T_L_US, LADDER_M = 60.0, 600.0, 2
# objective-divergence sweep: T_S straddles the deep model's 40us
# residency floor, and the latency target binds BELOW it (T_S >= 48
# measures ~22us+), so the two objectives must rank the shallow-band
# points — where their costs genuinely disagree
OBJ_T_S_GRID = (24.0, 36.0, 48.0, 60.0)
OBJ_T_L_GRID = (300.0, 600.0)
OBJ_M_GRID = (2, 3)
OBJ_RHOS = (0.2, 0.3)
OBJ_TARGET_LAT_US = 21.0
OBJ_MAX_LOSS = 1e-2


def _ladder(quick: bool) -> ROWS:
    from repro.runtime import (
        DEEP_CSTATE_ENERGY_MODEL,
        BusyPollPolicy,
        PoissonWorkload,
        SimRunConfig,
        SweepGrid,
        simulate_batch,
        simulate_run,
    )
    from repro.runtime.simcore import HR_SLEEP_MODEL

    em = DEEP_CSTATE_ENERGY_MODEL
    n_seeds = 4 if quick else 16
    duration = 30_000.0 if quick else 120_000.0
    cfg = SimRunConfig(duration_us=duration, sleep_model=HR_SLEEP_MODEL,
                       energy_model=em)
    pts = [dict(t_s_us=LADDER_T_S_US, t_l_us=LADDER_T_L_US, m=LADDER_M,
                n_queues=1, rate_mpps=rho * MU_MPPS, seed=s)
           for rho in RHOS for s in range(n_seeds)]
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5)
    met_nj = bs.energy_per_packet_nj.reshape(len(RHOS), n_seeds).mean(axis=1)
    met_w = bs.mean_power_w.reshape(len(RHOS), n_seeds).mean(axis=1)

    rows: ROWS = []
    busy_nj = np.empty(len(RHOS))
    for k, rho in enumerate(RHOS):
        rs = simulate_run(BusyPollPolicy(),
                          PoissonWorkload(rho * MU_MPPS), cfg)
        busy_nj[k] = rs.energy_per_packet_nj
        rows.append((
            f"power/rho{rho:.2f}/energy_per_packet_nj", float(met_nj[k]),
            f"busy_poll_nj={busy_nj[k]:.1f};"
            f"ratio={busy_nj[k] / met_nj[k]:.2f};"
            f"metronome_w={met_w[k]:.2f};"
            f"busy_poll_w={rs.mean_power_w:.2f};"
            f"t_s_us={LADDER_T_S_US:g};t_l_us={LADDER_T_L_US:g};"
            f"m={LADDER_M};seeds={n_seeds}"))

    busy_infl = float(busy_nj[0] / busy_nj[-1])
    met_infl = float(met_nj.max() / met_nj.min())
    low_ratio = float(busy_nj[0] / met_nj[0])
    ok = (busy_infl >= MIN_BUSY_INFLATION
          and met_infl <= MAX_MET_INFLATION
          and low_ratio >= MIN_LOW_LOAD_RATIO)
    rows.append((
        "power/low_load_inflation", busy_infl,
        f"busy_nj_low_over_high={busy_infl:.2f};"
        f"metronome_nj_spread={met_infl:.2f};"
        f"busy_over_metronome_at_rho{LOW_RHO:g}={low_ratio:.2f};"
        f"floors={MIN_BUSY_INFLATION:g}x_busy_"
        f"{MAX_MET_INFLATION:g}x_met_{MIN_LOW_LOAD_RATIO:g}x_ratio;"
        f"in_band={ok}"))
    return rows, ok


def _objective_divergence(quick: bool) -> ROWS:
    from repro.runtime import (
        DEEP_CSTATE_ENERGY_MODEL,
        SimRunConfig,
        SweepGrid,
        build_operating_table,
        simulate_batch,
    )
    from repro.runtime.simcore import HR_SLEEP_MODEL

    rhos = np.asarray(OBJ_RHOS)
    seeds = (0,) if quick else (0, 1)
    cfg = SimRunConfig(duration_us=30_000.0 if quick else 60_000.0,
                       sleep_model=HR_SLEEP_MODEL,
                       energy_model=DEEP_CSTATE_ENERGY_MODEL)
    grid = SweepGrid.product(t_s_us=OBJ_T_S_GRID, t_l_us=OBJ_T_L_GRID,
                             m=OBJ_M_GRID, rate_mpps=rhos * MU_MPPS,
                             seeds=seeds)
    bs = simulate_batch(grid, cfg, slot_us=0.5)
    # guard off (rel=5): we want the argmins over the RAW measured
    # lattice — feasibility is still enforced through measured latency
    # and loss, which is what the verdict is about
    tables = {
        obj: build_operating_table(
            rhos=rhos, target_mean_latency_us=OBJ_TARGET_LAT_US,
            t_s_grid=OBJ_T_S_GRID, t_l_grid=OBJ_T_L_GRID,
            m_grid=OBJ_M_GRID, cfg=cfg, seeds=seeds, slot_us=0.5,
            max_loss=OBJ_MAX_LOSS, analytic_guard_rel=5.0, sweep=bs,
            objective=obj)
        for obj in ("cpu", "energy")
    }

    rows: ROWS = []
    diverged = strictly_cheaper = False
    never_worse = True
    for pc, pe in zip(tables["cpu"].points, tables["energy"].points):
        point_differs = (pe.t_s_us, pe.t_l_us, pe.m) \
            != (pc.t_s_us, pc.t_l_us, pc.m)
        diverged = diverged or point_differs
        strictly_cheaper = strictly_cheaper or (
            point_differs and pe.energy_uj < pc.energy_uj)
        never_worse = never_worse and pe.energy_uj <= pc.energy_uj + 1e-6
        rows.append((
            f"objective/rho{pc.rho:.2f}/ts_shift_us",
            float(pe.t_s_us - pc.t_s_us),
            f"cpu_pick=ts{pc.t_s_us:g}_tl{pc.t_l_us:g}_m{pc.m};"
            f"energy_pick=ts{pe.t_s_us:g}_tl{pe.t_l_us:g}_m{pe.m};"
            f"cpu_obj_energy_uj={pc.energy_uj:.0f};"
            f"energy_obj_energy_uj={pe.energy_uj:.0f};"
            f"cpu_obj_cores={pc.cpu_fraction:.4f};"
            f"energy_obj_cores={pe.cpu_fraction:.4f};"
            f"both_meet_target={pc.meets_target and pe.meets_target}"))
    feasible = (all(p.meets_target for p in tables["cpu"].points)
                and all(p.meets_target for p in tables["energy"].points))
    ok = diverged and strictly_cheaper and never_worse and feasible
    rows.append((
        "objective/diverges", float(diverged),
        f"tables_pick_different_points={diverged};"
        f"energy_table_strictly_cheaper_somewhere={strictly_cheaper};"
        f"energy_table_never_costlier={never_worse};"
        f"all_points_feasible={feasible};in_band={ok}"))
    return rows, ok


def power(quick: bool = False) -> ROWS:
    ladder_rows, ladder_ok = _ladder(quick)
    obj_rows, obj_ok = _objective_divergence(quick)
    verdict = ladder_ok and obj_ok
    rows = ladder_rows + obj_rows
    rows.append(("verdict/ok", float(verdict), f"ok={verdict}"))
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = power(quick=quick)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows if n == "verdict/ok")
        if not ok:
            print("SMOKE FAILED: busy-poll energy/packet did not inflate "
                  "at low load, or the energy-objective table stopped "
                  "diverging from the CPU-optimal one under deep "
                  "C-states", file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
