"""CPU-sharing matrix: co-run application load vs retrieval policy at an
equal one-core budget — the paper's Sec 5.6 claim, reproduced.

Metronome's second headline result is that sleep&wake retrieval shares
its core with CPU-intensive applications: the I/O task uses ~rho of a
core and the application gets the rest, while DPDK-style busy polling
needs the whole core whether or not packets arrive — co-locating an app
with a spinner means the scheduler *takes* timeslices from it, and the
ring overflows while the spinner is off-CPU.

Grid: app CPU demand (fraction of the shared core) x policy (adaptive
metronome / busy-poll) x sleep primitive (hr_sleep / nanosleep timer
models).  Each cell runs the exact event engine in the contention
environment derived by ``repro.runtime.apps.co_run_config``:

  - metronome cells: every wake lands on a busy core w.p. ~demand and
    pays a wakeup-preemption delay; rare non-preemptible pile-ups add
    correlated stall windows;
  - busy-poll cells: CFS alternates the always-runnable spinner with
    the app in quantum-length timeslices (the app's fair share against
    a spinner caps at half the core), and the spin fluid model serves
    nothing during those descheduled windows.  The spinner's cadence
    has no sleeps, so the sleep-primitive axis collapses to one
    ``any`` row per demand (same convention as rss_skew's baseline).

Rows (suite convention ``name,value,derived`` — value is p99 us):
  - ``share/<sleep>/d<demand>/metronome``  per-cell latency/CPU/loss,
    plus ``app_share`` — the core fraction actually left for the app
    (min(demand, 1 - io_cpu); for busy-poll min(demand, 0.5): what CFS
    can wrestle from a spinner);
  - ``share/any/d<demand>/busy-poll``
  - ``verdict/...``  the claim under test: as demand rises to its max,
    metronome's mean/p99 degrade *gracefully* (bounded multiples of the
    quiet-host cell, loss still ~0) while busy-poll *collapses* (ring
    overflow loss and orders-of-magnitude mean inflation);
  - with ``--threads``, extra ``threads/...`` demo rows co-run a real
    ``DutyCycleBurner`` against real pollers via ``Runtime`` (not part
    of the verdict: wall-clock scheduling on a shared CI host is not
    deterministic).

CLI: ``python -m benchmarks.cpu_sharing [--smoke] [--threads]`` —
``--smoke`` runs the reduced grid and exits nonzero on a failed verdict
(the CI job).
"""

from __future__ import annotations

import sys

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
RHO = 0.45                   # offered I/O load on the shared core
RING = 4096                  # Rx descriptors (paper Table 3 scale)
# graceful-degradation bounds for metronome at max demand vs quiet host
GRACE_MEAN_X = 2.5
GRACE_P99_X = 4.0
GRACE_MAX_LOSS = 0.01
# collapse thresholds for busy-poll at max demand
COLLAPSE_LOSS = 0.02
COLLAPSE_MEAN_X = 20.0


def _simulate_cells(demands, duration_us: float) -> dict:
    from repro.core import MetronomeConfig
    from repro.runtime import (
        BusyPollPolicy,
        MetronomePolicy,
        PoissonWorkload,
        SimRunConfig,
        co_run_config,
        simulate_run,
    )
    from repro.runtime.simcore import HR_SLEEP_MODEL, NANOSLEEP_MODEL

    sleeps = [("hr_sleep", HR_SLEEP_MODEL), ("nanosleep", NANOSLEEP_MODEL)]
    cells: dict = {}
    for sname, sm in sleeps:
        for d in demands:
            cfg = SimRunConfig(duration_us=duration_us,
                               queue_capacity=RING, sleep_model=sm)
            rs = simulate_run(
                MetronomePolicy(MetronomeConfig()),
                PoissonWorkload(RHO * MU_MPPS),
                co_run_config(cfg, d))
            cells[(sname, d, "metronome")] = rs
    for d in demands:
        cfg = SimRunConfig(duration_us=duration_us, queue_capacity=RING)
        cells[("any", d, "busy-poll")] = simulate_run(
            BusyPollPolicy(), PoissonWorkload(RHO * MU_MPPS),
            co_run_config(cfg, d, spin=True))
    return cells


def _thread_demo_rows(duration_s: float = 0.4) -> ROWS:
    """Real OS threads: pollers + a DutyCycleBurner on the live host.
    Reported for inspection only — host scheduling is not deterministic
    enough to gate a verdict on."""
    import time

    from repro.core import MetronomeConfig
    from repro.runtime import (
        BoundedQueue,
        DutyCycleBurner,
        MetronomePolicy,
        Runtime,
    )

    rows: ROWS = []
    for demand in (0.0, 0.5):
        q = [BoundedQueue(RING)]
        app = (DutyCycleBurner(demand=demand, period_us=1_000.0)
               if demand else None)
        rt = Runtime(q, process=lambda items: None,
                     policy=MetronomePolicy(MetronomeConfig(
                         m=2, v_target_us=500.0, t_long_us=5_000.0)),
                     app_load=app)
        rt.start()
        t_end = time.monotonic() + duration_s
        i = 0
        while time.monotonic() < t_end:
            q[0].push(i)
            i += 1
            time.sleep(0.001)
        st = rt.stop()
        rows.append((
            f"threads/co_run/d{demand:g}/metronome", st.p99_latency_us,
            f"io_cpu={st.cpu_fraction:.3f};app_ops={st.app_ops};"
            f"app_cpu={st.app_cpu_fraction:.3f};items={st.items}"))
    return rows


def cpu_sharing(quick: bool = False, threads: bool = False) -> ROWS:
    demands = [0.0, 0.4, 0.8] if quick else [0.0, 0.2, 0.4, 0.6, 0.8]
    duration = 40_000.0 if quick else 120_000.0
    d_max = demands[-1]
    cells = _simulate_cells(demands, duration)

    rows: ROWS = []
    for (sname, d, pol), rs in cells.items():
        if pol == "metronome":
            app_share = min(d, max(1.0 - rs.cpu_fraction, 0.0))
        else:
            app_share = min(d, 0.5)
        rows.append((
            f"share/{sname}/d{d:g}/{pol}", rs.p99_latency_us,
            f"mean_lat_us={rs.mean_latency_us:.2f};"
            f"cpu={rs.cpu_fraction:.3f};"
            f"loss_pct={rs.loss_fraction * 100:.3f};"
            f"app_share={app_share:.2f}"))

    # verdict: graceful metronome on BOTH sleep primitives, collapsing
    # busy-poll, at the same offered load and core budget
    graceful = True
    detail = []
    for sname in ("hr_sleep", "nanosleep"):
        q0 = cells[(sname, 0.0, "metronome")]
        qd = cells[(sname, d_max, "metronome")]
        ok = (qd.mean_latency_us <= GRACE_MEAN_X * q0.mean_latency_us
              and qd.p99_latency_us <= GRACE_P99_X * q0.p99_latency_us
              and qd.loss_fraction <= GRACE_MAX_LOSS)
        graceful = graceful and ok
        detail.append(
            f"{sname}_mean_x={qd.mean_latency_us / q0.mean_latency_us:.2f};"
            f"{sname}_p99_x={qd.p99_latency_us / q0.p99_latency_us:.2f};"
            f"{sname}_loss_pct={qd.loss_fraction * 100:.3f}")
    b0 = cells[("any", 0.0, "busy-poll")]
    bd = cells[("any", d_max, "busy-poll")]
    mean_x = bd.mean_latency_us / max(b0.mean_latency_us, 1e-9)
    collapsed = (bd.loss_fraction > COLLAPSE_LOSS
                 or mean_x > COLLAPSE_MEAN_X)
    detail.append(f"busypoll_mean_x={mean_x:.0f};"
                  f"busypoll_loss_pct={bd.loss_fraction * 100:.2f}")
    verdict_ok = graceful and collapsed
    rows.append((
        "verdict/metronome_graceful_busypoll_collapse",
        float(bd.loss_fraction - cells[("hr_sleep", d_max,
                                        "metronome")].loss_fraction),
        f"metronome_graceful={graceful};busypoll_collapsed={collapsed};"
        f"d_max={d_max:g};" + ";".join(detail)))
    rows.append(("verdict/ok", float(verdict_ok), f"ok={verdict_ok}"))

    if threads:
        rows.extend(_thread_demo_rows())
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = cpu_sharing(quick=quick, threads="--threads" in sys.argv)
    print("name,p99_us,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows if n == "verdict/ok")
        if not ok:
            print("SMOKE FAILED: metronome did not degrade gracefully "
                  "and/or busy-poll did not collapse under co-run load",
                  file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
