"""Benchmarks reproducing the paper's tables/figures (simulation + host
measurements), expressed through the ``repro.runtime`` policy/workload
API.  Each function returns a list of (name, us_per_call, derived) rows
for benchmarks/run.py's CSV contract.

Mapping (paper -> function):
  Table 1   sleep precision              -> table1_sleep_precision
  Fig 2     CPU/energy of sleep loops    -> fig2_sleep_cpu
  Fig 5     vacation PDF vs Eq 9         -> fig5_vacation_pdf
  Table 2 / Fig 6   V-bar tuning         -> table2_vbar_tuning
  Fig 7/8/9 T_L and M tuning             -> fig7_tl_sweep / fig8_m_sweep
  Table 3   nanosleep loss               -> table3_nanosleep_loss
  Fig 11    adaptation to varying load   -> fig11_adaptation
  Fig 12    Metronome vs DPDK            -> fig12_dpdk_compare
  Fig 14/15 applications + co-existence  -> fig15_applications (serving)
"""

from __future__ import annotations

import resource
import threading
import time

import numpy as np

from repro.core import MetronomeConfig, hr_sleep, measure_precision, naive_sleep
from repro.core.analytics import vacation_pdf_high
from repro.runtime import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    BusyPollPolicy,
    EqualTimeoutsPolicy,
    MetronomePolicy,
    PoissonWorkload,
    SimRunConfig,
    simulate_run,
)

ROWS = list[tuple[str, float, str]]

LINE_RATE_MPPS = 14.88     # 10GbE, 64B frames
MU_MPPS = 29.76


def _metronome(m=3, v_target_us=10.0, t_long_us=500.0, **kw) -> MetronomePolicy:
    return MetronomePolicy(MetronomeConfig(m=m, v_target_us=v_target_us,
                                           t_long_us=t_long_us), **kw)


def table1_sleep_precision(quick: bool = False) -> ROWS:
    """Paper Table 1: achieved sleep (mean/p99) for target sweep, on this
    host: naive time.sleep (the nanosleep arm) vs hybrid hr_sleep."""
    targets = [1_000, 5_000, 10_000, 50_000, 100_000, 200_000]
    n = 60 if quick else 200
    rows = []
    for fn, label in ((naive_sleep, "nanosleep"), (hr_sleep, "hr_sleep")):
        res = measure_precision(fn, targets, samples=n)
        for tgt, (mean, p99) in res.items():
            rows.append((f"table1/{label}/target_{tgt // 1000}us",
                         mean / 1e3,
                         f"p99_us={p99 / 1e3:.2f};overshoot_us={(mean - tgt) / 1e3:.2f}"))
    return rows


def fig2_sleep_cpu(quick: bool = False) -> ROWS:
    """Paper Fig 2: process CPU time for M threads running a sleep loop
    (no traffic).  Energy proxy = CPU time (RAPL unavailable; DESIGN.md)."""
    iters = 2_000 if quick else 10_000
    rows = []
    for label, fn in (("nanosleep", naive_sleep), ("hr_sleep", hr_sleep)):
        for period_ns in (20_000, 100_000):
            for m in (1, 3):
                def worker():
                    for _ in range(iters // m):
                        fn(period_ns)
                t0c = resource.getrusage(resource.RUSAGE_SELF)
                t0 = time.monotonic()
                ts = [threading.Thread(target=worker) for _ in range(m)]
                [t.start() for t in ts]
                [t.join() for t in ts]
                t1c = resource.getrusage(resource.RUSAGE_SELF)
                dt = time.monotonic() - t0
                cpu = (t1c.ru_utime + t1c.ru_stime) - (t0c.ru_utime + t0c.ru_stime)
                rows.append((f"fig2/{label}/p{period_ns // 1000}us/m{m}",
                             cpu / iters * 1e6,
                             f"cpu_s={cpu:.3f};wall_s={dt:.3f}"))
    return rows


def fig5_vacation_pdf(quick: bool = False) -> ROWS:
    """Paper Fig 5: decorrelation — empirical vacation PDF vs Eq 9."""
    rows = []
    dur = 300_000.0 if quick else 900_000.0
    for m in (2, 3, 5):
        ts = 50.0
        policy = EqualTimeoutsPolicy(MetronomeConfig(m=m, v_target_us=ts))
        res = simulate_run(policy, PoissonWorkload(LINE_RATE_MPPS),
                           SimRunConfig(duration_us=dur, seed=5))
        v = res.vacations_us
        v = v[(v > 0) & (v < ts)]
        hist, edges = np.histogram(v, bins=20, range=(0, ts), density=True)
        centers = 0.5 * (edges[:-1] + edges[1:])
        pdf = vacation_pdf_high(centers, ts, ts, m)
        err = float(np.median(np.abs(hist - pdf) / pdf.max()))
        rows.append((f"fig5/pdf_vs_eq9/m{m}", res.mean_vacation_us,
                     f"median_rel_err={err:.3f};n={v.size}"))
    return rows


def table2_vbar_tuning(quick: bool = False) -> ROWS:
    """Paper Table 2 + Fig 6: V-bar sweep at line rate."""
    rows = []
    dur = 200_000.0 if quick else 1_000_000.0
    for v in (5.0, 10.0, 12.0, 15.0, 20.0):
        r = simulate_run(_metronome(v_target_us=v),
                         PoissonWorkload(LINE_RATE_MPPS),
                         SimRunConfig(duration_us=dur, seed=2))
        rows.append((f"table2/vbar_{v:g}us", r.mean_vacation_us,
                     f"B_us={r.mean_busy_us:.2f};N_V={r.mean_nv:.1f};"
                     f"loss_permille={r.loss_fraction * 1e3:.3f};"
                     f"cpu={r.cpu_fraction:.3f};"
                     f"lat_mean_us={r.mean_latency_us:.2f}"))
    return rows


def fig7_tl_sweep(quick: bool = False) -> ROWS:
    """Paper Fig 7: busy tries & CPU vs T_L."""
    rows = []
    dur = 200_000.0 if quick else 600_000.0
    for tl in (100.0, 300.0, 500.0, 700.0):
        r = simulate_run(_metronome(t_long_us=tl),
                         PoissonWorkload(LINE_RATE_MPPS),
                         SimRunConfig(duration_us=dur, seed=3))
        rows.append((f"fig7/tl_{tl:g}us", tl,
                     f"busy_tries_pct={100 * r.busy_tries / max(r.wakeups, 1):.2f};"
                     f"cpu={r.cpu_fraction:.3f}"))
    return rows


def fig8_m_sweep(quick: bool = False) -> ROWS:
    """Paper Fig 8/9: busy tries, CPU, latency vs thread count M."""
    rows = []
    dur = 200_000.0 if quick else 600_000.0
    for m in (2, 3, 4, 5, 6):
        r = simulate_run(_metronome(m=m), PoissonWorkload(LINE_RATE_MPPS),
                         SimRunConfig(duration_us=dur, seed=4))
        rows.append((f"fig8/m_{m}", r.mean_latency_us,
                     f"busy_tries_pct={100 * r.busy_tries / max(r.wakeups, 1):.2f};"
                     f"cpu={r.cpu_fraction:.3f};p99_lat_us={r.p99_latency_us:.2f}"))
    return rows


def table3_nanosleep_loss(quick: bool = False) -> ROWS:
    """Paper Table 3: Metronome-on-nanosleep loses packets at line rate.

    The nanosleep arm carries correlated preemption stalls in addition to
    its affine overshoot: the paper's own mechanism story (Sec 3.1 — the
    preamble is preemptable and timer handling heavy, so delays pile up
    across threads at once).  hr_sleep avoids that path by design, hence
    no stalls on its arm — matching the paper's zero-loss measurement.
    """
    rows = []
    dur = 300_000.0 if quick else 1_500_000.0
    cases = [(1024, 10.0), (2048, 10.0), (4096, 10.0), (4096, 1.0)]
    for qsize, vbar in cases:
        wl = PoissonWorkload(LINE_RATE_MPPS)
        r = simulate_run(_metronome(v_target_us=vbar), wl,
                         SimRunConfig(duration_us=dur, queue_capacity=qsize,
                                      sleep_model=NANOSLEEP_MODEL,
                                      stall_rate_per_us=3.5e-5,
                                      stall_mean_us=1_200.0, seed=6))
        hr = simulate_run(_metronome(v_target_us=vbar), wl,
                          SimRunConfig(duration_us=dur, queue_capacity=qsize,
                                       sleep_model=HR_SLEEP_MODEL, seed=6))
        rows.append((f"table3/q{qsize}_vbar{vbar:g}us",
                     r.loss_fraction * 100,
                     f"nanosleep_loss_pct={r.loss_fraction * 100:.3f};"
                     f"hr_sleep_loss_pct={hr.loss_fraction * 100:.4f}"))
    return rows


def fig11_adaptation(quick: bool = False) -> ROWS:
    """Paper Fig 11: rho/T_S track a ramp-up/ramp-down load profile."""
    dur = 300_000.0 if quick else 1_200_000.0
    peak = 14.0

    def profile(t):
        x = t / dur
        return peak * (2 * x if x < 0.5 else 2 * (1 - x))

    r = simulate_run(_metronome(),
                     PoissonWorkload(peak, profile=profile),
                     SimRunConfig(duration_us=dur, timeseries_bin_us=dur / 30,
                                  seed=8))
    # tracking error between estimated rho and true instantaneous rho
    t_mid = r.series_t_us + (dur / 30) / 2
    true_rho = np.array([profile(t) for t in t_mid]) / MU_MPPS
    err = float(np.mean(np.abs(r.rho_series[2:-2] - true_rho[2:-2])))
    served_frac = r.serviced / max(r.offered - r.dropped, 1)
    return [("fig11/adaptation", err,
             f"rho_track_mae={err:.3f};throughput_match={served_frac:.4f};"
             f"ts_range_us={r.ts_series.min():.1f}-{r.ts_series.max():.1f}")]


def fig12_dpdk_compare(quick: bool = False) -> ROWS:
    """Paper Fig 12: CPU + latency, Metronome vs continuous-poll DPDK."""
    rows = []
    dur = 200_000.0 if quick else 800_000.0
    for gbps, lam in ((0.5, 0.744), (1.0, 1.488), (5.0, 7.44), (10.0, 14.88)):
        met = simulate_run(_metronome(), PoissonWorkload(lam),
                           SimRunConfig(duration_us=dur, seed=9))
        dpdk = simulate_run(BusyPollPolicy(), PoissonWorkload(lam),
                            SimRunConfig(duration_us=dur, seed=9))
        rows.append((f"fig12/rate_{gbps:g}gbps", met.mean_latency_us,
                     f"met_cpu={met.cpu_fraction:.3f};dpdk_cpu=1.000;"
                     f"met_lat_us={met.mean_latency_us:.2f};"
                     f"dpdk_lat_us={dpdk.mean_latency_us:.2f};"
                     f"met_loss={met.loss_fraction:.2e}"))
    return rows


def fig15_applications(quick: bool = False) -> ROWS:
    """Paper Fig 14/15 analogue on the real serving stack: token service
    CPU usage, Metronome retrieval vs busy-poll, at two request rates."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import EngineConfig, InferenceEngine, Request, Server

    tiny = dataclasses.replace(
        get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=101)

    def drive(policy, rate_hz, n_req):
        model = Model(tiny)
        params = model.init(jax.random.PRNGKey(0), max_seq=64)
        eng = InferenceEngine(model, params,
                              EngineConfig(max_slots=4, max_len=64,
                                           prefill_buckets=(8,)))
        warm = Request(prompt=[1, 2], max_new_tokens=2)
        eng.submit([warm]); eng.pump()
        srv = Server(eng, policy)
        srv.start()
        reqs = []
        for i in range(n_req):
            r = Request(prompt=[(i % 90) + 1, (i % 90) + 2], max_new_tokens=4)
            srv.submit(r); reqs.append(r)
            time.sleep(1.0 / rate_hz)
        ok = all(r.wait(timeout=30.0) for r in reqs)
        st = srv.stop()
        lat = (np.median([r.first_token_ns - r.arrival_ns for r in reqs]) / 1e3
               if reqs else 0.0)
        return st, ok, lat

    rows = []
    n = 8 if quick else 24
    for rate in (20.0, 60.0):
        m_st, m_ok, m_lat = drive(
            MetronomePolicy(MetronomeConfig(m=3, v_target_us=3_000.0,
                                            t_long_us=60_000.0)), rate, n)
        b_st, b_ok, b_lat = drive(BusyPollPolicy(), rate, n)
        assert m_ok and b_ok
        rows.append((f"fig15/token_service_{rate:g}hz", m_lat,
                     f"met_cpu={m_st.cpu_fraction:.3f};"
                     f"poll_cpu={b_st.cpu_fraction:.3f};"
                     f"met_ttft_us={m_lat:.0f};poll_ttft_us={b_lat:.0f}"))
    return rows
