"""Regenerate the EXPERIMENTS.md roofline tables from the dry-run JSONL
artifacts.  Usage: PYTHONPATH=src python -m benchmarks.make_experiments_tables
prints markdown to stdout."""

import json
import os


def load(fname):
    if not os.path.exists(fname):
        return []
    return [json.loads(l) for l in open(fname)]


def fmt_s(x):
    return f"{x:.4f}" if x >= 1e-4 else f"{x:.2e}"


def lever(r) -> str:
    """One sentence per cell: what would move the dominant term down."""
    dom, shape, arch = r["dominant"], r["shape"], r["arch"]
    moe = arch in ("dbrx-132b", "llama4-scout-17b-a16e", "jamba-1.5-large-398b")
    if dom == "memory" and "decode" in shape or "long" in shape:
        if arch == "gemma2-2b":
            return ("ring-buffer the local-layer KV (window 4096 of 32768) "
                    "to cut half the layers' cache reads 8x")
        if arch == "mamba2-370m":
            return ("batch=1 reads all weights per token: decode batching "
                    "or weight int8 is the only lever")
        return ("KV/param reads dominate: int8 KV cache and larger decode "
                "batch per weight read")
    if dom == "memory":
        return ("fp32 attention probs + remat re-reads: flash-attention "
                "kernel (fused softmax, no S^2 materialization) and "
                "selective remat")
    if dom == "collective":
        if moe:
            return ("EP dispatch + Megatron residual all-reduce: sequence-"
                    "parallel residual (seq=model override) converts AR to "
                    "RS+AG; overlap all-to-all with expert GEMMs")
        return ("Megatron residual all-reduce per layer: sequence-parallel "
                "residual halves it")
    return "compute-bound: raise MXU utilization (larger tiles, bf16)"


def table(rows, n_dev):
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| frac | MODEL_TFLOPs | useful | peak GB | lever |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        model_tflops = (r["useful_flops_ratio"] * r["compute_s"] * 197e12
                        * n_dev / 1e12)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{model_tflops:.1f} | "
            f"{r['useful_flops_ratio']:.3f} | {r['peak_gb']:.2f} | "
            f"{lever(r)} |")
    return "\n".join(out)


def coll_table(rows):
    out = ["| arch | shape | all-gather | all-reduce | all-to-all | permute | total GB/dev |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: -(r["coll_detail"]["total"])):
        d = r["coll_detail"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {d['all-gather'] / 1e9:.2f} | "
            f"{d['all-reduce'] / 1e9:.2f} | {d['all-to-all'] / 1e9:.2f} | "
            f"{d['collective-permute'] / 1e9:.2f} | {d['total'] / 1e9:.2f} |")
    return "\n".join(out)


def opt_compare_table():
    base = {(r["arch"], r["shape"]): r for r in load("dryrun_16x16.jsonl")}
    opt = {(r["arch"], r["shape"]): r for r in load("dryrun_16x16_opt.jsonl")}
    if not opt:
        return None
    out = ["| arch | shape | baseline bound s | optimized bound s | speedup "
           "| collective: base → opt |",
           "|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        bb = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ob = max(o["compute_s"], o["memory_s"], o["collective_s"])
        out.append(
            f"| {key[0]} | {key[1]} | {fmt_s(bb)} | {fmt_s(ob)} | "
            f"{bb / max(ob, 1e-30):.2f}x | "
            f"{fmt_s(b['collective_s'])} → {fmt_s(o['collective_s'])} |")
    return "\n".join(out)


def main():
    for mesh, fname, n_dev in (
            ("16x16 (single pod, 256 chips)", "dryrun_16x16.jsonl", 256),
            ("2x16x16 (two pods, 512 chips)", "dryrun_2x16x16.jsonl", 512)):
        rows = load(fname)
        if not rows:
            continue
        print(f"\n### Mesh {mesh} — {len(rows)} cells\n")
        print(table(rows, n_dev))
        if "16x16 (single" in mesh:
            print("\n#### Collective traffic per device (single pod)\n")
            print(coll_table(rows[:]))
    cmp_tbl = opt_compare_table()
    if cmp_tbl:
        print("\n### Paper-faithful baseline vs beyond-paper optimized "
              "(16x16, all cells)\n")
        print("Optimized = `--override moe=shard_map --override attn=chunked "
              "--override seq=model --kv-quant --kv-ring` (every §Perf lever "
              "on; baselines unchanged above).\n")
        print(cmp_tbl)


if __name__ == "__main__":
    main()
