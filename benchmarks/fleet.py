"""Fleet-scale serving benchmark: hedged Metronome fleet vs busy-poll fleet.

The paper's single-host claim — sleep&wake retrieval trades a few
microseconds of mean latency for most of a core — has a fleet-level
counterpart this benchmark measures end to end: on a *noisy shared
cluster* (correlated stall windows per host, independent across hosts),
a fleet of Metronome hosts behind a load balancer, with hedged requests
duplicated to a second replica after a deadline D, serves the same
offered load as a busy-poll fleet at

  verdict: strictly lower total CPU (cores) AND equal-or-better p99.9
  end-to-end latency.

The mechanism is the interesting part: a single Metronome host has a
*worse* tail than a spinner (stall windows park its wake-ups), but
stalls are independent across replicas, so "duplicate after D; first
completion wins" collapses the stall tail (both replicas must stall)
while the busy-poll fleet pays H full cores and still eats the
co-runner stalls.  The busy-poll comparator's p99.9 comes from the
same two-component tail model (``hedged_latency_quantile`` at D=0)
applied to its event-engine spin-model mean, so both sides' tails are
scored by one formula.

Rows (suite convention: ``name,value,derived``):
  - ``fleet/H<H>/<lb>/D<D>``  one fleet operating point: value = total
    CPU cores; derived has p999/mean latency, loss, offered (incl.
    hedge duplicates) and the backend (vmap vs shard_map);
  - ``fleet/busy_poll/H<H>``  the busy-poll comparator fleet;
  - ``verdict/hedged_vs_busy_poll``  the claim above, machine-readable;
  - ``fleet/scale/...``       a 1000-host x 8-point sweep in ONE jit
    call: wall-clock and points*hosts/sec throughput.

CLI: ``python -m benchmarks.fleet [--smoke]`` — ``--smoke`` runs the
small grid and exits nonzero on a failed verdict (the CI job).
"""

from __future__ import annotations

import sys
import time

import numpy as np

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
RHO = 0.5                     # per-host utilization at uniform split
T_S_US, T_L_US, M = 12.0, 500.0, 3
# noisy shared cluster: correlated stall windows (Exp(150us) bursts
# every ~4ms per host, independent ACROSS hosts) — the regime where
# hedging pays.  tail_prob = stall_rate * stall_mean ~= 3.75%.
STALLS = dict(stall_rate_per_us=2.5e-4, stall_mean_us=150.0)
# hedge ladder: loosest -> tightest, all above the drain-time scale
# (tighter deadlines duplicate aggressively enough to inflate host
# means — the cost side the offered_with_hedges column tracks)
HEDGE_LADDER = (0.0, 80.0, 40.0, 20.0)


def _fleet_env(duration_us: float):
    from repro.runtime import SimRunConfig

    return SimRunConfig(duration_us=duration_us, **STALLS)


def _busy_poll_mean_us(cfg) -> float:
    """Event-engine spin-model mean sojourn at the per-host rate."""
    from repro.runtime import BusyPollPolicy, PoissonWorkload, simulate_run

    rs = simulate_run(BusyPollPolicy(), PoissonWorkload(RHO * MU_MPPS), cfg)
    return float(rs.mean_sojourn_us)


def fleet_bench(quick: bool = False) -> ROWS:
    from repro.runtime import (
        FleetConfig,
        FleetGrid,
        hedged_latency_quantile,
        simulate_fleet,
    )

    duration = 20_000.0 if quick else 60_000.0
    slot_us = 1.0 if quick else 0.5
    sizes = (4, 16) if quick else (4, 16, 64)
    cfg = _fleet_env(duration)
    tail_prob = min(STALLS["stall_rate_per_us"] * STALLS["stall_mean_us"],
                    0.5)
    tail_scale = STALLS["stall_mean_us"]

    rows: ROWS = []
    verdicts = []
    lbs = {
        "uniform": lambda H: FleetConfig(n_hosts=H),
        "weighted": lambda H: FleetConfig(
            n_hosts=H, lb="weighted",
            host_weights=tuple(1.0 + 0.5 * (h % 2) for h in range(H))),
        "least-loaded": lambda H: FleetConfig(
            n_hosts=H, lb="least-loaded", lb_stale_us=200.0),
    }
    busy_mean = _busy_poll_mean_us(cfg)

    for H in sizes:
        # busy-poll comparator: H spinning hosts, the same stall tail
        busy_p999 = hedged_latency_quantile(
            0.999, np.full(H, busy_mean), hedge_deadline_us=0.0,
            tail_prob=tail_prob, tail_scale_us=tail_scale)
        rows.append((
            f"fleet/busy_poll/H{H}", float(H),
            f"p999_us={busy_p999:.1f};mean_lat_us={busy_mean:.2f};"
            f"cpu_cores={H};spin=True"))

        for lb, make in lbs.items():
            fgrid = FleetGrid.product(
                fleet=make(H), t_s_us=(T_S_US,), t_l_us=(T_L_US,),
                rate_mpps=(RHO * MU_MPPS * H,), m=(M,),
                hedge_deadline_us=HEDGE_LADDER)
            fs = simulate_fleet(fgrid, cfg, slot_us=slot_us)
            for i in range(len(fs)):
                d = float(fgrid.hedge_deadline_us[i])
                p999 = fs.quantile(i, 0.999)
                rows.append((
                    f"fleet/H{H}/{lb}/D{d:g}",
                    float(fs.total_cpu_cores[i]),
                    f"p999_us={p999:.1f};"
                    f"mean_lat_us={fs.mean_latency_us[i]:.2f};"
                    f"loss_frac={fs.loss_fraction[i]:.4f};"
                    f"offered_w_hedges_pkts="
                    f"{fs.offered_with_hedges[i]:.0f};"
                    f"backend={fs.backend}"))
                if lb == "uniform" and d > 0.0:
                    verdicts.append((H, d, float(fs.total_cpu_cores[i]),
                                     p999, busy_p999))

    # verdict at the largest fleet: the best hedged uniform point must
    # beat the busy-poll fleet on BOTH axes (cores and p99.9)
    H = sizes[-1]
    cands = [v for v in verdicts if v[0] == H]
    best = min(cands, key=lambda v: v[3])
    _, best_d, best_cpu, best_p999, busy_p999 = best
    ok = bool(best_cpu < H and best_p999 <= busy_p999)
    rows.append((
        "verdict/hedged_vs_busy_poll", float(ok),
        f"ok={ok};n_hosts={H};hedge_deadline_us={best_d:g};"
        f"metronome_cpu_cores={best_cpu:.1f};busy_poll_cpu_cores={H};"
        f"metronome_p999_us={best_p999:.1f};"
        f"busy_poll_p999_us={busy_p999:.1f}"))

    # scale row: a whole-cluster sweep in ONE jit call — 1000 hosts x
    # 8 operating points (hedge ladder x 2 loads), point axis sharded
    # across however many devices are visible
    H_big = 100 if quick else 1000
    dur_big, slot_big = (2_000.0, 1.0) if quick else (5_000.0, 1.0)
    cfg_big = _fleet_env(dur_big)
    fgrid = FleetGrid.product(
        fleet=FleetConfig(n_hosts=H_big), t_s_us=(T_S_US,),
        t_l_us=(T_L_US,), m=(M,),
        rate_mpps=(0.35 * MU_MPPS * H_big, 0.55 * MU_MPPS * H_big),
        hedge_deadline_us=HEDGE_LADDER)
    t0 = time.time()
    fs = simulate_fleet(fgrid, cfg_big, slot_us=slot_big)
    np.asarray(fs.serviced)            # block on the device computation
    wall = time.time() - t0
    ph = len(fgrid) * H_big
    rows.append((
        "fleet/scale/one_jit_call", wall,
        f"points={len(fgrid)};n_hosts={H_big};points_x_hosts={ph};"
        f"pts_hosts_per_s={ph / max(wall, 1e-9):.0f};"
        f"host_slots_per_s="
        f"{ph * int(dur_big / slot_big) / max(wall, 1e-9):.3g};"
        f"one_jit_call=True;backend={fs.backend}"))
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = fleet_bench(quick=quick)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows
                  if n == "verdict/hedged_vs_busy_poll")
        if not ok:
            print("SMOKE FAILED: hedged Metronome fleet did not beat the "
                  "busy-poll fleet on CPU + p99.9", file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
