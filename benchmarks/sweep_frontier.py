"""CPU-vs-latency trade-off frontier from a batched parameter sweep.

The paper's central claim is that sleep&wake retrieval traces a much
better CPU/latency frontier than busy polling, *if* (T_S, T_L, M) are
chosen per load.  This benchmark reproduces that frontier empirically
from thousands of simulated operating points in one JIT-compiled
batched-engine call (``repro.runtime.batched``), then runs the
calibration layer over the same sweep and checks its promise:

  verdict: for every load on the ladder, the calibrated operating table
  meets the mean-latency target at CPU <= the best *fixed*-(T_S, T_L, M)
  configuration that meets the target at every load (the static
  provisioning a paper reader would deploy).  The inequality holds per
  load by construction — the fixed config is one of the candidates the
  per-load argmin sees — so a False here means the calibration layer
  regressed, not that the experiment got unlucky.

Rows (suite convention: ``name,value,derived``):
  - ``frontier/<rho>/...``  per-load Pareto frontier samples (CPU at a
    latency band), plus busy-poll's corner (CPU=1);
  - ``table/<rho>``         the calibrated operating point per load;
  - ``verdict/...``         the calibrated-vs-fixed comparison above;
  - ``sweep/…``             sweep size and wall time (one jit call),
    split into first-call (``wall_s`` = trace + compile + execute) and
    second-call (``execute_s``, a compile-cache hit) timings, with
    ``compile_s`` their difference and throughput on the execute time.

CLI: ``python -m benchmarks.sweep_frontier [--smoke] [--interference]``
— ``--smoke`` runs a tiny grid and exits nonzero on a failed verdict
(the CI job); ``--interference`` runs the whole pipeline on a *noisy
shared host* (per-wake OS interference + correlated stall windows
through the batched engine, an analytic guard widened by the
environment's interference slack, and event-engine spot checks in that
same noisy environment) with a correspondingly relaxed latency target
and loss budget — the CPU-sharing counterpart of the quiet-host
frontier.
"""

from __future__ import annotations

import sys
import time

import numpy as np

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
TARGET_MEAN_LAT_US = 15.0
MAX_LOSS = 1e-3
# noisy-shared-host mode (--interference): a fifth of all wakes delayed
# by Exp(15us) co-runner preemption, Exp(100us) stall windows every
# ~5ms; latency target and loss budget relaxed to match the host
NOISY_ENV = dict(interference_prob=0.2, interference_mean_us=15.0,
                 stall_rate_per_us=1.0 / 5_000.0, stall_mean_us=100.0)
NOISY_TARGET_MEAN_LAT_US = 30.0
NOISY_MAX_LOSS = 0.05


def _sweep(quick: bool, noisy: bool = False):
    from repro.runtime import SimRunConfig, SweepGrid, simulate_batch

    if quick:
        t_s_grid = np.linspace(4.0, 60.0, 8)
        t_l_grid = np.asarray([120.0, 500.0])
        m_grid = (2, 3)
        rhos = np.asarray([0.15, 0.35, 0.55, 0.75])
        seeds = (0,)
        duration = 30_000.0
        slot_us = 1.0
    else:
        t_s_grid = np.linspace(3.0, 80.0, 14)
        t_l_grid = np.asarray([120.0, 250.0, 500.0, 900.0])
        m_grid = (2, 3, 4)
        rhos = np.asarray([0.1, 0.25, 0.4, 0.55, 0.7, 0.85])
        seeds = (0, 1)
        duration = 50_000.0
        slot_us = 0.5
    cfg = SimRunConfig(duration_us=duration,
                       **(NOISY_ENV if noisy else {}))
    grid = SweepGrid.product(t_s_us=t_s_grid, t_l_us=t_l_grid, m=m_grid,
                             rate_mpps=rhos * MU_MPPS, seeds=seeds)
    t0 = time.time()
    bs = simulate_batch(grid, cfg, slot_us=slot_us)
    wall = time.time() - t0          # trace + compile + execute
    t1 = time.time()
    simulate_batch(grid, cfg, slot_us=slot_us)
    execute = time.time() - t1       # compile-cache hit: execute only
    return (cfg, grid, bs, wall, execute, t_s_grid, t_l_grid, m_grid,
            rhos, seeds, slot_us)


def sweep_frontier(quick: bool = False, noisy: bool = False) -> ROWS:
    from repro.runtime import build_operating_table
    from repro.runtime.calibrate import analytic_guard_mask

    target = NOISY_TARGET_MEAN_LAT_US if noisy else TARGET_MEAN_LAT_US
    max_loss = NOISY_MAX_LOSS if noisy else MAX_LOSS
    (cfg, grid, bs, wall, execute, t_s_grid, t_l_grid, m_grid, rhos,
     seeds, slot_us) = _sweep(quick, noisy)

    # seed-averaged (ts, tl, m, rho) lattice
    lat = bs.reshaped("mean_latency_us").mean(axis=-1)[:, :, :, 0, :]
    cpu = bs.reshaped("cpu_fraction").mean(axis=-1)[:, :, :, 0, :]
    loss = bs.reshaped("loss_fraction").mean(axis=-1)[:, :, :, 0, :]
    vac = bs.reshaped("mean_vacation_us").mean(axis=-1)
    # the same validity rule the calibration layer applies (incl. the
    # noisy-host slack), so the fixed baseline and the table argmin over
    # one candidate set (this is what makes the verdict hold by
    # construction)
    valid = analytic_guard_mask(
        vac, t_s_grid, t_l_grid, m_grid, rhos, guard_rel=0.6,
        slot_us=slot_us,
        slack_us=cfg.interference_slack_us())[:, :, :, 0, :]

    rows: ROWS = [(
        "sweep/points", float(len(grid)),
        f"one_jit_call=True;wall_s={wall:.2f};"
        f"compile_s={max(wall - execute, 0.0):.2f};"
        f"execute_s={execute:.2f};slots_per_point="
        f"{int(cfg.duration_us / slot_us)};"
        f"pts_per_s={len(grid) / max(execute, 1e-9):.0f};"
        f"interference={cfg.is_noisy}")]

    # per-load Pareto frontiers: min CPU within sliding latency bands,
    # and the same cut through power (the energy model charges the whole
    # host: active awake time + per-arm C-state residency + transitions)
    em = cfg.energy_model
    busy_w = em.active_power_w * em.dvfs_busy_scale
    watts = bs.reshaped("mean_power_w").mean(axis=-1)[:, :, :, 0, :]
    bands = [5.0, 10.0, 15.0, 25.0, 50.0]
    for k, rho in enumerate(rhos):
        flat_lat = lat[..., k].ravel()
        flat_cpu = cpu[..., k].ravel()
        flat_w = watts[..., k].ravel()
        ok = loss[..., k].ravel() <= max_loss
        for band in bands:
            sel = ok & (flat_lat <= band)
            if not sel.any():
                continue
            rows.append((
                f"frontier/rho{rho:.2f}/lat_le_{band:g}us",
                float(flat_cpu[sel].min()),
                f"points={int(sel.sum())};"
                f"best_lat_us={flat_lat[sel][flat_cpu[sel].argmin()]:.2f}"))
            rows.append((
                f"pfrontier/rho{rho:.2f}/lat_le_{band:g}us",
                float(flat_w[sel].min()),
                f"points={int(sel.sum())};busy_poll_w={busy_w:.2f};"
                f"best_lat_us={flat_lat[sel][flat_w[sel].argmin()]:.2f}"))
        rows.append((f"frontier/rho{rho:.2f}/busy_poll", 1.0,
                     "spinning baseline: one full core by construction"))
        rows.append((
            f"pfrontier/rho{rho:.2f}/busy_poll_w", busy_w,
            "spinning baseline: one core at dvfs-pinned active power, "
            "flat in load"))

    # calibrated table over the same environment — reusing this sweep's
    # BatchStats, so the 2000+ points are simulated exactly once
    table = build_operating_table(
        rhos=rhos, target_mean_latency_us=target,
        t_s_grid=t_s_grid, t_l_grid=t_l_grid, m_grid=m_grid, cfg=cfg,
        seeds=seeds, slot_us=slot_us, max_loss=max_loss,
        spot_check=0 if quick else 3, sweep=bs)
    for p in table.points:
        rows.append((
            f"table/rho{p.rho:.2f}", p.cpu_fraction,
            f"t_s_us={p.t_s_us:.1f};t_l_us={p.t_l_us:.0f};m={p.m};"
            f"mean_lat_us={p.mean_latency_us:.2f};"
            f"meets_target={p.meets_target}"))

    # fixed baseline: the cheapest single (ts, tl, m) meeting the target
    # at EVERY load — what you would statically provision.  Restricted
    # to guard-valid cells, the same filter the table's argmin saw.
    meets_all = (valid & (lat <= target)
                 & (loss <= max_loss)).all(axis=-1)
    verdict_ok = all(p.meets_target for p in table.points)
    if meets_all.any():
        total_cpu = np.where(meets_all, cpu.sum(axis=-1), np.inf)
        i, j, l = np.unravel_index(int(np.argmin(total_cpu)),
                                   total_cpu.shape)
        base_cpu = cpu[i, j, l, :]
        tab_cpu = np.asarray([p.cpu_fraction for p in table.points])
        per_load_ok = bool(np.all(tab_cpu <= base_cpu + 1e-9))
        verdict_ok = verdict_ok and per_load_ok
        rows.append((
            "verdict/calibrated_vs_fixed_ts",
            float(base_cpu.sum() - tab_cpu.sum()),
            f"fixed_t_s_us={t_s_grid[i]:.1f};"
            f"fixed_t_l_us={t_l_grid[j]:.0f};fixed_m={m_grid[l]};"
            f"fixed_cpu_sum={base_cpu.sum():.3f};"
            f"calibrated_cpu_sum={tab_cpu.sum():.3f};"
            f"calibrated_leq_fixed_at_every_load={per_load_ok};"
            f"all_loads_meet_{target:g}us_target="
            f"{all(p.meets_target for p in table.points)}"))
    else:
        verdict_ok = False
        rows.append(("verdict/calibrated_vs_fixed_ts", float("nan"),
                     "no fixed configuration meets the target at every "
                     "load — widen the grid"))
    rows.append(("verdict/ok", float(verdict_ok), f"ok={verdict_ok}"))
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = sweep_frontier(quick=quick, noisy="--interference" in sys.argv)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows if n == "verdict/ok")
        if not ok:
            print("SMOKE FAILED: calibrated table did not beat the fixed "
                  "baseline while meeting the latency target",
                  file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
