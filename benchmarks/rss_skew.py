"""Multi-queue (RSS) ingress matrix: the headline result the multi-queue
refactor exists to produce.

Grid: arrival spread (uniform round-robin vs Zipf flow-hash skew, the
RSS-with-elephant-flows regime) × thread↔queue assignment (dedicated /
shared / stealing) × policy (metronome / busy-poll), reporting the
CPU-vs-p99-vs-loss trade-off per cell.

Comparisons are made *at equal CPU fraction*: each metronome arm's
vacation target is bisected until the run lands on a common CPU budget,
so a lower p99 is a genuinely better operating point, not just a
willingness to burn more wakes.  Under Zipf skew this shows work
stealing strictly below dedicated per-ring pollers on p99 at the same
CPU — the dedicated hot ring starves between its lone poller's visits
(and starts dropping first), while stealing turns the cold rings'
pollers into extra hot-ring capacity.

Sampled p99 is censored by drops (a dropped packet never reports a
latency), so every row also carries its loss; read high-loss cells'
latency as a lower bound.
"""

from __future__ import annotations

from repro.core import MetronomeConfig
from repro.runtime import (
    BusyPollPolicy,
    DedicatedAssignment,
    FlowHashDispatch,
    MetronomePolicy,
    PoissonWorkload,
    RoundRobinDispatch,
    SharedAssignment,
    SimRunConfig,
    StealingAssignment,
    simulate_run,
)

ROWS = list[tuple[str, float, str]]

N_QUEUES = 4
RATE_MPPS = 20.0          # aggregate; mu = 29.76 per draining core
TARGET_CPU = 0.82         # common budget the metronome arms are tuned to
T_LONG_US = 800.0

DISPATCHES = [
    ("uniform", lambda: RoundRobinDispatch()),
    ("zipf", lambda: FlowHashDispatch(n_flows=16, zipf_s=2.0)),
]

# dedicated clones its policy per queue, so one thread per ring keeps the
# total thread budget equal to the shared/stealing arms' M = N_QUEUES
ASSIGNMENTS = [
    ("dedicated", DedicatedAssignment, 1),
    ("shared", SharedAssignment, N_QUEUES),
    ("stealing", StealingAssignment, N_QUEUES),
]


def _metronome_run(mk_dispatch, assignment_cls, m: int, v_target_us: float,
                   duration_us: float, seed: int = 9):
    policy = MetronomePolicy(
        MetronomeConfig(m=m, v_target_us=v_target_us, t_long_us=T_LONG_US),
        adaptive=False)
    return simulate_run(
        policy, PoissonWorkload(RATE_MPPS),
        SimRunConfig(duration_us=duration_us, seed=seed, n_queues=N_QUEUES),
        dispatcher=mk_dispatch(), assignment=assignment_cls())


def _calibrate_v_target(mk_dispatch, assignment_cls, m: int,
                        duration_us: float, iters: int) -> float:
    """Bisect the (static) vacation target until CPU lands on the common
    budget — cpu is monotone decreasing in v_target."""
    lo, hi = 10.0, 400.0
    for _ in range(iters):
        vt = (lo + hi) / 2
        cpu = _metronome_run(mk_dispatch, assignment_cls, m, vt,
                             duration_us).cpu_fraction
        if cpu > TARGET_CPU:
            lo = vt
        else:
            hi = vt
    return (lo + hi) / 2


def matrix_rss_skew(quick: bool = False) -> ROWS:
    calib_dur = 60_000.0 if quick else 100_000.0
    final_dur = 120_000.0 if quick else 250_000.0
    iters = 5 if quick else 7

    rows: ROWS = []
    cells: dict[tuple[str, str], object] = {}
    for dname, mk_dispatch in DISPATCHES:
        for aname, assignment_cls, m in ASSIGNMENTS:
            vt = _calibrate_v_target(mk_dispatch, assignment_cls, m,
                                     calib_dur, iters)
            rs = _metronome_run(mk_dispatch, assignment_cls, m, vt, final_dur)
            cells[(dname, aname)] = rs
            per_q = ":".join(str(q.offered) for q in rs.per_queue)
            rows.append((
                f"rss/{dname}/{aname}/metronome", rs.p99_latency_us,
                f"cpu={rs.cpu_fraction:.3f};v_target_us={vt:.1f};"
                f"mean_lat_us={rs.mean_latency_us:.2f};"
                f"loss_pct={rs.loss_fraction * 100:.3f};"
                f"perq_offered={per_q}"))

    # spinning baseline: one core sweeps every ring, CPU pinned at 1 —
    # the fluid model sees the union of the rings, so the arrival spread
    # is irrelevant and one row covers both dispatch arms
    rs = simulate_run(
        BusyPollPolicy(), PoissonWorkload(RATE_MPPS),
        SimRunConfig(duration_us=final_dur, seed=9, n_queues=N_QUEUES))
    rows.append((
        "rss/any/-/busy-poll", rs.p99_latency_us,
        f"cpu={rs.cpu_fraction:.3f};mean_lat_us={rs.mean_latency_us:.2f};"
        f"loss_pct={rs.loss_fraction * 100:.3f}"))

    ded = cells[("zipf", "dedicated")]
    ste = cells[("zipf", "stealing")]
    rows.append((
        "rss/verdict/stealing_vs_dedicated_zipf",
        ded.p99_latency_us - ste.p99_latency_us,
        f"stealing_p99_us={ste.p99_latency_us:.2f};"
        f"dedicated_p99_us={ded.p99_latency_us:.2f};"
        f"stealing_cpu={ste.cpu_fraction:.3f};"
        f"dedicated_cpu={ded.cpu_fraction:.3f};"
        f"stealing_strictly_better="
        f"{ste.p99_latency_us < ded.p99_latency_us}"))
    return rows


if __name__ == "__main__":
    print("name,p99_us,derived")
    for name, val, derived in matrix_rss_skew():
        print(f"{name},{val:.3f},{derived}")
