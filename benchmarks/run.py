"""Benchmark harness: one function per paper table/figure (+ kernels +
roofline + the batched sweep frontier + the nonstationary adaptation
matrix).  Prints ``name,us_per_call,derived`` CSV; ``--json out.json``
additionally writes every row machine-readably (derived ``k=v;k=v``
strings parsed into dicts — so policy/workload labels, p50/p99
latencies, CPU fractions, and the adaptation rows' ``schedule``
descriptor plus tracking fields — conv_us, overshoot_us,
violation_frac, rho_rmse — land as fields) for a ``BENCH_*.json`` perf
trajectory across PRs.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only substr]
                                          [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import math
import platform
import sys
import time


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` strings -> dict (numbers coerced); anything else is
    kept whole under ``note``."""
    out: dict = {}
    parts = [p for p in str(derived).split(";") if p]
    for p in parts:
        if "=" not in p:
            out.setdefault("note", []).append(p)
            continue
        k, v = p.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                fv = float(v)
                out[k] = fv if math.isfinite(fv) else v   # strict JSON
            except ValueError:
                out[k] = {"True": True, "False": False}.get(v, v)
    if isinstance(out.get("note"), list):
        out["note"] = "; ".join(out["note"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write all rows to this file as JSON")
    args = ap.parse_args()

    from benchmarks.adaptation import adaptation
    from benchmarks.cpu_sharing import cpu_sharing
    from benchmarks.fleet import fleet_bench
    from benchmarks.kernels_bench import kernels
    from benchmarks.policy_matrix import matrix_policies_workloads
    from benchmarks.power import power
    from benchmarks.rss_skew import matrix_rss_skew
    from benchmarks.stepping import stepping_compare
    from benchmarks.sweep_frontier import sweep_frontier
    from benchmarks.paper_tables import (
        fig2_sleep_cpu,
        fig5_vacation_pdf,
        fig7_tl_sweep,
        fig8_m_sweep,
        fig11_adaptation,
        fig12_dpdk_compare,
        fig15_applications,
        table1_sleep_precision,
        table2_vbar_tuning,
        table3_nanosleep_loss,
    )
    from benchmarks.roofline_table import roofline

    def compile_caches(quick: bool = False):
        """JIT compile-cache counters across everything that ran above —
        hits/misses/evictions per registered ``CompileCache`` (the
        batched and fleet sweep caches), so cache behavior lands in the
        perf trajectory next to the numbers it explains.  Must stay the
        LAST suite."""
        from repro.runtime import compile_cache_stats

        return [(f"cache/{s['name']}", float(s["hits"]),
                 f"misses={s['misses']};evictions={s['evictions']};"
                 f"currsize={s['currsize']};maxsize={s['maxsize']}")
                for s in compile_cache_stats()]

    suites = [
        table1_sleep_precision, fig2_sleep_cpu, fig5_vacation_pdf,
        table2_vbar_tuning, fig7_tl_sweep, fig8_m_sweep,
        table3_nanosleep_loss, fig11_adaptation, fig12_dpdk_compare,
        matrix_policies_workloads, matrix_rss_skew, sweep_frontier,
        cpu_sharing, adaptation, fig15_applications, fleet_bench,
        kernels, roofline, stepping_compare, power, compile_caches,
    ]
    print("name,us_per_call,derived")
    failures = 0
    records: list[dict] = []
    for suite in suites:
        if args.only and args.only not in suite.__name__:
            continue
        t0 = time.time()
        try:
            for name, us, derived in suite(quick=args.quick):
                print(f"{name},{us:.3f},{derived}")
                records.append({"suite": suite.__name__, "name": name,
                                # NaN/inf rows (e.g. "no verdict") must
                                # stay strict-JSON parseable: use null
                                "value": us if math.isfinite(us) else None,
                                "derived": _parse_derived(derived)})
        except Exception as e:  # keep the harness going; report at exit
            failures += 1
            print(f"{suite.__name__}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            records.append({"suite": suite.__name__, "name": "ERROR",
                            "value": None,
                            "derived": {"error": f"{type(e).__name__}: {e}"}})
        sys.stdout.flush()
        print(f"# {suite.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if args.json:
        payload = {
            "schema": "repro-bench/1",
            "created_unix": time.time(),
            "host": platform.node(),
            "python": platform.python_version(),
            "quick": bool(args.quick),
            "only": args.only,
            "failures": failures,
            "rows": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
