"""Benchmark harness: one function per paper table/figure (+ kernels +
roofline).  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only substr]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks.kernels_bench import kernels
    from benchmarks.policy_matrix import matrix_policies_workloads
    from benchmarks.rss_skew import matrix_rss_skew
    from benchmarks.paper_tables import (
        fig2_sleep_cpu,
        fig5_vacation_pdf,
        fig7_tl_sweep,
        fig8_m_sweep,
        fig11_adaptation,
        fig12_dpdk_compare,
        fig15_applications,
        table1_sleep_precision,
        table2_vbar_tuning,
        table3_nanosleep_loss,
    )
    from benchmarks.roofline_table import roofline

    suites = [
        table1_sleep_precision, fig2_sleep_cpu, fig5_vacation_pdf,
        table2_vbar_tuning, fig7_tl_sweep, fig8_m_sweep,
        table3_nanosleep_loss, fig11_adaptation, fig12_dpdk_compare,
        matrix_policies_workloads, matrix_rss_skew,
        fig15_applications, kernels, roofline,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        if args.only and args.only not in suite.__name__:
            continue
        t0 = time.time()
        try:
            for name, us, derived in suite(quick=args.quick):
                print(f"{name},{us:.3f},{derived}")
        except Exception as e:  # keep the harness going; report at exit
            failures += 1
            print(f"{suite.__name__}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
        sys.stdout.flush()
        print(f"# {suite.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
