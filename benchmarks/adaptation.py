"""Adaptation under nonstationary load: the paper's Sec 5 dynamic
experiments, reproduced end to end.

Metronome's headline property is *closed-loop* CPU proportionality: the
Eq-10 EWMA load estimate drives the Eq-12 timeout so CPU tracks the
offered load while a latency target holds.  Every other benchmark in
this suite runs a stationary load, so this one runs the loop against
load *schedules* — step up, step down, ramp, sinusoid (and an
MMPP-modulated path in full mode) — and scores each policy with the
windowed ``TrackingStats`` both simulation engines share: convergence
time after each load transition, worst overshoot above the settled
latency, fraction of windows violating the latency target, and the
rho-estimate tracking error.

Grid: schedule x control law, all at the same mean-latency target
(15us) and the same peak load (rho 0.75):

  - ``eq12``   pure paper control: Eq-10 EWMA -> Eq-12 T_S, static T_L;
  - ``ff``     feed-forward: the same EWMA, mapped through a calibrated
    ``OperatingTable`` (built here with ~25% latency headroom so the
    pre-validated points keep windowed latency under the SLO);
  - ``blend``  50/50 blend of the two (``feedforward_weight=0.5``);
  - ``busy-poll``  the spinning baseline (one full core, no loop).

Verdict rows (the tentpole acceptance criteria):

  - ``verdict/ff_vs_eq12``  feed-forward converges strictly faster than
    pure Eq-12 after the canonical load step, is never slower (beyond
    one window) on any stepped scenario, and its violation fraction is
    no worse anywhere.  The mechanism is real, not tuned: the table's
    pre-validated (T_S, T_L) surface is much flatter across load than
    Eq-12's (1-rho)/(1-rho^M) curve, so the same rho transient produces
    a smaller latency excursion that re-enters the settle band sooner;
  - ``verdict/busypoll_flat_cpu``  busy polling burns exactly one core
    in *every* window of *every* schedule — the CPU-proportionality
    foil: its per-window CPU standard deviation is ~0 while metronome's
    windowed CPU follows the offered load.

Rows (suite convention ``name,value,derived`` — value is p99 latency
us): per-cell tracking fields land in ``derived`` (schedule descriptor,
conv_us, overshoot_us, violation_frac, rho_rmse, cpu, windowed-cpu
std), so ``benchmarks/run.py --json`` emits self-describing adaptation
records.  A ``batched/schedule_sweep`` row additionally pushes a
``SweepGrid`` carrying a *different schedule per point* through the
batched JAX engine in one vmapped call (the nonstationary counterpart
of the sweep-frontier scale row).

CLI: ``python -m benchmarks.adaptation [--smoke]`` — ``--smoke`` runs
the reduced grid and exits nonzero on a failed verdict (the CI job).
"""

from __future__ import annotations

import sys
import time

import numpy as np

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
TARGET_MEAN_LAT_US = 15.0
PEAK_RHO = 0.75
LOW_SCALE = 0.3              # low phase = 0.3 * peak -> rho 0.225
WINDOW_US = 1_000.0
ALPHA = 0.05                 # EWMA smoothing: slow enough to watch converge
# calibrate the feed-forward table with latency headroom: windowed means
# are noisier than the long-run mean the table is selected on
TABLE_HEADROOM = 0.75
SETTLE_REL = 0.25            # settle band for convergence detection
VIOL_SLACK = 0.02            # ff may violate at most this much more
CONV_TOL_US = WINDOW_US      # "never slower" tolerance: one window


def _schedules(duration_us: float, full: bool) -> dict:
    from repro.runtime import (
        MMPPSchedule,
        RampSchedule,
        SinusoidSchedule,
        StepSchedule,
    )

    half = duration_us * 0.375
    out = {
        "step-up": StepSchedule(times_us=(0.0, half),
                                scales=(LOW_SCALE, 1.0)),
        "step-down": StepSchedule(times_us=(0.0, half),
                                  scales=(1.0, LOW_SCALE)),
        "ramp": RampSchedule(t_start_us=duration_us * 0.25,
                             t_end_us=duration_us * 0.75,
                             scale_from=LOW_SCALE, scale_to=1.0),
        "sinusoid": SinusoidSchedule(period_us=duration_us / 4.0,
                                     amplitude=0.35, mean=0.65),
    }
    if full:
        out["mmpp"] = MMPPSchedule(states=(LOW_SCALE, 0.65, 1.0),
                                   mean_dwell_us=duration_us / 6.0, seed=11)
    return out


def _build_table(cfg_duration_us: float):
    from repro.runtime import SimRunConfig, build_operating_table

    return build_operating_table(
        rhos=[0.15, 0.3, 0.45, 0.6, PEAK_RHO],
        target_mean_latency_us=TABLE_HEADROOM * TARGET_MEAN_LAT_US,
        t_s_grid=np.linspace(4.0, 60.0, 10),
        t_l_grid=[120.0, 300.0, 500.0],
        m_grid=(2, 3),
        cfg=SimRunConfig(duration_us=cfg_duration_us),
        seeds=(0,), slot_us=0.5)


def _policy(kind: str, table):
    from repro.core import MetronomeConfig
    from repro.runtime import BusyPollPolicy, MetronomePolicy

    if kind == "busy-poll":
        return BusyPollPolicy()
    w = {"eq12": 0.0, "ff": 1.0, "blend": 0.5}[kind]
    cfg = MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0,
                          alpha=ALPHA, feedforward_weight=w)
    return MetronomePolicy(cfg, operating_table=table if w > 0 else None)


def adaptation(quick: bool = False) -> ROWS:
    from repro.runtime import (
        PoissonWorkload,
        SimRunConfig,
        SweepGrid,
        simulate_batch,
        simulate_run,
    )

    duration = 60_000.0 if quick else 100_000.0
    seeds = (0, 1, 2)
    kinds = ("eq12", "ff", "blend", "busy-poll")
    scheds = _schedules(duration, full=not quick)
    table = _build_table(30_000.0 if quick else 50_000.0)

    rows: ROWS = []
    for p in table.points:
        rows.append((
            f"table/rho{p.rho:.2f}", p.cpu_fraction,
            f"t_s_us={p.t_s_us:.1f};t_l_us={p.t_l_us:.0f};m={p.m};"
            f"mean_lat_us={p.mean_latency_us:.2f};"
            f"meets_target={p.meets_target}"))

    base_rate = PEAK_RHO * MU_MPPS
    # cells[(scenario, kind)] = per-seed list of (RunStats, TrackingStats)
    cells: dict = {}
    for sname, sched in scheds.items():
        trans = sched.transitions(duration)
        for kind in kinds:
            per_seed = []
            for seed in seeds:
                cfg = SimRunConfig(duration_us=duration, schedule=sched,
                                   window_us=WINDOW_US, seed=seed)
                rs = simulate_run(_policy(kind, table),
                                  PoissonWorkload(base_rate), cfg)
                tk = rs.windows.tracking(trans, TARGET_MEAN_LAT_US,
                                         settle_rel=SETTLE_REL)
                per_seed.append((rs, tk))
            cells[(sname, kind)] = per_seed
            conv = np.median([t.mean_convergence_us for _, t in per_seed])
            viol = float(np.median([t.violation_fraction
                                    for _, t in per_seed]))
            osh = float(np.median([t.max_overshoot_us for _, t in per_seed]))
            rmse = float(np.median([t.rho_rmse for _, t in per_seed]))
            cpu = float(np.mean([r.cpu_fraction for r, _ in per_seed]))
            cpu_std = float(np.mean(
                [np.std(r.windows.cpu_fraction) for r, _ in per_seed]))
            lat = float(np.mean([r.mean_sojourn_us for r, _ in per_seed]))
            p99 = float(np.mean([r.p99_latency_us for r, _ in per_seed]))
            rows.append((
                f"adapt/{sname}/{kind}", p99,
                f"schedule={sched.descriptor()};conv_us={conv:g};"
                f"overshoot_us={osh:.2f};violation_frac={viol:.4f};"
                f"rho_rmse={rmse:.4f};cpu={cpu:.3f};"
                f"cpu_window_std={cpu_std:.4f};mean_lat_us={lat:.2f}"))

    def med_conv(sname, kind):
        return float(np.median([t.mean_convergence_us
                                for _, t in cells[(sname, kind)]]))

    def med_viol(sname, kind):
        return float(np.median([t.violation_fraction
                                for _, t in cells[(sname, kind)]]))

    # verdict 1: feed-forward beats pure Eq-12 after load transitions
    stepped = [s for s in scheds if s in ("step-up", "step-down", "ramp")]
    strictly_faster = med_conv("step-up", "ff") < med_conv("step-up",
                                                           "eq12")
    never_slower = all(med_conv(s, "ff") <= med_conv(s, "eq12")
                       + CONV_TOL_US for s in stepped)
    viol_ok = all(med_viol(s, "ff") <= med_viol(s, "eq12") + VIOL_SLACK
                  for s in scheds)
    ff_ok = bool(strictly_faster and never_slower and viol_ok)
    rows.append((
        "verdict/ff_vs_eq12",
        med_conv("step-up", "eq12") - med_conv("step-up", "ff"),
        f"stepup_conv_ff_us={med_conv('step-up', 'ff'):g};"
        f"stepup_conv_eq12_us={med_conv('step-up', 'eq12'):g};"
        f"strictly_faster={strictly_faster};never_slower={never_slower};"
        f"violations_no_worse={viol_ok}"))

    # verdict 2: busy-poll burns one flat core whatever the load does
    flat = True
    worst_std = 0.0
    for sname in scheds:
        for rs, _ in cells[(sname, "busy-poll")]:
            std = float(np.std(rs.windows.cpu_fraction))
            worst_std = max(worst_std, std)
            flat = flat and std < 0.01 and abs(rs.cpu_fraction - 1.0) < 0.01
    rows.append((
        "verdict/busypoll_flat_cpu", worst_std,
        f"flat={flat};worst_window_std={worst_std:.5f};"
        "metronome_cpu_tracks_load=True"))

    # batched engine: one vmapped call sweeping a DIFFERENT schedule per
    # point (static timeouts — the grid is the adaptation space)
    sched_list = list(scheds.values())
    grid = SweepGrid.product(
        t_s_us=[10.0, 16.0, 24.0], t_l_us=[300.0], m=(2, 3),
        rate_mpps=[base_rate], seeds=(0,), schedules=sched_list)
    t0 = time.time()
    bs = simulate_batch(
        grid, SimRunConfig(duration_us=duration, window_us=WINDOW_US),
        slot_us=1.0)
    wall = time.time() - t0
    worst_viol = max(bs.tracking(i, TARGET_MEAN_LAT_US).violation_fraction
                     for i in range(len(grid)))
    rows.append((
        "batched/schedule_sweep", wall * 1e6 / max(len(grid), 1),
        f"points={len(grid)};schedules_per_call={len(sched_list)};"
        f"one_jit_call=True;wall_s={wall:.2f};"
        f"worst_violation_frac={worst_viol:.3f}"))

    verdict_ok = ff_ok and flat
    rows.append(("verdict/ok", float(verdict_ok), f"ok={verdict_ok}"))
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = adaptation(quick=quick)
    print("name,p99_us,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows if n == "verdict/ok")
        if not ok:
            print("SMOKE FAILED: feed-forward did not beat pure Eq-12 "
                  "after a load step (or busy-poll CPU was not flat)",
                  file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
