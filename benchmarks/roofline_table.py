"""Roofline table benchmark: reads the dry-run JSONL artifacts (written by
``python -m repro.launch.dryrun --all --json ...``) and emits one row per
(arch x shape x mesh) cell.  The dry-run itself needs 512 host devices so
it must run in its own process; this reader keeps benchmarks/run.py
single-device."""

from __future__ import annotations

import json
import os

FILES = {
    "16x16": "dryrun_16x16.jsonl",
    "2x16x16": "dryrun_2x16x16.jsonl",
}


def roofline(quick: bool = False):
    rows = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    found = False
    for mesh, fname in FILES.items():
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            continue
        found = True
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
                rows.append((
                    f"roofline/{r['arch']}/{r['shape']}@{r['mesh']}",
                    bound * 1e6,
                    f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
                    f"compute_s={r['compute_s']:.4f};"
                    f"memory_s={r['memory_s']:.4f};"
                    f"collective_s={r['collective_s']:.4f};"
                    f"useful={r['useful_flops_ratio']:.3f};"
                    f"peak_gb={r['peak_gb']:.2f}"))
    if not found:
        rows.append(("roofline/missing", 0.0,
                     "run python -m repro.launch.dryrun --all --json first"))
    return rows
