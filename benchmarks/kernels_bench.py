"""Kernel substrate benchmark: us/call of the jnp reference paths on this
host (CPU) + interpret-mode kernel-vs-oracle max error.  Wall-clock kernel
timing is only meaningful on real TPU; the CPU numbers track the substrate
the engine drives and catch regressions."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, *args, n=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def kernels(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    s = 256 if quick else 512

    # flash attention
    q = jax.random.normal(key, (1, s, 8, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, 64), jnp.float32)
    us = _time(flash_attention, q, k, v, use_kernel=False)
    kk = flash_attention(q, k, v, block_q=128, block_k=128)
    rr = flash_attention(q, k, v, use_kernel=False)
    err = float(np.abs(np.asarray(kk) - np.asarray(rr)).max())
    rows.append((f"kernels/flash_attention_s{s}", us, f"interp_max_err={err:.2e}"))

    # decode attention
    t = 2048 if quick else 8192
    q1 = jax.random.normal(key, (4, 8, 64), jnp.float32)
    k1 = jax.random.normal(jax.random.fold_in(key, 3), (4, t, 2, 64), jnp.float32)
    v1 = jax.random.normal(jax.random.fold_in(key, 4), (4, t, 2, 64), jnp.float32)
    pos = jnp.array([t - 1, t // 2, 7, t - 100], jnp.int32)
    us = _time(decode_attention, q1, k1, v1, pos, use_kernel=False)
    kk = decode_attention(q1, k1, v1, pos, block_k=512)
    rr = decode_attention(q1, k1, v1, pos, use_kernel=False)
    err = float(np.abs(np.asarray(kk) - np.asarray(rr)).max())
    rows.append((f"kernels/decode_attention_t{t}", us, f"interp_max_err={err:.2e}"))

    # ssd scan
    L = 512 if quick else 1024
    x = jax.random.normal(key, (1, L, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5), (1, L, 4)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (4,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(key, 7), (1, L, 32)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 8), (1, L, 32)) * 0.3
    us = _time(ssd_scan, x, dt, a, bm, cm, chunk=128, use_kernel=False)
    yk, hk = ssd_scan(x, dt, a, bm, cm, chunk=128)
    yr, hr = ssd_scan(x, dt, a, bm, cm, chunk=128, use_kernel=False)
    err = float(max(np.abs(np.asarray(yk) - np.asarray(yr)).max(),
                    np.abs(np.asarray(hk) - np.asarray(hr)).max()))
    rows.append((f"kernels/ssd_scan_L{L}", us, f"interp_max_err={err:.2e}"))
    return rows
