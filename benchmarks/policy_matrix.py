"""The apples-to-apples grid the unified API exists for: every retrieval
policy against every workload class, one simulator, one RunStats.

Policies:  busy-poll, metronome (adaptive), fixed-period, equal-timeouts.
Workloads: poisson (line rate), on/off bursty, trace replay (sped-up
timestamped trace with jitter — the pcap-sender model).

Rows report the paper's headline trade-off per cell: CPU fraction vs
mean/p99 retrieval latency vs loss.
"""

from __future__ import annotations

import numpy as np

from repro.core import MetronomeConfig
from repro.runtime import (
    BusyPollPolicy,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    MetronomePolicy,
    OnOffBurstyWorkload,
    PoissonWorkload,
    SimRunConfig,
    TraceReplayWorkload,
    simulate_run,
)

ROWS = list[tuple[str, float, str]]

LINE_RATE_MPPS = 14.88


def _synthetic_trace(n: int = 200_000, seed: int = 42) -> np.ndarray:
    """A trace with temporal structure (three phases: slow / burst / slow)
    so replay actually differs from a Poisson fit of the same mean."""
    rng = np.random.default_rng(seed)
    thirds = n // 3
    gaps = np.concatenate([
        rng.exponential(1 / 4.0, size=thirds),        # 4 Mpps
        rng.exponential(1 / 24.0, size=thirds),       # 24 Mpps burst
        rng.exponential(1 / 4.0, size=n - 2 * thirds),
    ])
    return np.cumsum(gaps)


def _policies():
    return [
        ("busy-poll", lambda: BusyPollPolicy()),
        ("metronome", lambda: MetronomePolicy(
            MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0))),
        ("fixed-50us", lambda: FixedPeriodPolicy(50.0, threads=1)),
        ("equal-timeouts", lambda: EqualTimeoutsPolicy(
            MetronomeConfig(m=3, v_target_us=10.0))),
    ]


def _workloads():
    trace = _synthetic_trace()
    return [
        ("poisson-line-rate", lambda: PoissonWorkload(LINE_RATE_MPPS)),
        ("onoff-bursty", lambda: OnOffBurstyWorkload(
            2 * LINE_RATE_MPPS, on_mean_us=3_000.0, off_mean_us=6_000.0)),
        ("trace-replay-x2-j10", lambda: TraceReplayWorkload(
            trace, speedup=2.0, jitter=0.10, loop=True)),
    ]


def _p50_us(r) -> float:
    if r.latency_override or not len(r.latency_us):
        return r.mean_latency_us          # analytic backends: no samples
    return float(np.percentile(np.asarray(r.latency_us), 50))


def matrix_policies_workloads(quick: bool = False) -> ROWS:
    dur = 100_000.0 if quick else 400_000.0
    rows = []
    for wname, wfn in _workloads():
        for pname, pfn in _policies():
            r = simulate_run(pfn(), wfn(),
                             SimRunConfig(duration_us=dur, seed=12))
            rows.append((f"matrix/{pname}/{wname}", r.mean_latency_us,
                         f"policy={r.policy};workload={r.workload};"
                         f"cpu={r.cpu_fraction:.3f};"
                         f"p50_lat_us={_p50_us(r):.2f};"
                         f"p99_lat_us={r.p99_latency_us:.2f};"
                         f"loss_pct={r.loss_fraction * 100:.3f};"
                         f"busy_tries={r.busy_tries};"
                         f"serviced={r.serviced}"))
    return rows
