"""Fixed-slot vs adaptive event-jump stepping, head to head.

The adaptive kernel's pitch is load-proportional cost: scan length
O(#wakes + #segments + #windows) instead of O(duration / slot_us), so a
lightly loaded sweep should need an order of magnitude fewer steps and
run several times faster — while reporting the same physics.  This
suite measures all three claims on the same grid at a ladder of loads
(T_S = 50us, T_L = 500us, M = 3, a batch of seeds per load):

  - ``stepping/rho<r>/step_ratio``  live fixed steps / live adaptive
    steps (plus the compiled scan lengths and forced-step count);
  - ``stepping/rho<r>/speedup``     execute-only wall-clock ratio,
    fixed / adaptive, each from the *second* call so compile time is
    excluded (first-call timings land in the derived fields);
  - ``stepping/rho<r>/parity``      |mean latency delta| between the
    two kernels, with the documented quiet bands
    (max(1.5us, 12%) latency, 0.02 + 5% CPU) and an in_band flag;
  - ``verdict/ok``                  every load in band AND the lowest
    load's step_ratio >= 3 (the CI smoke gate's floor; the full-size
    run demonstrates the >= 10x reduction recorded in BENCH_008).

CLI: ``python -m benchmarks.stepping [--smoke]`` — ``--smoke`` runs the
quick grid and exits nonzero if the adaptive kernel has fewer than 3x
fewer live steps at the low-load point or parity drifts out of band.
"""

from __future__ import annotations

import sys
import time

import numpy as np

ROWS = list[tuple[str, float, str]]

MU_MPPS = 29.76
RHOS = (0.2, 0.45, 0.7)
LOW_RHO = RHOS[0]
MIN_STEP_RATIO = 3.0        # smoke-gate floor at the low-load point
LAT_BAND_ABS_US = 1.5       # quiet parity bands (see batched_adaptive)
LAT_BAND_REL = 0.12
CPU_BAND_ABS = 0.02
CPU_BAND_REL = 0.05


def _grid(rho: float, quick: bool):
    from repro.runtime import SimRunConfig, SweepGrid
    from repro.runtime.simcore import HR_SLEEP_MODEL

    n_seeds = 16 if quick else 48
    duration = 60_000.0 if quick else 120_000.0
    pts = [dict(t_s_us=50.0, t_l_us=500.0, m=3, n_queues=2,
                rate_mpps=rho * MU_MPPS, seed=s) for s in range(n_seeds)]
    cfg = SimRunConfig(duration_us=duration, sleep_model=HR_SLEEP_MODEL)
    return SweepGrid.of_points(pts), cfg


def _timed_pair(grid, cfg, slot_us: float, stepping: str):
    """(stats, first_s, second_s): first call traces + compiles +
    executes, second is a compile-cache hit and times execution only."""
    from repro.runtime import simulate_batch

    t0 = time.time()
    bs = simulate_batch(grid, cfg, slot_us=slot_us, stepping=stepping)
    first = time.time() - t0
    t1 = time.time()
    simulate_batch(grid, cfg, slot_us=slot_us, stepping=stepping)
    second = time.time() - t1
    return bs, first, second


def stepping_compare(quick: bool = False) -> ROWS:
    slot_us = 0.5
    rows: ROWS = []
    verdict = True
    for rho in RHOS:
        grid, cfg = _grid(rho, quick)
        bf, f_first, f_second = _timed_pair(grid, cfg, slot_us, "fixed")
        ba, a_first, a_second = _timed_pair(grid, cfg, slot_us,
                                            "adaptive")

        steps_f = float(np.mean(bf.n_steps))
        steps_a = float(np.mean(ba.n_steps))
        step_ratio = steps_f / max(steps_a, 1.0)
        rows.append((
            f"stepping/rho{rho:.2f}/step_ratio", step_ratio,
            f"fixed_steps={steps_f:.0f};adaptive_steps={steps_a:.0f};"
            f"scan_fixed={bf.scan_len};scan_adaptive={ba.scan_len};"
            f"forced_steps={float(np.mean(ba.forced_steps)):.1f};"
            f"points={len(grid)};"
            f"slots_per_point={int(cfg.duration_us / slot_us)}"))

        speedup = f_second / max(a_second, 1e-9)
        rows.append((
            f"stepping/rho{rho:.2f}/speedup", speedup,
            f"fixed_execute_s={f_second:.3f};"
            f"adaptive_execute_s={a_second:.3f};"
            f"fixed_compile_s={max(f_first - f_second, 0.0):.2f};"
            f"adaptive_compile_s={max(a_first - a_second, 0.0):.2f}"))

        lat_f = float(np.mean(bf.mean_latency_us))
        lat_a = float(np.mean(ba.mean_latency_us))
        cpu_f = float(np.mean(bf.cpu_fraction))
        cpu_a = float(np.mean(ba.cpu_fraction))
        lat_band = max(LAT_BAND_ABS_US, LAT_BAND_REL * lat_f)
        cpu_band = CPU_BAND_ABS + CPU_BAND_REL * cpu_f
        in_band = (abs(lat_a - lat_f) <= lat_band
                   and abs(cpu_a - cpu_f) <= cpu_band)
        rows.append((
            f"stepping/rho{rho:.2f}/parity", abs(lat_a - lat_f),
            f"lat_fixed_us={lat_f:.2f};lat_adaptive_us={lat_a:.2f};"
            f"cpu_fixed={cpu_f:.4f};cpu_adaptive={cpu_a:.4f};"
            f"lat_band_us={lat_band:.2f};cpu_band={cpu_band:.4f};"
            f"in_band={in_band}"))

        verdict = verdict and in_band
        if rho == LOW_RHO:
            verdict = verdict and step_ratio >= MIN_STEP_RATIO

    rows.append(("verdict/ok", float(verdict), f"ok={verdict}"))
    return rows


def main() -> None:
    quick = "--smoke" in sys.argv or "--quick" in sys.argv
    rows = stepping_compare(quick=quick)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    if "--smoke" in sys.argv:
        ok = next(v for n, v, _ in rows if n == "verdict/ok")
        if not ok:
            print("SMOKE FAILED: adaptive stepping lost its step-count "
                  "advantage at low load or drifted out of the parity "
                  "bands", file=sys.stderr)
            sys.exit(1)
        print("# smoke ok", file=sys.stderr)


if __name__ == "__main__":
    main()
