"""Per-architecture smoke tests: reduced config, one forward + train step
+ prefill/decode on CPU; asserts output shapes and no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import Model

ARCHS = list_configs()


def _batch_for(cfg, model, b=2, s=32, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub" and cfg.frontend_len:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.frontend_len, cfg.d_model),
            dtype=jnp.float32)
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, s, cfg.d_model), dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    b, s = 2, 32
    batch = _batch_for(cfg, model, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    extra = cfg.frontend_len if cfg.frontend else 0
    assert logits.shape == (b, s + extra, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    if cfg.n_experts:
        assert jnp.isfinite(aux["moe_aux"]), arch
        assert aux["moe_aux"] >= 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_and_stays_finite(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1), max_seq=64)
    batch = _batch_for(cfg, model, 2, 16)
    labels = batch["tokens"]

    def loss_fn(p):
        logits, aux = model.forward(p, batch)
        extra = cfg.frontend_len if cfg.frontend else 0
        logits = logits[:, extra:, :]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux["moe_aux"]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch
    # one SGD step must change the loss (graph is actually differentiable)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert jnp.isfinite(loss2)
    assert abs(float(loss2) - float(loss)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forced decode after prefill must reproduce forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.frontend == "vision_stub":
        cfg = cfg  # prefix handled below
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2), max_seq=64)
    b, s = 2, 16
    batch = _batch_for(cfg, model, b, s, key=jax.random.PRNGKey(3))
    full_logits, _ = jax.jit(model.forward)(params, batch)

    # prefill on the first s-4 tokens, decode the next 4 teacher-forced
    split = s - 4
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :split]
    logits_p, cache = jax.jit(model.prefill)(params, pre_batch)
    extra = cfg.frontend_len if cfg.frontend else 0
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, :split + extra]),
        rtol=2e-2, atol=2e-2)

    # pad KV caches to full length for decode
    max_len = s + extra + 8

    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == split + extra:  # (G,B,S,..)
            pad_width = [(0, 0)] * leaf.ndim
            pad_width[2] = (0, max_len - leaf.shape[2])
            return jnp.pad(leaf, pad_width)
        return leaf

    if cfg.is_encdec:
        cache = {"self": jax.tree.map(pad, cache["self"]), "cross": cache["cross"]}
    else:
        cache = jax.tree.map(pad, cache)

    decode = jax.jit(model.decode_step)
    for i in range(split, s):
        tok = batch["tokens"][:, i]
        pos = jnp.full((b,), i + extra, jnp.int32)
        logits_d, cache = decode(params, tok, cache, pos)
        ref = full_logits[:, i + extra]
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                                   rtol=5e-2, atol=5e-2)


def test_scan_unit_structure():
    assert get_config("gemma2-2b").scan_unit() == 2
    assert get_config("jamba-1.5-large-398b").scan_unit() == 8
    assert get_config("mamba2-370m").scan_unit() == 1
    assert get_config("granite-3-8b").scan_unit() == 1
    plan = get_config("jamba-1.5-large-398b").layer_plan()
    assert plan[0] == ("attn", "moe")
    assert plan[1] == ("ssm", "dense")
    assert plan[2] == ("ssm", "moe")
    assert sum(1 for m, _ in plan if m == "attn") == 9     # 1:7 interleave


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
