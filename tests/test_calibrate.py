"""Calibration layer: operating-table construction, persistence,
controller feed-forward, serving integration, and the calibrated-vs-
fixed-baseline acceptance verdict."""

import numpy as np
import pytest

from repro.core import MetronomeConfig, MetronomeController
from repro.runtime import (
    MetronomePolicy,
    OperatingPoint,
    OperatingTable,
    SimRunConfig,
    build_operating_table,
)


def _tiny_table(**kw):
    args = dict(
        rhos=[0.15, 0.4, 0.65],
        target_mean_latency_us=15.0,
        t_s_grid=np.linspace(4.0, 48.0, 6),
        t_l_grid=[150.0, 500.0],
        m_grid=(2, 3),
        cfg=SimRunConfig(duration_us=30_000.0),
        seeds=(0,),
        slot_us=1.0,
    )
    args.update(kw)
    return build_operating_table(**args)


@pytest.fixture(scope="module")
def table():
    return _tiny_table()


def test_build_meets_target_and_scales_cpu_with_load(table):
    assert all(p.meets_target for p in table.points)
    assert all(p.mean_latency_us <= table.target_mean_latency_us
               for p in table.points)
    assert all(p.loss_fraction <= 1e-3 for p in table.points)
    cpus = [p.cpu_fraction for p in table.points]
    assert cpus == sorted(cpus)                  # more load, more CPU
    assert cpus[-1] < 1.0                        # still beats busy-poll


def test_spot_check_against_event_engine_passes():
    # same tiny grid, now cross-examined by the exact engine
    _tiny_table(spot_check=2)


def test_lookup_is_conservative_and_interp_clamps(table):
    lo, hi = table.points[0], table.points[-1]
    # below the ladder: governed by the lowest calibrated load
    assert table.lookup(0.0) == lo
    # between rungs: governed by the next rung UP (conservative)
    mid_rho = (table.points[0].rho + table.points[1].rho) / 2
    assert table.lookup(mid_rho) == table.points[1]
    # above the ladder: clamped to the top rung
    assert table.lookup(0.99) == hi
    # interpolation clamps outside the calibrated range
    assert table.timeouts_us(0.0) == (lo.t_s_us, lo.t_l_us)
    assert table.timeouts_us(1.0) == (hi.t_s_us, hi.t_l_us)
    t_s_mid, _ = table.timeouts_us(mid_rho)
    assert (min(lo.t_s_us, table.points[1].t_s_us) <= t_s_mid
            <= max(lo.t_s_us, table.points[1].t_s_us))


def test_json_roundtrip_and_save_load(table, tmp_path):
    assert OperatingTable.from_json(table.to_json()) == table
    path = tmp_path / "op_table.json"
    table.save(path)
    assert OperatingTable.load(path) == table


def test_points_sorted_and_validated():
    pts = (OperatingPoint(rho=0.7, t_s_us=10.0, t_l_us=500.0, m=3,
                          mean_latency_us=8.0, cpu_fraction=0.7,
                          loss_fraction=0.0),
           OperatingPoint(rho=0.2, t_s_us=40.0, t_l_us=500.0, m=2,
                          mean_latency_us=14.0, cpu_fraction=0.2,
                          loss_fraction=0.0))
    t = OperatingTable(target_mean_latency_us=15.0, service_rate_mpps=29.76,
                       points=pts)
    assert [p.rho for p in t.points] == [0.2, 0.7]
    with pytest.raises(ValueError):
        OperatingTable(target_mean_latency_us=15.0,
                       service_rate_mpps=29.76, points=())


# ---------------------------------------------------------------------------
# controller / policy / server integration
# ---------------------------------------------------------------------------

def _hand_table():
    return OperatingTable(
        target_mean_latency_us=15.0, service_rate_mpps=29.76,
        points=(
            OperatingPoint(rho=0.1, t_s_us=60.0, t_l_us=800.0, m=2,
                           mean_latency_us=12.0, cpu_fraction=0.1,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.9, t_s_us=10.0, t_l_us=400.0, m=3,
                           mean_latency_us=9.0, cpu_fraction=0.9,
                           loss_fraction=0.0),
        ))


def test_controller_feedforward_follows_table():
    tbl = _hand_table()
    cfg = MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0)
    ctl = MetronomeController(cfg, feedforward=tbl)
    # init at rho_init=0.5: the table's interpolated surface, not Eq 12
    ts_ff, tl_ff = tbl.timeouts_us(cfg.rho_init)
    assert ctl.t_short_us == pytest.approx(ts_ff)
    assert ctl.t_long_us == pytest.approx(tl_ff)
    # drive rho high: T_S slides toward the high-load rung
    for _ in range(200):
        ctl.on_cycle_end(busy_us=40.0, vacation_us=10.0)
    assert ctl.rho > 0.75
    ts_hi, tl_hi = tbl.timeouts_us(ctl.rho)
    assert ctl.t_short_us == pytest.approx(ts_hi)
    assert ctl.timeout_us(primary=False) == pytest.approx(tl_hi)
    # feed-forward beats the Eq-12 upper clamp at low load: the 60us
    # low-load rung survives even though resolved_ts_max() is 30us
    ctl2 = MetronomeController(cfg, feedforward=tbl)
    for _ in range(200):
        ctl2.on_cycle_end(busy_us=0.5, vacation_us=60.0)
    assert ctl2.t_short_us > cfg.resolved_ts_max()


def test_feedforward_weight_blends_back_to_eq12():
    tbl = _hand_table()
    cfg0 = MetronomeConfig(m=3, v_target_us=10.0, feedforward_weight=0.0)
    ctl = MetronomeController(cfg0, feedforward=tbl)
    plain = MetronomeController(MetronomeConfig(m=3, v_target_us=10.0))
    for c in (ctl, plain):
        c.on_cycle_end(busy_us=20.0, vacation_us=10.0)
    assert ctl.t_short_us == pytest.approx(plain.t_short_us)
    assert ctl.t_long_us == pytest.approx(cfg0.t_long_us)


def test_policy_carries_table_across_resets():
    tbl = _hand_table()
    pol = MetronomePolicy(MetronomeConfig(m=3, v_target_us=10.0),
                          operating_table=tbl)
    pol.reset()
    assert pol.controller.feedforward is tbl
    ts_ff, _ = tbl.timeouts_us(pol.controller.rho)
    assert pol.t_short_us == pytest.approx(ts_ff)


def test_server_loads_operating_table_at_startup(tmp_path):
    from repro.serving import Server

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    tbl = _hand_table()
    path = tmp_path / "table.json"
    tbl.save(path)
    pol = MetronomePolicy(MetronomeConfig(m=2, v_target_us=2_000.0,
                                          t_long_us=50_000.0))
    srv = Server(_NullEngine(), pol, operating_table=str(path))
    assert srv.operating_table == tbl
    assert pol.controller.feedforward == tbl
    ts_ff, _ = tbl.timeouts_us(pol.controller.rho)
    assert pol.controller.t_short_us == pytest.approx(ts_ff)
    # policies without a controller cannot take a table
    from repro.runtime import FixedPeriodPolicy
    with pytest.raises(ValueError, match="no .*controller"):
        Server(_NullEngine(), FixedPeriodPolicy(50.0), operating_table=tbl)


# ---------------------------------------------------------------------------
# acceptance verdict: calibrated beats (<=) the fixed-t_s baseline
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_frontier_verdict_calibrated_beats_fixed():
    """The benchmark's verdict row: per load, the calibrated table meets
    the latency target at CPU <= the best fixed configuration."""
    from benchmarks.sweep_frontier import sweep_frontier

    rows = {name: (val, derived)
            for name, val, derived in sweep_frontier(quick=True)}
    ok, derived = rows["verdict/ok"]
    assert ok == 1.0, rows.get("verdict/calibrated_vs_fixed_ts")
    _, vd = rows["verdict/calibrated_vs_fixed_ts"]
    assert "calibrated_leq_fixed_at_every_load=True" in vd
