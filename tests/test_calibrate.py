"""Calibration layer: operating-table construction, persistence,
controller feed-forward, serving integration, and the calibrated-vs-
fixed-baseline acceptance verdict."""

import numpy as np
import pytest

from repro.core import MetronomeConfig, MetronomeController
from repro.runtime import (
    MetronomePolicy,
    OperatingPoint,
    OperatingTable,
    SimRunConfig,
    build_operating_table,
)


def _tiny_table(**kw):
    args = dict(
        rhos=[0.15, 0.4, 0.65],
        target_mean_latency_us=15.0,
        t_s_grid=np.linspace(4.0, 48.0, 6),
        t_l_grid=[150.0, 500.0],
        m_grid=(2, 3),
        cfg=SimRunConfig(duration_us=30_000.0),
        seeds=(0,),
        slot_us=1.0,
    )
    args.update(kw)
    return build_operating_table(**args)


@pytest.fixture(scope="module")
def table():
    return _tiny_table()


def test_build_meets_target_and_scales_cpu_with_load(table):
    assert all(p.meets_target for p in table.points)
    assert all(p.mean_latency_us <= table.target_mean_latency_us
               for p in table.points)
    assert all(p.loss_fraction <= 1e-3 for p in table.points)
    cpus = [p.cpu_fraction for p in table.points]
    assert cpus == sorted(cpus)                  # more load, more CPU
    assert cpus[-1] < 1.0                        # still beats busy-poll


def test_spot_check_against_event_engine_passes():
    # same tiny grid, now cross-examined by the exact engine
    _tiny_table(spot_check=2)


def test_table_records_its_calibration_environment(table):
    """Tentpole: the table carries the SimRunConfig it was calibrated
    in, sleep model and interference knobs included, through JSON."""
    from repro.runtime import OperatingTable

    env = table.environment
    assert env is not None
    assert env["duration_us"] == 30_000.0
    assert env["interference_prob"] == 0.0
    assert env["stall_rate_per_us"] == 0.0
    assert "base_us" in env["sleep_model"]
    rt = OperatingTable.from_json(table.to_json())
    assert rt.environment == env
    assert rt == table


def test_fleet_calibration_shrinks_host_budget_and_records_fleet():
    """A fleet-aware table gives hosts a latency budget shrunk by the
    share-weighted topology delay at the fleet-aggregate peak rate, and
    records the FleetConfig in its environment (JSON-safe)."""
    from repro.runtime import FleetConfig, OperatingTable

    fleet = FleetConfig(n_hosts=8, far_fraction=0.5, near_cost_us=1.0,
                        far_cost_us=3.0, link_rate_mpps=200.0)
    table = _tiny_table(fleet=fleet)
    cfg = SimRunConfig(duration_us=30_000.0)
    topo = fleet.mean_topo_delay_us(0.65 * cfg.service_rate_mpps * 8)
    assert topo > 0.0
    assert table.target_mean_latency_us == pytest.approx(15.0 - topo)
    assert table.environment["fleet"]["n_hosts"] == 8
    assert table.environment["fleet"]["far_fraction"] == 0.5
    rt = OperatingTable.from_json(table.to_json())
    assert rt.environment["fleet"]["link_rate_mpps"] == 200.0
    # a topology that eats the whole budget is rejected loudly
    greedy = FleetConfig(n_hosts=8, far_fraction=1.0, far_cost_us=20.0)
    with pytest.raises(ValueError, match="latency target"):
        _tiny_table(fleet=greedy)


def test_noisy_host_calibration_is_contention_honest():
    """Tentpole: build_operating_table in an interference environment
    (a) records that environment, (b) spot-checks against the event
    engine WITHOUT quieting the config first, and (c) produces a table
    whose points reflect the noisy host (higher latency than the quiet
    table at the same grid/loads)."""
    noisy_cfg = SimRunConfig(duration_us=30_000.0,
                             interference_prob=0.2,
                             interference_mean_us=15.0,
                             stall_rate_per_us=1.0 / 5000.0,
                             stall_mean_us=100.0)
    noisy = _tiny_table(cfg=noisy_cfg, target_mean_latency_us=40.0,
                        max_loss=0.05, spot_check=2)
    assert noisy.environment["interference_prob"] == 0.2
    assert noisy.environment["stall_rate_per_us"] == 1.0 / 5000.0
    quiet = _tiny_table(target_mean_latency_us=40.0, max_loss=0.05)
    for np_, qp in zip(noisy.points, quiet.points):
        assert np_.mean_latency_us > qp.mean_latency_us


def test_spot_check_runs_in_the_calibration_environment(monkeypatch):
    """The event-engine spot check must see the caller's interference
    config — the old code laundered noisy tables through a quieted
    replace(cfg, interference_prob=0, stall_rate_per_us=0)."""
    from repro.runtime import calibrate as cal

    seen_cfgs = []
    real = cal._event_sim_point

    def spy(p, cfg, rate):
        seen_cfgs.append(cfg)
        return real(p, cfg, rate)

    monkeypatch.setattr(cal, "_event_sim_point", spy)
    noisy_cfg = SimRunConfig(duration_us=20_000.0,
                             interference_prob=0.15,
                             interference_mean_us=10.0,
                             stall_rate_per_us=1.0 / 8000.0,
                             stall_mean_us=80.0)
    _tiny_table(cfg=noisy_cfg, target_mean_latency_us=40.0,
                max_loss=0.05, spot_check=1)
    assert seen_cfgs, "spot check did not run"
    for c in seen_cfgs:
        assert c.interference_prob == 0.15
        assert c.stall_rate_per_us == 1.0 / 8000.0


def test_multi_queue_build_operating_table_regression():
    """Satellite bugfix: the analytic guard used a literal-[0] n_queues
    placeholder and the aggregate rho, so every multi-queue lattice was
    compared against the wrong closed form and wholesale-rejected
    (tables fell back to meets_target=False rows).  With the per-queue
    prediction, a plainly feasible multi-queue grid calibrates."""
    cfg = SimRunConfig(duration_us=30_000.0, n_queues=2)
    tbl = _tiny_table(cfg=cfg, target_mean_latency_us=25.0)
    assert all(p.meets_target for p in tbl.points)
    assert tbl.environment["n_queues"] == 2
    cpus = [p.cpu_fraction for p in tbl.points]
    assert cpus == sorted(cpus)


def test_guard_mask_uses_per_queue_load():
    """Direct unit check of the fixed meshgrid: at n_queues=nq the
    guard's prediction is nq * general(ts, tl, m, p(rho/nq))."""
    from repro.core import analytics
    from repro.runtime.calibrate import analytic_guard_mask

    ts, tl, m, rho, nq = 12.0, 300.0, 3, 0.6, 4
    pred_q = float(nq * analytics.mean_vacation_general(
        ts, tl, m, analytics.primary_prob(rho / nq)))
    vac = np.full((1, 1, 1, 1, 1), pred_q)
    ok = analytic_guard_mask(vac, [ts], [tl], [m], [rho],
                             guard_rel=0.05, slot_us=0.0, n_queues=(nq,))
    assert ok.all()
    # the aggregate-rho prediction (the old bug) is far outside the band
    pred_agg = float(analytics.mean_vacation_general(
        ts, tl, m, analytics.primary_prob(rho)))
    vac_bad = np.full((1, 1, 1, 1, 1), pred_agg)
    assert not analytic_guard_mask(vac_bad, [ts], [tl], [m], [rho],
                                   guard_rel=0.05, slot_us=0.0,
                                   n_queues=(nq,)).any()


def test_guard_mask_interference_slack_widens_band():
    cfg = SimRunConfig(interference_prob=0.25, interference_mean_us=20.0,
                       stall_rate_per_us=1.0 / 4000.0, stall_mean_us=100.0)
    slack = cfg.interference_slack_us()
    assert slack == pytest.approx(0.25 * 20.0 + 100.0 ** 2 / 4000.0)
    from repro.core import analytics
    from repro.runtime.calibrate import analytic_guard_mask

    ts, tl, m, rho = 10.0, 200.0, 2, 0.5
    pred = float(analytics.mean_vacation_general(
        ts, tl, m, analytics.primary_prob(rho)))
    # a measurement shifted by almost the whole slack passes only when
    # the slack is threaded through
    vac = np.full((1, 1, 1, 1, 1), pred * 1.05 + slack * 0.9)
    common = dict(guard_rel=0.05, slot_us=0.0)
    assert analytic_guard_mask(vac, [ts], [tl], [m], [rho],
                               slack_us=slack, **common).all()
    assert not analytic_guard_mask(vac, [ts], [tl], [m], [rho],
                                   **common).any()


def test_lookup_is_conservative_and_interp_clamps(table):
    lo, hi = table.points[0], table.points[-1]
    # below the ladder: governed by the lowest calibrated load
    assert table.lookup(0.0) == lo
    # between rungs: governed by the next rung UP (conservative)
    mid_rho = (table.points[0].rho + table.points[1].rho) / 2
    assert table.lookup(mid_rho) == table.points[1]
    # above the ladder: clamped to the top rung
    assert table.lookup(0.99) == hi
    # interpolation clamps outside the calibrated range
    assert table.timeouts_us(0.0) == (lo.t_s_us, lo.t_l_us)
    assert table.timeouts_us(1.0) == (hi.t_s_us, hi.t_l_us)
    t_s_mid, _ = table.timeouts_us(mid_rho)
    assert (min(lo.t_s_us, table.points[1].t_s_us) <= t_s_mid
            <= max(lo.t_s_us, table.points[1].t_s_us))


def test_json_roundtrip_and_save_load(table, tmp_path):
    assert OperatingTable.from_json(table.to_json()) == table
    path = tmp_path / "op_table.json"
    table.save(path)
    assert OperatingTable.load(path) == table


def test_points_sorted_and_validated():
    pts = (OperatingPoint(rho=0.7, t_s_us=10.0, t_l_us=500.0, m=3,
                          mean_latency_us=8.0, cpu_fraction=0.7,
                          loss_fraction=0.0),
           OperatingPoint(rho=0.2, t_s_us=40.0, t_l_us=500.0, m=2,
                          mean_latency_us=14.0, cpu_fraction=0.2,
                          loss_fraction=0.0))
    t = OperatingTable(target_mean_latency_us=15.0, service_rate_mpps=29.76,
                       points=pts)
    assert [p.rho for p in t.points] == [0.2, 0.7]
    with pytest.raises(ValueError):
        OperatingTable(target_mean_latency_us=15.0,
                       service_rate_mpps=29.76, points=())


# ---------------------------------------------------------------------------
# controller / policy / server integration
# ---------------------------------------------------------------------------

def _hand_table():
    return OperatingTable(
        target_mean_latency_us=15.0, service_rate_mpps=29.76,
        points=(
            OperatingPoint(rho=0.1, t_s_us=60.0, t_l_us=800.0, m=2,
                           mean_latency_us=12.0, cpu_fraction=0.1,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.9, t_s_us=10.0, t_l_us=400.0, m=3,
                           mean_latency_us=9.0, cpu_fraction=0.9,
                           loss_fraction=0.0),
        ))


def test_controller_feedforward_follows_table():
    tbl = _hand_table()
    cfg = MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0)
    ctl = MetronomeController(cfg, feedforward=tbl)
    # init at rho_init=0.5: the table's interpolated surface, not Eq 12
    ts_ff, tl_ff = tbl.timeouts_us(cfg.rho_init)
    assert ctl.t_short_us == pytest.approx(ts_ff)
    assert ctl.t_long_us == pytest.approx(tl_ff)
    # drive rho high: T_S slides toward the high-load rung
    for _ in range(200):
        ctl.on_cycle_end(busy_us=40.0, vacation_us=10.0)
    assert ctl.rho > 0.75
    ts_hi, tl_hi = tbl.timeouts_us(ctl.rho)
    assert ctl.t_short_us == pytest.approx(ts_hi)
    assert ctl.timeout_us(primary=False) == pytest.approx(tl_hi)
    # feed-forward beats the Eq-12 upper clamp at low load: the 60us
    # low-load rung survives even though resolved_ts_max() is 30us
    ctl2 = MetronomeController(cfg, feedforward=tbl)
    for _ in range(200):
        ctl2.on_cycle_end(busy_us=0.5, vacation_us=60.0)
    assert ctl2.t_short_us > cfg.resolved_ts_max()


def test_controller_clamps_tl_above_ts_with_adversarial_table():
    """Satellite bugfix: a calibrated rung whose T_L is below T_S (or a
    pathological blend) must not invert the backup/primary roles —
    backups would fire before primaries.  The controller clamps
    T_L >= T_S at every derivation, and releases the clamp once T_S
    falls again."""
    adversarial = OperatingTable(
        target_mean_latency_us=15.0, service_rate_mpps=29.76,
        points=(
            # low-load rung: huge T_S, tiny T_L — inverted on purpose
            OperatingPoint(rho=0.1, t_s_us=120.0, t_l_us=8.0, m=2,
                           mean_latency_us=12.0, cpu_fraction=0.1,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.9, t_s_us=10.0, t_l_us=400.0, m=3,
                           mean_latency_us=9.0, cpu_fraction=0.9,
                           loss_fraction=0.0),
        ))
    cfg = MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0)
    ctl = MetronomeController(cfg, feedforward=adversarial)
    # drive rho low: the table feeds T_S=120, T_L=8 — the clamp holds
    for _ in range(300):
        ctl.on_cycle_end(busy_us=0.5, vacation_us=100.0)
        assert ctl.t_long_us >= ctl.t_short_us
        assert (ctl.timeout_us(primary=False)
                >= ctl.timeout_us(primary=True))
    assert ctl.t_short_us > 100.0          # the inverted rung is active
    # back at high load the table is sane again and the clamp releases:
    # T_L returns to the table's 400us rung, well above T_S
    for _ in range(300):
        ctl.on_cycle_end(busy_us=40.0, vacation_us=10.0)
    assert ctl.rho > 0.75
    assert ctl.t_long_us > 4 * ctl.t_short_us
    assert ctl.t_long_us >= ctl.t_short_us
    # the clamp also guards the pure-Eq-12 path (no table): a config
    # with T_L below the Eq-12 T_S band cannot invert either
    ctl2 = MetronomeController(
        MetronomeConfig(m=3, v_target_us=200.0, t_long_us=50.0))
    for _ in range(50):
        ctl2.on_cycle_end(busy_us=0.5, vacation_us=300.0)
        assert ctl2.t_long_us >= ctl2.t_short_us


def test_feedforward_weight_blends_back_to_eq12():
    tbl = _hand_table()
    cfg0 = MetronomeConfig(m=3, v_target_us=10.0, feedforward_weight=0.0)
    ctl = MetronomeController(cfg0, feedforward=tbl)
    plain = MetronomeController(MetronomeConfig(m=3, v_target_us=10.0))
    for c in (ctl, plain):
        c.on_cycle_end(busy_us=20.0, vacation_us=10.0)
    assert ctl.t_short_us == pytest.approx(plain.t_short_us)
    assert ctl.t_long_us == pytest.approx(cfg0.t_long_us)


def test_policy_carries_table_across_resets():
    tbl = _hand_table()
    pol = MetronomePolicy(MetronomeConfig(m=3, v_target_us=10.0),
                          operating_table=tbl)
    pol.reset()
    assert pol.controller.feedforward is tbl
    ts_ff, _ = tbl.timeouts_us(pol.controller.rho)
    assert pol.t_short_us == pytest.approx(ts_ff)


def test_server_loads_operating_table_at_startup(tmp_path):
    from repro.serving import Server

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    tbl = _hand_table()
    path = tmp_path / "table.json"
    tbl.save(path)
    pol = MetronomePolicy(MetronomeConfig(m=2, v_target_us=2_000.0,
                                          t_long_us=50_000.0))
    srv = Server(_NullEngine(), pol, operating_table=str(path))
    assert srv.operating_table == tbl
    assert pol.controller.feedforward == tbl
    ts_ff, _ = tbl.timeouts_us(pol.controller.rho)
    assert pol.controller.t_short_us == pytest.approx(ts_ff)
    # policies without a controller cannot take a table
    from repro.runtime import FixedPeriodPolicy
    with pytest.raises(ValueError, match="no .*controller"):
        Server(_NullEngine(), FixedPeriodPolicy(50.0), operating_table=tbl)


# ---------------------------------------------------------------------------
# acceptance verdict: calibrated beats (<=) the fixed-t_s baseline
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_frontier_verdict_calibrated_beats_fixed():
    """The benchmark's verdict row: per load, the calibrated table meets
    the latency target at CPU <= the best fixed configuration."""
    from benchmarks.sweep_frontier import sweep_frontier

    rows = {name: (val, derived)
            for name, val, derived in sweep_frontier(quick=True)}
    ok, derived = rows["verdict/ok"]
    assert ok == 1.0, rows.get("verdict/calibrated_vs_fixed_ts")
    _, vd = rows["verdict/calibrated_vs_fixed_ts"]
    assert "calibrated_leq_fixed_at_every_load=True" in vd
