"""Batched-vs-event parity under *nonstationary* load — the scheduled
counterpart of the PR 3 (quiet) and PR 4 (interference) parity pins.

Each pinned configuration draws a random static (T_S, T_L, M) operating
point AND a random load schedule (step / ramp / sinusoid); the batched
engine evaluates the schedule per slot while the event engine time-warps
the workload, and the two must agree within the explicit bands below on
aggregate mean sojourn, CPU fraction, loss, and the windowed offered-
rate trajectory (which also proves both engines saw the *same*
schedule).

Documented tolerance bands (scheduled, n_queues=1, peak rho <= 0.85):

  - quiet host: mean sojourn within max(1.5us, 12%); CPU within
    0.02 + 5%; loss both ~0; per-window offered rate within 8% of the
    event engine's peak window (observed: ~0.4us / ~3% lat, ~0.004 CPU,
    ~2% offered);
  - interference (per-wake delays AND correlated stalls): mean sojourn
    within max(5.0us, 25%); CPU within 0.025 + 6%; loss within 0.03
    absolute; offered within 15% of peak (observed: ~3.7us / ~17% lat,
    ~0.008 CPU, ~8% offered).
"""

from dataclasses import replace

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    MetronomePolicy,
    PoissonWorkload,
    RampSchedule,
    SimRunConfig,
    SinusoidSchedule,
    StepSchedule,
    SweepGrid,
    simulate_batch,
    simulate_run,
)
from repro.runtime.simcore import HR_SLEEP_MODEL

# quiet-host scheduled parity bands
SLAT_ABS_US, SLAT_REL = 1.5, 0.12
SCPU_ABS, SCPU_REL = 0.02, 0.05
SOFF_REL = 0.08
# interference scheduled parity bands
ISLAT_ABS_US, ISLAT_REL = 5.0, 0.25
ISCPU_ABS, ISCPU_REL = 0.025, 0.06
ISLOSS_ABS = 0.03
ISOFF_REL = 0.15

INTERFERENCE_ENV = dict(interference_prob=0.25, interference_mean_us=20.0,
                        stall_rate_per_us=1.0 / 4000.0, stall_mean_us=150.0)

DURATION_US = 100_000.0
WINDOW_US = 5_000.0


def _random_schedule(rng, dur):
    kind = int(rng.integers(3))
    lo = float(rng.uniform(0.25, 0.6))
    hi = float(rng.uniform(1.0, 1.4))
    if rng.random() < 0.5:
        lo, hi = hi, lo
    if kind == 0:
        return StepSchedule(times_us=(0.0, float(rng.uniform(0.3, 0.7))
                                      * dur), scales=(lo, hi))
    if kind == 1:
        return RampSchedule(t_start_us=float(rng.uniform(0.2, 0.4)) * dur,
                            t_end_us=float(rng.uniform(0.6, 0.8)) * dur,
                            scale_from=lo, scale_to=hi)
    return SinusoidSchedule(period_us=dur / float(rng.integers(2, 6)),
                            amplitude=float(rng.uniform(0.2, 0.4)),
                            mean=float(rng.uniform(0.6, 0.9)))


def _scheduled_configs(n, seed):
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        t_s = float(rng.uniform(5.0, 40.0))
        sched = _random_schedule(rng, DURATION_US)
        # keep peak rho <= 0.85 whatever the schedule's max scale is
        smax = float(np.max(sched.segments(DURATION_US)[1]))
        rate = float(rng.uniform(0.15, 0.85)) * 29.76 / max(smax, 1.0)
        pts.append(dict(t_s_us=t_s,
                        t_l_us=float(t_s * rng.uniform(4.0, 25.0)),
                        m=int(rng.integers(1, 5)), rate_mpps=rate,
                        seed=i, schedule=sched))
    return pts


def _event_twin(p, cfg):
    policy = MetronomePolicy(
        MetronomeConfig(m=p["m"], v_target_us=p["t_s_us"],
                        t_long_us=p["t_l_us"],
                        ts_min_us=min(1.0, p["t_s_us"])),
        adaptive=False)
    ecfg = replace(cfg, schedule=p["schedule"])
    return simulate_run(policy, PoissonWorkload(p["rate_mpps"]), ecfg)


def _assert_windows_match(wb, we, off_rel, label):
    """Both engines must have seen the same offered-load trajectory."""
    assert wb.n_windows == we.n_windows
    peak = max(float(we.offered_mpps.max()), 1e-9)
    diff = np.max(np.abs(wb.offered_mpps - we.offered_mpps))
    assert diff <= off_rel * peak, (label, diff, peak)


@pytest.mark.slow
def test_scheduled_parity_quiet_12_random_configs():
    """>= 12 random (static point x schedule) configs on a quiet host:
    batched and event engines agree within the scheduled quiet bands,
    and their windowed offered-rate series coincide."""
    pts = _scheduled_configs(n=12, seed=42)
    cfg = SimRunConfig(duration_us=DURATION_US, sleep_model=HR_SLEEP_MODEL,
                       window_us=WINDOW_US)
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5)
    assert {p["schedule"].name for p in pts} >= {"step", "ramp",
                                                 "sinusoid"}
    for i, p in enumerate(pts):
        rs = _event_twin(p, cfg)
        lat_b, lat_e = float(bs.mean_latency_us[i]), rs.mean_sojourn_us
        cpu_b, cpu_e = float(bs.cpu_fraction[i]), rs.cpu_fraction
        assert abs(lat_b - lat_e) <= max(SLAT_ABS_US, SLAT_REL * lat_e), \
            (p, lat_b, lat_e)
        assert abs(cpu_b - cpu_e) <= SCPU_ABS + SCPU_REL * cpu_e, \
            (p, cpu_b, cpu_e)
        assert float(bs.loss_fraction[i]) < 1e-3
        assert rs.loss_fraction < 1e-3
        _assert_windows_match(bs.windows(i), rs.windows, SOFF_REL, p)
        # the shared TrackingStats path runs on both backends' series
        trans = p["schedule"].transitions(DURATION_US)
        tb = bs.windows(i).tracking(trans, 1e9)
        te = rs.windows.tracking(trans, 1e9)
        assert tb.violation_fraction == te.violation_fraction == 0.0


@pytest.mark.slow
def test_scheduled_parity_interference_10_random_configs():
    """>= 10 random scheduled configs on a noisy shared host (per-wake
    interference AND correlated stalls): agreement within the widened
    scheduled interference bands."""
    pts = _scheduled_configs(n=10, seed=7)
    cfg = SimRunConfig(duration_us=DURATION_US, sleep_model=HR_SLEEP_MODEL,
                       window_us=WINDOW_US, **INTERFERENCE_ENV)
    assert cfg.is_noisy
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5)
    for i, p in enumerate(pts):
        rs = _event_twin(p, cfg)
        lat_b, lat_e = float(bs.mean_latency_us[i]), rs.mean_sojourn_us
        cpu_b, cpu_e = float(bs.cpu_fraction[i]), rs.cpu_fraction
        assert abs(lat_b - lat_e) <= max(ISLAT_ABS_US, ISLAT_REL * lat_e), \
            (p, lat_b, lat_e)
        assert abs(cpu_b - cpu_e) <= ISCPU_ABS + ISCPU_REL * cpu_e, \
            (p, cpu_b, cpu_e)
        assert abs(float(bs.loss_fraction[i]) - rs.loss_fraction) \
            <= ISLOSS_ABS, (p, float(bs.loss_fraction[i]),
                            rs.loss_fraction)
        _assert_windows_match(bs.windows(i), rs.windows, ISOFF_REL, p)


def test_scheduled_parity_smoke_two_configs():
    """Tier-1 guard: a tiny scheduled batched-vs-event comparison (wide
    bands) so the scheduled code path cannot silently break between
    slow-tier runs."""
    dur = 30_000.0
    sched = StepSchedule(times_us=(0.0, 15_000.0), scales=(0.5, 1.2))
    pts = [dict(t_s_us=12.0, t_l_us=300.0, m=3, rate_mpps=0.5 * 29.76,
                seed=0, schedule=sched),
           dict(t_s_us=20.0, t_l_us=400.0, m=2, rate_mpps=0.4 * 29.76,
                seed=1, schedule=sched)]
    cfg = SimRunConfig(duration_us=dur, window_us=3_000.0)
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=1.0)
    for i, p in enumerate(pts):
        rs = _event_twin(p, cfg)
        lat_b, lat_e = float(bs.mean_latency_us[i]), rs.mean_sojourn_us
        assert abs(lat_b - lat_e) <= max(3.0, 0.25 * lat_e)
        assert abs(float(bs.cpu_fraction[i]) - rs.cpu_fraction) \
            <= 0.03 + 0.08 * rs.cpu_fraction
        _assert_windows_match(bs.windows(i), rs.windows, 0.15, p)
