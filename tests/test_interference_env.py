"""Dedicated coverage for the CPU-sharing environment model: the event
engine's per-wake interference and correlated-stall paths
(repro.runtime.sim) and SleepModel tail sampling (repro.runtime.simcore)
— golden-pinned directional effects at fixed seed."""

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    MetronomePolicy,
    PoissonWorkload,
    SimRunConfig,
    simulate_run,
)
from repro.runtime.simcore import HR_SLEEP_MODEL, SleepModel


def _run(cfg):
    policy = MetronomePolicy(
        MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0),
        adaptive=False)
    return simulate_run(policy, PoissonWorkload(0.7 * 29.76), cfg)


def _cfg(**kw):
    base = dict(duration_us=200_000.0, queue_capacity=256, seed=11,
                sleep_model=HR_SLEEP_MODEL)
    base.update(kw)
    return SimRunConfig(**base)


def test_per_wake_interference_strictly_raises_vacation_and_loss():
    """sim.py's interference branch: Bernoulli x Exp per-wake delays
    strictly increase mean vacation AND loss over the quiet baseline at
    the same seed (the queue sized so the delays actually overflow)."""
    quiet = _run(_cfg())
    noisy = _run(_cfg(interference_prob=0.3, interference_mean_us=120.0))
    assert noisy.mean_vacation_us > quiet.mean_vacation_us
    assert noisy.loss_fraction > quiet.loss_fraction
    assert noisy.mean_sojourn_us > quiet.mean_sojourn_us


def test_correlated_stalls_strictly_raise_vacation_and_loss():
    """sim.py's stall-window branch: system-wide freeze windows defer
    every wake inside them — vacations stretch and the ring overflows,
    strictly above the quiet baseline at the same seed."""
    quiet = _run(_cfg())
    stalled = _run(_cfg(stall_rate_per_us=1.0 / 4_000.0,
                        stall_mean_us=300.0))
    assert stalled.mean_vacation_us > quiet.mean_vacation_us
    assert stalled.loss_fraction > quiet.loss_fraction
    # deferred wakes are not charged: the stalled run wakes *less*
    assert stalled.wakeups < quiet.wakeups


def test_interference_and_stalls_compose():
    """Both injections together are worse than either alone (same seed,
    same workload) — the noisy-shared-host worst case."""
    intf = _run(_cfg(interference_prob=0.3, interference_mean_us=120.0))
    stall = _run(_cfg(stall_rate_per_us=1.0 / 4_000.0, stall_mean_us=300.0))
    both = _run(_cfg(interference_prob=0.3, interference_mean_us=120.0,
                     stall_rate_per_us=1.0 / 4_000.0, stall_mean_us=300.0))
    assert both.loss_fraction > max(intf.loss_fraction, stall.loss_fraction)
    assert both.mean_vacation_us > max(intf.mean_vacation_us,
                                       stall.mean_vacation_us)


# ---------------------------------------------------------------------------
# SleepModel tail sampling (simcore.py)
# ---------------------------------------------------------------------------

def test_sleep_model_tail_adds_exp_mass():
    """Golden-pinned at fixed rng: the Bernoulli x Exp tail arm adds
    ~tail_prob * tail_mean to the mean overshoot and produces samples
    far beyond the Gaussian arm's reach."""
    base = SleepModel(base_us=2.8, slope=0.027, sigma_us=0.5)
    tailed = SleepModel(base_us=2.8, slope=0.027, sigma_us=0.5,
                        tail_prob=0.05, tail_mean_us=400.0)
    targets = np.full(200_000, 50.0)
    plain = base.sample(targets, np.random.default_rng(3))
    heavy = tailed.sample(targets, np.random.default_rng(3))
    extra = float(np.mean(heavy) - np.mean(plain))
    assert extra == pytest.approx(0.05 * 400.0, rel=0.1)
    # the tail reaches multi-hundred-us; the Gaussian arm never does
    assert float(np.max(heavy)) > 1_000.0
    assert float(np.max(plain)) < 50.0 * 1.1 + 2.8 + 10 * 0.5


def test_sleep_model_certain_tail_mean_is_pinned():
    """tail_prob=1: every sample carries one Exp(tail_mean) draw, so the
    mean overshoot is base + slope*t + E|N| + tail_mean."""
    m = SleepModel(base_us=5.0, slope=0.0, sigma_us=0.0,
                   tail_prob=1.0, tail_mean_us=200.0)
    s = m.sample(np.full(100_000, 10.0), np.random.default_rng(9))
    assert float(np.mean(s)) == pytest.approx(10.0 + 5.0 + 200.0, rel=0.02)
    assert float(np.min(s)) >= 15.0


def test_sleep_model_no_tail_is_affine_plus_halfnormal():
    m = SleepModel(base_us=2.0, slope=0.1, sigma_us=1.0)
    s = m.sample(np.full(100_000, 20.0), np.random.default_rng(4))
    # mean = t + base + slope*t + sigma*sqrt(2/pi)
    expect = 20.0 + 2.0 + 2.0 + 1.0 * np.sqrt(2.0 / np.pi)
    assert float(np.mean(s)) == pytest.approx(expect, rel=0.01)
    assert float(np.min(s)) >= 24.0
