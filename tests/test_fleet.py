"""Fleet engine: parity vs the single-host engines, hedging sanity,
LB/topology direction, device sharding, and the compile cache — the
acceptance criteria of the fleet-scale batched-simulation tier.

The strongest pin is bit-exactness: under uniform round-robin with
topology and hedging off, host ``h`` of a fleet row seeded ``s`` IS the
single-host batched kernel at ``rate/H`` seeded ``s + h`` (same PRNG
stream by construction), so fleet-vs-event parity inherits the batched
engine's documented bands rather than needing new ones.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
fleet-smoke job does) to exercise the shard_map path for real; on one
device the ``shard=True`` parametrizations degenerate to pure vmap and
still must agree.
"""

import logging

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    FleetConfig,
    FleetGrid,
    MetronomePolicy,
    Reservoir,
    RunStats,
    SimRunConfig,
    SweepGrid,
    fleet_tail_reference,
    hedged_latency_quantile,
    simulate_batch,
    simulate_fleet,
    simulate_fleet_run,
)

# the batched engine's documented quiet-region parity bands
# (tests/test_batched_engine.py pins them engine-vs-engine; the fleet
# inherits them through per-host bit-exactness)
LAT_ABS_US, LAT_REL = 1.5, 0.12
CPU_ABS, CPU_REL = 0.02, 0.05

MU = 29.76


def _fgrid(fleet, *, rate_per_host=0.4 * MU, hedge=(0.0,), seeds=(3,),
           t_s=12.0):
    return FleetGrid.product(
        fleet=fleet, t_s_us=(t_s,), t_l_us=(500.0,), m=(3,),
        rate_mpps=(rate_per_host * fleet.n_hosts,), seeds=seeds,
        hedge_deadline_us=hedge)


# ---------------------------------------------------------------------------
# FleetConfig / FleetGrid surface
# ---------------------------------------------------------------------------

def test_fleet_config_validates():
    with pytest.raises(ValueError):
        FleetConfig(n_hosts=0).validate()
    with pytest.raises(ValueError):
        FleetConfig(n_hosts=2, lb="magic").validate()
    with pytest.raises(ValueError):
        FleetConfig(n_hosts=3, lb="weighted",
                    host_weights=(1.0, 2.0)).validate()
    with pytest.raises(ValueError):
        FleetConfig(n_hosts=2, far_fraction=1.5).validate()
    f = FleetConfig(n_hosts=4, lb="weighted",
                    host_weights=(1.0, 1.0, 2.0, 4.0)).validate()
    assert f.shares() == pytest.approx([0.125, 0.125, 0.25, 0.5])
    assert FleetConfig(n_hosts=4, far_fraction=0.5).far_hosts() == 2


def test_fleet_grid_product_and_points():
    fleet = FleetConfig(n_hosts=8)
    fg = FleetGrid.product(fleet=fleet, t_s_us=(8.0, 16.0),
                           t_l_us=(500.0,), rate_mpps=(40.0,),
                           hedge_deadline_us=(0.0, 25.0))
    assert len(fg) == 4
    assert fg.shape == (2, 1, 1, 1, 1, 1, 2)
    p = fg.point(1)
    assert p["hedge_deadline_us"] == 25.0
    assert p["n_hosts"] == 8 and p["lb"] == "uniform"
    fg2 = FleetGrid.of_points(
        [dict(t_s_us=8.0, t_l_us=500.0, rate_mpps=40.0,
              hedge_deadline_us=30.0),
         dict(t_s_us=16.0, t_l_us=500.0, rate_mpps=40.0)],
        fleet=fleet)
    assert list(fg2.hedge_deadline_us) == [30.0, 0.0]


# ---------------------------------------------------------------------------
# Parity: fleet host h == single-host batched run seeded s + h (exact),
# and == merged event-engine hosts (within the documented bands)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shard", [False, True])
def test_fleet_hosts_bit_exact_vs_single_host_batched(shard):
    """Uniform RR, no topology, no hedging: every fleet host replays the
    single-host batched kernel at rate/H with seed s+h, bit for bit."""
    H, seed, rate_h = 4, 11, 0.45 * MU
    cfg = SimRunConfig(duration_us=30_000.0)
    fs = simulate_fleet(_fgrid(FleetConfig(n_hosts=H),
                               rate_per_host=rate_h, seeds=(seed,)),
                        cfg, slot_us=1.0, shard=shard)
    bs = simulate_batch(
        SweepGrid.of_points([dict(t_s_us=12.0, t_l_us=500.0, m=3,
                                  rate_mpps=rate_h, seed=seed + h)
                             for h in range(H)]),
        cfg, slot_us=1.0)
    np.testing.assert_array_equal(fs.serviced[0], bs.serviced)
    np.testing.assert_array_equal(fs.lat_area[0], bs.lat_area)
    np.testing.assert_array_equal(fs.awake_us[0], bs.awake_us)
    assert float(fs.topo_area[0].sum()) == 0.0
    assert float(fs.hedge_dup[0].sum()) == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("shard", [False, True])
def test_fleet_matches_merged_event_engine_hosts(shard):
    """A uniform-RR fleet of k identical hosts agrees with the n-way
    ``RunStats.merge_all`` of k event-engine runs at rate/k (seeds
    s..s+k-1) within the quiet parity bands."""
    H, seed, rate_h = 4, 5, 0.4 * MU
    cfg = SimRunConfig(duration_us=60_000.0)
    fs = simulate_fleet(_fgrid(FleetConfig(n_hosts=H),
                               rate_per_host=rate_h, seeds=(seed,)),
                        cfg, slot_us=0.5, shard=shard)

    hosts = simulate_fleet_run(
        lambda h: MetronomePolicy(
            MetronomeConfig(m=3, v_target_us=12.0, t_long_us=500.0,
                            ts_min_us=1.0),
            adaptive=False),
        rate_h * H, cfg, FleetConfig(n_hosts=H))
    merged = hosts[0].merge_all(hosts[1:])

    lat_f, lat_e = float(fs.mean_latency_us[0]), merged.mean_sojourn_us
    assert abs(lat_f - lat_e) <= max(LAT_ABS_US, LAT_REL * lat_e), \
        (lat_f, lat_e)
    # both sides' CPU is fleet-total cores (merge sums awake time over
    # hosts at a fixed wall-clock duration)
    cpu_f, cpu_e = float(fs.total_cpu_cores[0]), merged.cpu_fraction
    assert abs(cpu_f - cpu_e) <= H * (CPU_ABS + CPU_REL * cpu_e / H), \
        (cpu_f, cpu_e)
    assert float(fs.loss_fraction[0]) < 1e-3
    assert merged.loss_fraction < 1e-3


@pytest.mark.parametrize("n_points", [1, 6])
def test_shard_path_matches_vmap_path(n_points):
    """shard=True and shard=False produce identical results (including
    when the point count does not divide the device count — padding)."""
    fleet = FleetConfig(n_hosts=3)
    fg = FleetGrid.product(
        fleet=fleet, t_s_us=tuple(8.0 + 2.0 * i for i in range(n_points)),
        t_l_us=(400.0,), rate_mpps=(0.4 * MU * 3,),
        hedge_deadline_us=(30.0,))
    cfg = SimRunConfig(duration_us=10_000.0)
    a = simulate_fleet(fg, cfg, slot_us=1.0, shard=False)
    b = simulate_fleet(fg, cfg, slot_us=1.0, shard=True)
    for f in ("serviced", "lat_area", "awake_us", "hedge_dup"):
        np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                   rtol=1e-6, atol=1e-3, err_msg=f)


# ---------------------------------------------------------------------------
# Hedging
# ---------------------------------------------------------------------------

def test_hedging_tightening_deadline_tail_and_cost():
    """On the noisy cluster, tightening the hedge deadline (above the
    drain-time scale) drives p99.9 monotonically down while the offered
    load including duplicates rises strictly — the tail/cost trade."""
    cfg = SimRunConfig(duration_us=30_000.0, stall_rate_per_us=2.5e-4,
                       stall_mean_us=150.0)
    fs = simulate_fleet(_fgrid(FleetConfig(n_hosts=8),
                               hedge=(0.0, 80.0, 40.0, 20.0)),
                        cfg, slot_us=1.0)
    p999 = fs.p999_latency_us
    offered = fs.offered_with_hedges
    assert np.all(np.diff(p999) <= 1e-9), p999
    assert p999[-1] < 0.5 * p999[0], p999
    assert np.all(np.diff(offered) > 0), offered


def test_hedge_deadline_zero_leaves_dynamics_untouched():
    cfg = SimRunConfig(duration_us=10_000.0)
    a = simulate_fleet(_fgrid(FleetConfig(n_hosts=4), hedge=(0.0,)),
                       cfg, slot_us=1.0)
    b = simulate_fleet(_fgrid(FleetConfig(n_hosts=4), hedge=(-5.0,)),
                       cfg, slot_us=1.0)
    np.testing.assert_array_equal(a.serviced, b.serviced)
    assert float(a.hedge_dup.sum()) == 0.0


def test_hedged_quantile_closed_form_pinned_against_exact_mc():
    """``hedged_latency_quantile`` vs the exact first-completion-wins
    reference on hosts whose latency IS the model's mixture: within 8%
    at p99/p99.9 across the deadline ladder."""
    rng = np.random.default_rng(42)
    H, N = 3, 60_000
    L = np.array([8.0, 12.0, 10.0])
    p, c = 0.05, 120.0
    hosts = []
    for h in range(H):
        tail = rng.random(N) < p
        lat = rng.exponential(L[h], N)
        lat[tail] = rng.exponential(L[h] + c, tail.sum())
        res = Reservoir(capacity=N, seed=h)
        res.extend(lat)
        hosts.append(RunStats(backend="synthetic", items=N, offered=N,
                              awake_ns=int(1e9), latency_us=res))
    fleet = FleetConfig(n_hosts=H)
    for d in (0.0, 150.0, 60.0, 25.0):
        mc = fleet_tail_reference(hosts, fleet, d, n_samples=400_000,
                                  seed=9)
        for q in (0.99, 0.999):
            emp = float(np.percentile(mc, 100 * q))
            ana = hedged_latency_quantile(q, L, hedge_deadline_us=d,
                                          tail_prob=p, tail_scale_us=c)
            assert abs(emp - ana) <= 0.08 * ana, (d, q, emp, ana)


def test_hedged_quantile_monotone_in_deadline():
    means = np.array([9.0, 11.0])
    qs = [hedged_latency_quantile(0.999, means, hedge_deadline_us=d,
                                  tail_prob=0.04, tail_scale_us=150.0)
          for d in (0.0, 200.0, 100.0, 50.0, 25.0)]
    assert all(a >= b - 1e-9 for a, b in zip(qs, qs[1:])), qs


# ---------------------------------------------------------------------------
# Topology and load balancing
# ---------------------------------------------------------------------------

def test_topology_adds_network_delay_without_touching_host_queues():
    cfg = SimRunConfig(duration_us=20_000.0)
    flat = simulate_fleet(_fgrid(FleetConfig(n_hosts=4)), cfg, slot_us=1.0)
    topo = simulate_fleet(
        _fgrid(FleetConfig(n_hosts=4, far_fraction=0.5, near_cost_us=2.0,
                           far_cost_us=8.0, link_rate_mpps=60.0)),
        cfg, slot_us=1.0)
    # host-side dynamics are bit-identical: network delay is charged to
    # a separate integral, never to the host queues
    np.testing.assert_array_equal(flat.serviced, topo.serviced)
    np.testing.assert_array_equal(flat.lat_area, topo.lat_area)
    assert float(topo.topo_area.sum()) > 0.0
    # direction and rough size: every packet pays its rack cost, far
    # packets also wait on the link
    added = float(topo.mean_latency_us[0] - flat.mean_latency_us[0])
    assert added > 0.5 * 5.0          # at least half the mean rack cost
    assert added < 50.0


def test_weighted_lb_skew_degrades_vs_uniform():
    cfg = SimRunConfig(duration_us=20_000.0)
    H = 4
    uni = simulate_fleet(_fgrid(FleetConfig(n_hosts=H),
                                rate_per_host=0.55 * MU), cfg, slot_us=1.0)
    skew = simulate_fleet(
        _fgrid(FleetConfig(n_hosts=H, lb="weighted",
                           host_weights=(4.0, 1.0, 1.0, 1.0)),
               rate_per_host=0.55 * MU),
        cfg, slot_us=1.0)
    # the hot host saturates: worse fleet mean latency (or real loss)
    assert (float(skew.mean_latency_us[0])
            > float(uni.mean_latency_us[0])
            or float(skew.loss_fraction[0]) > 0.01)


def test_stale_least_loaded_lag_hurts():
    cfg = SimRunConfig(duration_us=20_000.0, stall_rate_per_us=2.5e-4,
                       stall_mean_us=150.0)
    fresh = simulate_fleet(
        _fgrid(FleetConfig(n_hosts=4, lb="least-loaded", lb_stale_us=1.0)),
        cfg, slot_us=1.0)
    stale = simulate_fleet(
        _fgrid(FleetConfig(n_hosts=4, lb="least-loaded",
                           lb_stale_us=4_000.0)),
        cfg, slot_us=1.0)
    assert (float(stale.mean_latency_us[0])
            >= float(fresh.mean_latency_us[0]) - 0.5)


# ---------------------------------------------------------------------------
# Cluster rollups
# ---------------------------------------------------------------------------

def test_fleet_rollup_through_run_stats_merge_all():
    cfg = SimRunConfig(duration_us=10_000.0)
    fs = simulate_fleet(_fgrid(FleetConfig(n_hosts=4)), cfg, slot_us=1.0)
    hosts = fs.host_run_stats(0)
    assert len(hosts) == 4
    rolled = fs.to_run_stats(0)
    assert rolled.items == sum(int(v) for v in fs.serviced[0])
    assert rolled.offered == sum(int(v) for v in fs.offered[0])
    assert rolled.mean_sojourn_us == pytest.approx(
        float(fs.mean_latency_us[0]), rel=1e-3)
    assert rolled.latency_override["p99"] == pytest.approx(
        fs.quantile(0, 0.99))


def test_event_fleet_reference_contract():
    """simulate_fleet_run: per-host seeds s..s+H-1, rates split by the
    static shares; fleet_tail_reference hedging never hurts the tail."""
    fleet = FleetConfig(n_hosts=3, lb="weighted",
                        host_weights=(2.0, 1.0, 1.0))
    cfg = SimRunConfig(duration_us=20_000.0, seed=9)
    hosts = simulate_fleet_run(
        lambda h: MetronomePolicy(MetronomeConfig()), 0.9 * MU, cfg, fleet)
    assert len(hosts) == 3
    items = np.asarray([rs.items for rs in hosts], dtype=np.float64)
    # the 2x-weighted host serves about twice the others' traffic
    assert items[0] / items[1:].mean() == pytest.approx(2.0, rel=0.25)
    unhedged = fleet_tail_reference(hosts, fleet, 0.0, n_samples=50_000,
                                    seed=1)
    hedged = fleet_tail_reference(hosts, fleet, 40.0, n_samples=50_000,
                                  seed=1)
    assert (np.percentile(hedged, 99.9)
            <= np.percentile(unhedged, 99.9) + 1e-9)


# ---------------------------------------------------------------------------
# Compile cache
# ---------------------------------------------------------------------------

def test_compile_cache_counters_and_eviction(caplog):
    from repro.runtime import CompileCache

    builds = []

    def build(a, b):
        builds.append((a, b))
        return a + b

    cc = CompileCache(build, maxsize=2, name="test.cache")
    assert cc(1, 2) == 3 and cc(1, 2) == 3
    info = cc.cache_info()
    assert (info.hits, info.misses, info.evictions) == (1, 1, 0)
    cc(3, 4)
    with caplog.at_level(logging.WARNING, logger="repro.runtime.batched"):
        cc(5, 6)                      # evicts (1, 2), logs it
    info = cc.cache_info()
    assert info.evictions == 1 and info.currsize == 2
    assert any("test.cache" in r.message for r in caplog.records)
    assert cc(1, 2) == 3              # rebuilt after eviction
    assert builds.count((1, 2)) == 2
    stats = cc.stats()
    assert stats["name"] == "test.cache" and stats["maxsize"] == 2


def test_compile_cache_registry_surfaces_fleet_and_batched():
    from repro.runtime import compile_cache_stats

    names = {s["name"] for s in compile_cache_stats()}
    assert "batched._compiled_sweep" in names
    assert "fleet._compiled_fleet_sweep" in names
