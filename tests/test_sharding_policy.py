"""Sharding policy rules (pspec correctness, divisibility degradation) and
a real (small-mesh) dry-run through the CLI in a subprocess."""

import functools
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models import Model
from repro.sharding.policy import param_pspecs

# jax >= 0.4.36 takes a shape_tuple of (name, size) pairs; older versions
# took (shape, axis_names) positionally.
try:
    MESH = AbstractMesh((("data", 16), ("model", 16)))
    MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
except TypeError:  # pragma: no cover - older jax
    MESH = AbstractMesh((16, 16), ("data", "model"))
    MESH_MP = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def _specs(arch, mesh=MESH, mode="train"):
    cfg = get_config(arch)
    params = jax.eval_shape(
        functools.partial(Model(cfg).init, max_seq=4096), jax.random.PRNGKey(0))
    return cfg, params, param_pspecs(cfg, params, mesh, mode)


def test_dense_tp_fsdp_rules():
    cfg, params, specs = _specs("internvl2-76b")
    blk = specs["blocks"]["layer0"]
    # stacked group dim first, then (D, X): fsdp x model
    assert blk["mixer"]["wq"] == P(None, "data", "model")
    assert blk["mixer"]["wo"] == P(None, "model", "data")
    assert blk["ffn"]["w_up"] == P(None, "data", "model")
    assert blk["ffn"]["w_down"] == P(None, "model", "data")
    assert blk["mixer_norm"] == P(None, None)
    # untied input embedding: vocab over fsdp
    assert specs["embed"] == P("data", None)
    assert specs["lm_head"] == P("data", "model")


def test_serve_mode_has_no_fsdp():
    cfg, params, specs = _specs("internvl2-76b", mode="serve")
    blk = specs["blocks"]["layer0"]
    assert blk["mixer"]["wq"] == P(None, None, "model")
    assert blk["ffn"]["w_down"] == P(None, "model", None)


def test_moe_expert_parallel_rules():
    cfg, params, specs = _specs("dbrx-132b")
    moe = specs["blocks"]["layer0"]["ffn"]
    assert moe["w_gate"] == P(None, "data", None, "model")   # (G, E, D, F)
    assert moe["w_down"] == P(None, "data", "model", None)   # (G, E, F, D)
    assert moe["router"] == P(None, None, None)


def test_divisibility_degrades_to_replication():
    # granite vocab 49155 isn't divisible by 16 anywhere
    cfg, params, specs = _specs("granite-3-8b")
    assert specs["embed"] == P(None, "data")   # tied: vocab/model unfit ->None
    # mamba2 vocab 50280 % 16 != 0, tied embedding
    cfg, params, specs = _specs("mamba2-370m")
    assert specs["embed"][0] is None


def test_multipod_fsdp_spans_pod_and_data():
    cfg, params, specs = _specs("internvl2-76b", mesh=MESH_MP)
    blk = specs["blocks"]["layer0"]
    assert blk["mixer"]["wq"] == P(None, ("pod", "data"), "model")


def test_ssm_rules():
    cfg, params, specs = _specs("mamba2-370m")
    blk = specs["blocks"]["layer0"]["mixer"]
    assert blk["wx"] == P(None, "data", "model")
    assert blk["out"] == P(None, "model", "data")
    assert blk["conv_w"] == P(None, None, "model")
    assert blk["A_log"] == P(None, None)


ALL_CELLS_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs.base import cells
from repro.launch.inputs import build_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
n = 0
for arch, shape, skip in cells():
    cell = build_cell(arch, shape, mesh)      # constructs every abstract
    assert cell.args, (arch, shape)           # input tree + sharding
    n += 1
print("BUILT", n)
"""


@pytest.mark.slow
def test_every_cell_constructs_on_small_mesh_subprocess():
    """All 32 runnable cells must build their abstract sharded inputs on an
    arbitrary (2,4) mesh — catches shape/divisibility bugs without the
    cost of compiling (the full compile proof is the dry-run)."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", ALL_CELLS_SUBPROC],
                         capture_output=True, text=True, env=env, cwd=root,
                         timeout=560)
    assert "BUILT 32" in out.stdout, out.stdout + out.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_cli_one_cell_subprocess():
    """The actual dry-run entry point must pass for a representative cell
    (cheapest full cell: mamba2 long_500k) on the production mesh."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "long_500k"],
        capture_output=True, text=True, env=env, cwd=root, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "1 cells compiled OK, 0 failed" in out.stdout
    assert "roofline:" in out.stdout
