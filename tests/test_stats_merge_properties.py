"""Property tests for the weighted-union merges on ``Reservoir`` /
``QueueStats`` / ``RunStats`` — the invariants the sharded-sweep
machinery silently relies on (counts conserved, merged quantiles
bounded by the inputs' extremes, distributional order-insensitivity at
a fixed seed), which until now were only example-tested."""

import copy

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed in this environment")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.stats import QueueStats, Reservoir, RunStats

floats_us = st.floats(min_value=0.0, max_value=1e6,
                      allow_nan=False, allow_infinity=False)
value_lists = st.lists(floats_us, min_size=0, max_size=200)
small_caps = st.integers(min_value=1, max_value=64)


def _reservoir(values, capacity=32, seed=0):
    r = Reservoir(capacity, seed=seed)
    r.extend(list(values))
    return r


# ---------------------------------------------------------------------------
# Reservoir.merge
# ---------------------------------------------------------------------------

@given(a=value_lists, b=value_lists, cap=small_caps)
@settings(max_examples=60, deadline=None)
def test_reservoir_merge_conserves_count_and_bounds_buffer(a, b, cap):
    ra, rb = _reservoir(a, cap), _reservoir(b, cap)
    merged = ra.merge(rb)
    assert merged is ra
    # counts conserved: merged stream length = sum of input streams
    assert merged.count == len(a) + len(b)
    # buffer never exceeds capacity, and is as full as possible
    assert len(merged) <= cap
    assert len(merged) == min(cap, len(a) + len(b))


@given(a=value_lists, b=value_lists, cap=small_caps)
@settings(max_examples=60, deadline=None)
def test_reservoir_merge_quantiles_bounded_by_input_extremes(a, b, cap):
    ra, rb = _reservoir(a, cap), _reservoir(b, cap)
    pool = list(ra) + list(rb)          # survivors before the union
    ra.merge(rb)
    if not pool:
        assert len(ra) == 0
        return
    lo, hi = min(pool), max(pool)
    arr = np.asarray(ra)
    assert arr.size > 0
    # every merged sample (hence every quantile of the merged buffer)
    # comes from one of the input buffers
    assert float(arr.min()) >= lo - 1e-12
    assert float(arr.max()) <= hi + 1e-12
    for q in (1, 50, 99):
        v = float(np.percentile(arr, q))
        assert lo - 1e-12 <= v <= hi + 1e-12


@given(a=value_lists, b=value_lists, cap=small_caps,
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_reservoir_merge_order_insensitive_in_distribution(a, b, cap, seed):
    """At a fixed seed, A.merge(B) and B.merge(A) need not be the same
    buffer — but they must describe the same pooled stream: identical
    total counts, buffer sizes, and (when nothing was evicted anywhere)
    identical sample *sets*."""
    ab = _reservoir(a, cap, seed).merge(_reservoir(b, cap, seed))
    ba = _reservoir(b, cap, seed).merge(_reservoir(a, cap, seed))
    assert ab.count == ba.count == len(a) + len(b)
    assert len(ab) == len(ba)
    if len(a) + len(b) <= cap:
        # lossless regime: the union is exact in both orders
        assert sorted(ab) == sorted(ba) == sorted(list(a) + list(b))
    else:
        # lossy regime: both are samples of the same pool
        pool = set()
        pool.update(_reservoir(a, cap, seed))
        pool.update(_reservoir(b, cap, seed))
        assert set(ab) <= set(a) | set(b)
        assert set(ba) <= set(a) | set(b)


@given(vals=st.lists(floats_us, min_size=1, max_size=120), cap=small_caps)
@settings(max_examples=40, deadline=None)
def test_reservoir_merge_empty_is_identity(vals, cap):
    r = _reservoir(vals, cap)
    before_buf, before_count = list(r), r.count
    r.merge(Reservoir(cap, seed=9))
    assert list(r) == before_buf and r.count == before_count


# ---------------------------------------------------------------------------
# QueueStats.merge
# ---------------------------------------------------------------------------

counter = st.integers(min_value=0, max_value=10**9)


@given(o1=counter, d1=counter, s1=counter, b1=counter, c1=counter,
       o2=counter, d2=counter, s2=counter, b2=counter, c2=counter,
       lat1=value_lists, lat2=value_lists)
@settings(max_examples=50, deadline=None)
def test_queue_stats_merge_adds_every_counter(o1, d1, s1, b1, c1,
                                              o2, d2, s2, b2, c2,
                                              lat1, lat2):
    qa = QueueStats(queue=0, offered=o1, dropped=d1, serviced=s1,
                    busy_tries=b1, cycles=c1,
                    latency_us=_reservoir(lat1, 16))
    qb = QueueStats(queue=0, offered=o2, dropped=d2, serviced=s2,
                    busy_tries=b2, cycles=c2,
                    latency_us=_reservoir(lat2, 16))
    qa.merge(qb)
    assert qa.offered == o1 + o2
    assert qa.dropped == d1 + d2
    assert qa.serviced == s1 + s2
    assert qa.busy_tries == b1 + b2
    assert qa.cycles == c1 + c2
    assert qa.latency_us.count == len(lat1) + len(lat2)
    # the donor is unchanged
    assert qb.offered == o2 and qb.latency_us.count == len(lat2)


# ---------------------------------------------------------------------------
# RunStats.merge
# ---------------------------------------------------------------------------

def _run_stats(offered, dropped, items, awake_ns, lat, *, n_queues=2,
               seed=0):
    rs = RunStats(backend="sim", policy="p", workload="w",
                  wakeups=offered % 97, cycles=items % 89,
                  busy_tries=dropped % 83, items=items, offered=offered,
                  dropped=dropped, awake_ns=awake_ns, started_ns=0,
                  stopped_ns=10**9,
                  latency_us=_reservoir(lat, 32, seed))
    rs.per_queue = [
        QueueStats(queue=q, offered=offered // n_queues,
                   dropped=dropped // n_queues,
                   serviced=items // n_queues,
                   latency_us=_reservoir(lat[q::n_queues], 16, seed + q))
        for q in range(n_queues)
    ]
    return rs


@given(o1=counter, d1=counter, i1=counter, a1=counter,
       o2=counter, d2=counter, i2=counter, a2=counter,
       lat1=value_lists, lat2=value_lists)
@settings(max_examples=40, deadline=None)
def test_run_stats_merge_conserves_counters_and_reservoirs(
        o1, d1, i1, a1, o2, d2, i2, a2, lat1, lat2):
    ra = _run_stats(o1, d1, i1, a1, lat1)
    rb = _run_stats(o2, d2, i2, a2, lat2, seed=1)
    rb_snapshot = copy.deepcopy(rb)
    ra.merge(rb)
    assert ra.offered == o1 + o2
    assert ra.dropped == d1 + d2
    assert ra.items == i1 + i2
    assert ra.awake_ns == a1 + a2
    assert ra.latency_us.count == len(lat1) + len(lat2)
    # per-queue slices merged by index, conserving their sums
    assert len(ra.per_queue) == 2
    for q in range(2):
        assert ra.per_queue[q].offered == (o1 // 2) + (o2 // 2)
    # the donor was not mutated (merge adopts copies of its slices)
    for q in range(2):
        assert rb.per_queue[q].offered == rb_snapshot.per_queue[q].offered
        assert (rb.per_queue[q].latency_us.count
                == rb_snapshot.per_queue[q].latency_us.count)


@given(lat=st.lists(floats_us, min_size=2, max_size=100))
@settings(max_examples=30, deadline=None)
def test_run_stats_merged_latency_quantiles_bounded(lat):
    half = len(lat) // 2
    ra = _run_stats(10, 0, 5, 100, lat[:half])
    rb = _run_stats(10, 0, 5, 100, lat[half:], seed=1)
    lo, hi = min(lat), max(lat)
    ra.merge(rb)
    arr = np.asarray(ra.latency_us)
    if arr.size:
        assert float(np.percentile(arr, 99)) <= hi + 1e-12
        assert float(np.percentile(arr, 1)) >= lo - 1e-12


# ---------------------------------------------------------------------------
# merge_all — the n-way rollups the fleet tier leans on
# ---------------------------------------------------------------------------

shard_lists = st.lists(value_lists, min_size=0, max_size=8)


@given(shards=shard_lists, cap=small_caps)
@settings(max_examples=40, deadline=None)
def test_reservoir_merge_all_conserves_count_and_bounds(shards, cap):
    base = _reservoir([], cap)
    base.merge_all(_reservoir(s, cap, seed=i + 1)
                   for i, s in enumerate(shards))
    total = sum(len(s) for s in shards)
    assert base.count == total
    assert len(base) == min(cap, total)
    pool = [v for s in shards for v in s]
    if pool:
        arr = np.asarray(base)
        assert float(arr.min()) >= min(pool) - 1e-12
        assert float(arr.max()) <= max(pool) + 1e-12


@given(shards=shard_lists, cap=small_caps)
@settings(max_examples=40, deadline=None)
def test_reservoir_merge_all_matches_sequential_merge_counts(shards, cap):
    nway = _reservoir([], cap)
    nway.merge_all(_reservoir(s, cap, seed=i + 1)
                   for i, s in enumerate(shards))
    seq = _reservoir([], cap)
    for i, s in enumerate(shards):
        seq.merge(_reservoir(s, cap, seed=i + 1))
    assert nway.count == seq.count
    assert len(nway) == len(seq)


@given(sides=st.lists(st.tuples(counter, counter, counter, value_lists),
                      min_size=1, max_size=6))
@settings(max_examples=40, deadline=None)
def test_queue_stats_merge_all_adds_every_counter(sides):
    (o0, d0, s0, lat0), rest = sides[0], sides[1:]
    qa = QueueStats(queue=0, offered=o0, dropped=d0, serviced=s0,
                    latency_us=_reservoir(lat0, 16))
    qa.merge_all(QueueStats(queue=0, offered=o, dropped=d, serviced=s,
                            latency_us=_reservoir(lat, 16, seed=i + 1))
                 for i, (o, d, s, lat) in enumerate(rest))
    assert qa.offered == sum(o for o, _, _, _ in sides)
    assert qa.dropped == sum(d for _, d, _, _ in sides)
    assert qa.serviced == sum(s for _, _, s, _ in sides)
    assert qa.latency_us.count == sum(len(lat) for *_, lat in sides)


@given(sides=st.lists(st.tuples(counter, counter, counter, counter,
                                value_lists),
                      min_size=1, max_size=6))
@settings(max_examples=30, deadline=None)
def test_run_stats_merge_all_conserves_counters(sides):
    runs = [_run_stats(o, d, i_, a, lat, seed=k)
            for k, (o, d, i_, a, lat) in enumerate(sides)]
    donors = [copy.deepcopy(r) for r in runs[1:]]
    head = runs[0]
    out = head.merge_all(runs[1:])
    assert out is head
    assert head.offered == sum(o for o, *_ in sides)
    assert head.dropped == sum(d for _, d, *_ in sides)
    assert head.items == sum(i_ for _, _, i_, _, _ in sides)
    assert head.awake_ns == sum(a for *_, a, _ in sides)
    assert head.latency_us.count == sum(len(lat) for *_, lat in sides)
    assert len(head.per_queue) == 2
    for q in range(2):
        assert head.per_queue[q].offered == sum(o // 2 for o, *_ in sides)
    # donors untouched (merge_all deep-copies their per-queue slices)
    for donor, snap in zip(runs[1:], donors):
        assert donor.offered == snap.offered
        for q in range(2):
            assert (donor.per_queue[q].latency_us.count
                    == snap.per_queue[q].latency_us.count)


@given(sides=st.lists(st.tuples(counter, counter, counter, counter,
                                value_lists),
                      min_size=2, max_size=6))
@settings(max_examples=30, deadline=None)
def test_run_stats_merge_all_counters_match_sequential_fold(sides):
    runs_a = [_run_stats(o, d, i_, a, lat, seed=k)
              for k, (o, d, i_, a, lat) in enumerate(sides)]
    runs_b = [_run_stats(o, d, i_, a, lat, seed=k)
              for k, (o, d, i_, a, lat) in enumerate(sides)]
    nway = runs_a[0].merge_all(runs_a[1:])
    seq = runs_b[0]
    for r in runs_b[1:]:
        seq.merge(r)
    for f in ("offered", "dropped", "items", "awake_ns", "wakeups",
              "cycles", "busy_tries"):
        assert getattr(nway, f) == getattr(seq, f), f
    assert nway.latency_us.count == seq.latency_us.count


def test_run_stats_merge_all_empty_iterable_is_noop():
    rs = _run_stats(10, 1, 5, 100, [1.0, 2.0])
    before = (rs.offered, rs.items, rs.latency_us.count)
    rs.merge_all([])
    assert (rs.offered, rs.items, rs.latency_us.count) == before
