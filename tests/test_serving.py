"""End-to-end serving tests: continuous-batching engine + Metronome server
(the paper's architecture on the serving path)."""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import MetronomeConfig
from repro.models import Model
from repro.serving import (
    BusyPollServer,
    EngineConfig,
    InferenceEngine,
    MetronomeServer,
    Request,
)

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=101)


def _make_engine(max_slots=4, max_len=64):
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0), max_seq=max_len)
    return InferenceEngine(model, params,
                           EngineConfig(max_slots=max_slots, max_len=max_len,
                                        prefill_buckets=(8, 16)))


def test_engine_generates_deterministically_and_matches_decode_path():
    """Engine output == manual prefill+greedy-decode for the same model."""
    eng = _make_engine()
    prompt = [5, 7, 11, 13]
    req = Request(prompt=list(prompt), max_new_tokens=6)
    eng.submit([req])
    eng.pump()
    assert len(req.tokens) == 6

    # manual reference: prefill then greedy decode with the same model
    import jax.numpy as jnp
    model, params = eng.model, eng.params
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == len(prompt):
            pw = [(0, 0)] * leaf.ndim
            pw[2] = (0, 64 - len(prompt))
            return jnp.pad(leaf, pw)
        return leaf
    cache = jax.tree.map(pad, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    decode = jax.jit(model.decode_step)
    for _ in range(5):
        lg, cache = decode(params, jnp.asarray([toks[-1]], jnp.int32), cache,
                           jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert req.tokens == toks


def test_engine_continuous_batching_isolation():
    """Concurrent requests must not contaminate each other: answers equal
    the same requests served one-at-a-time."""
    solo = []
    for seed in range(3):
        eng = _make_engine()
        req = Request(prompt=[seed + 1, seed + 2, seed + 3], max_new_tokens=5)
        eng.submit([req])
        eng.pump()
        solo.append(req.tokens)

    eng = _make_engine()
    reqs = [Request(prompt=[s + 1, s + 2, s + 3], max_new_tokens=5)
            for s in range(3)]
    eng.submit(reqs)
    eng.pump()
    for r, expect in zip(reqs, solo):
        assert r.tokens == expect


def test_engine_more_requests_than_slots():
    eng = _make_engine(max_slots=2)
    reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=4) for i in range(5)]
    eng.submit(reqs)
    eng.pump()
    assert all(len(r.tokens) == 4 for r in reqs)
    assert not eng.has_work


def _drive_server(server_cls, n_req=12, rate_hz=60.0, **kw):
    eng = _make_engine(max_slots=4)
    # warm the jit caches (prefill bucket + decode) so retrieval-latency
    # measurements aren't dominated by first-call compilation
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    eng.submit([warm])
    eng.pump()
    srv = server_cls(eng, **kw)
    srv.start()
    reqs = []
    for i in range(n_req):
        r = Request(prompt=[(i % 90) + 1, (i % 90) + 2], max_new_tokens=4)
        assert srv.submit(r)
        reqs.append(r)
        time.sleep(1.0 / rate_hz)
    for r in reqs:
        assert r.wait(timeout=20.0), "request not completed"
    stats = srv.stop()
    return reqs, stats


def test_metronome_server_serves_everything():
    reqs, stats = _drive_server(
        MetronomeServer,
        cfg=MetronomeConfig(m=3, v_target_us=3_000.0, t_long_us=60_000.0))
    assert all(len(r.tokens) == 4 for r in reqs)
    assert stats.busy_periods > 0
    assert 0 < stats.cpu_fraction < 3.0


def test_metronome_server_cpu_below_busy_poll():
    """Paper Fig 12b on the serving path: Metronome's retrieval burns less
    host CPU than the spinning baseline at the same (light) request load,
    with no requests lost."""
    m_reqs, m_stats = _drive_server(
        MetronomeServer, n_req=10, rate_hz=40.0,
        cfg=MetronomeConfig(m=2, v_target_us=4_000.0, t_long_us=80_000.0))
    b_reqs, b_stats = _drive_server(BusyPollServer, n_req=10, rate_hz=40.0)
    assert all(len(r.tokens) == 4 for r in m_reqs + b_reqs)
    assert m_stats.cpu_fraction < b_stats.cpu_fraction


def test_metronome_server_retrieval_latency_tracks_target():
    """Retrieval latency ~ vacation target, not the backup timeout."""
    reqs, stats = _drive_server(
        MetronomeServer, n_req=10, rate_hz=30.0,
        cfg=MetronomeConfig(m=3, v_target_us=2_000.0, t_long_us=100_000.0))
    assert stats.retrieval_lat_us
    med = float(np.median(stats.retrieval_lat_us))
    assert med < 50_000.0, med   # well below T_L; dominated by engine busy time


def test_server_shards_ingress_across_queues():
    """Multi-queue serving ingress: requests spread across n_queues with
    stable affinity, every request is served, and the per-queue counters
    sum to the totals."""
    from repro.serving import Server
    from repro.runtime import MetronomePolicy, StealingAssignment

    eng = _make_engine(max_slots=4)
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    eng.submit([warm])
    eng.pump()

    srv = Server(eng,
                 MetronomePolicy(MetronomeConfig(m=3, v_target_us=3_000.0,
                                                 t_long_us=60_000.0)),
                 n_queues=3, assignment=StealingAssignment())
    assert len(srv.queues) == 3
    srv.start()
    reqs = []
    for i in range(12):
        r = Request(prompt=[(i % 90) + 1, (i % 90) + 2], max_new_tokens=4)
        assert srv.submit(r)
        reqs.append(r)
        time.sleep(0.02)
    for r in reqs:
        assert r.wait(timeout=20.0), "request not completed"
    stats = srv.stop()
    assert all(len(r.tokens) == 4 for r in reqs)
    assert len(stats.per_queue) == 3
    assert sum(q.offered for q in stats.per_queue) == stats.offered == 12
    assert sum(q.serviced for q in stats.per_queue) == 12
    assert sum(q.dropped for q in stats.per_queue) == stats.dropped == 0


def test_server_affinity_routes_same_key_to_same_queue():
    """Requests sharing a session attribute always land in one queue."""
    from repro.serving import Server
    from repro.runtime import FixedPeriodPolicy

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    srv = Server(_NullEngine(), FixedPeriodPolicy(5_000.0), n_queues=4)

    class _KeyedReq:
        def __init__(self, session_id):
            self.session_id = session_id

    # do not start the server: pushed requests stay put, exposing routing
    for _ in range(8):
        srv.submit(_KeyedReq("session-A"))
    occupied = [len(q) for q in srv.queues]
    assert sum(occupied) == 8
    assert max(occupied) == 8    # all eight in a single queue


def test_server_replay_schedules_a_nonstationary_request_stream():
    """Server.replay drives live serving from a Workload x LoadSchedule
    pair: everything submitted is served, and the stats carry the
    schedule descriptor so live runs line up with simulated ones."""
    from repro.runtime import MetronomePolicy, PoissonWorkload, StepSchedule
    from repro.serving import Server

    eng = _make_engine()
    srv = Server(eng, MetronomePolicy(MetronomeConfig(
        m=2, v_target_us=1_000.0, t_long_us=20_000.0)))
    # ~60 requests over 0.3s, rate stepping up 3x halfway through
    sched = StepSchedule(times_us=(0.0, 150_000.0), scales=(0.5, 1.5))
    stats = srv.replay(
        PoissonWorkload(0.0002), duration_us=300_000.0, schedule=sched,
        make_request=lambda i: Request(prompt=[1, 2, 3], max_new_tokens=2))
    assert stats.backend == "server"
    assert stats.schedule.startswith("step[")
    assert stats.workload.startswith("poisson")
    assert stats.offered > 0
    assert stats.items == stats.offered - stats.dropped
    assert stats.dropped == 0
