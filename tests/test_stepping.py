"""Stepping modes of the batched engine: compile-cache bucketing, time
accounting invariants on both kernels (fixed + adaptive event-jump),
and the fleet engine's adaptive mode.

The invariants are checked two ways: a seeded-random sweep that always
runs (this environment has no hypothesis), and the same properties
under hypothesis when it is installed.
"""

import numpy as np
import pytest

from repro.runtime import SimRunConfig, SweepGrid, simulate_batch
from repro.runtime.batched import bucket_steps
from repro.runtime.simcore import HR_SLEEP_MODEL

INTERFERENCE_ENV = dict(interference_prob=0.25, interference_mean_us=20.0,
                        stall_rate_per_us=1.0 / 4000.0,
                        stall_mean_us=150.0)
STEPPINGS = ("fixed", "adaptive")

# f32 accumulators drift ~1e-4 relative over 1e5 slots; the conservation
# law must hold far tighter than any physical effect but not bit-exactly
CONS_REL = 2e-3


def _mixed_grid(n=10, seed=3, interference=False):
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        t_s = float(rng.uniform(5.0, 50.0))
        pts.append(dict(
            t_s_us=t_s,
            t_l_us=float(t_s * rng.uniform(4.0, 20.0)),
            m=int(rng.integers(1, 5)),
            n_queues=int(rng.integers(1, 4)),
            rate_mpps=float(rng.uniform(0.1, 0.8) * 29.76),
            seed=1000 + i))
    env = INTERFERENCE_ENV if interference else {}
    cfg = SimRunConfig(duration_us=30_000.0, sleep_model=HR_SLEEP_MODEL,
                       window_us=1_000.0, **env)
    return SweepGrid.of_points(pts), cfg


def _check_invariants(bs, cfg, stepping):
    n = len(bs.offered)
    # 1. sum of dt == duration: exact for adaptive (the final live step
    # takes dt = remaining, so the carried remainder hits 0.0 in f32);
    # fixed quantizes up to one slot
    if stepping == "adaptive":
        assert np.all(bs.sim_time_us == np.float64(
            np.float32(cfg.duration_us))), bs.sim_time_us
    else:
        assert np.all(bs.sim_time_us >= cfg.duration_us - 1e-6)
        assert np.all(bs.sim_time_us < cfg.duration_us + bs.slot_us)
    # 2. packet conservation: offered = served + dropped + backlog
    resid = bs.offered - bs.serviced - bs.dropped - bs.final_backlog
    assert np.all(np.abs(resid) <= CONS_REL * np.maximum(bs.offered, 1.0)
                  + 1.0), resid
    # 3. CPU accounting cannot exceed every thread being awake always
    m = np.asarray(bs.grid.m, dtype=np.float64)
    assert np.all(bs.awake_us >= 0.0)
    assert np.all(bs.awake_us <= m * cfg.duration_us * (1.0 + 1e-6))
    # 4. windowed series sums match run totals (same accumulators,
    # binned): offered / served / lat_area / awake / energy columns
    assert bs.win.shape[0] == n and bs.win.shape[2] == 5
    for col, name in ((0, "offered"), (1, "serviced"), (2, "lat_area"),
                      (3, "awake_us"), (4, "energy_uj")):
        tot = getattr(bs, name)
        wsum = bs.win[:, :, col].sum(axis=1)
        assert np.all(np.abs(wsum - tot)
                      <= CONS_REL * np.maximum(np.abs(tot), 1.0) + 1.0), \
            (name, wsum, tot)
    # 5. ns/us unit conversion in to_run_stats rounds (never truncates):
    # converting back must land within half an ns, not a full one
    for i in (0, n - 1):
        rs = bs.to_run_stats(i)
        assert abs(rs.awake_ns / 1e3 - float(bs.awake_us[i])) <= 5.1e-4
        assert abs(rs.stopped_ns / 1e3 - cfg.duration_us) <= 5.1e-4
        assert rs.energy_uj == pytest.approx(float(bs.energy_uj[i]))
    # diagnostics are well-formed
    assert np.all(bs.n_steps >= 1)
    assert np.all(bs.n_steps <= bs.scan_len)
    assert np.all(bs.forced_steps >= 0)
    assert bs.stepping == stepping


@pytest.mark.parametrize("stepping", STEPPINGS)
@pytest.mark.parametrize("interference", (False, True),
                         ids=("quiet", "noisy"))
def test_time_accounting_invariants(stepping, interference):
    grid, cfg = _mixed_grid(interference=interference)
    bs = simulate_batch(grid, cfg, slot_us=0.5, stepping=stepping)
    _check_invariants(bs, cfg, stepping)


def test_adaptive_needs_far_fewer_steps_at_low_load():
    """The load-proportionality claim at test scale: a rho=0.2,
    T_S=50us point takes >= 10x fewer live scan steps than fixed."""
    pts = [dict(t_s_us=50.0, t_l_us=500.0, m=3, rate_mpps=0.2 * 29.76,
                seed=0)]
    cfg = SimRunConfig(duration_us=60_000.0, sleep_model=HR_SLEEP_MODEL)
    grid = SweepGrid.of_points(pts)
    bf = simulate_batch(grid, cfg, slot_us=0.5)
    ba = simulate_batch(grid, cfg, slot_us=0.5, stepping="adaptive")
    assert float(ba.n_steps[0]) * 10.0 <= float(bf.n_steps[0])
    assert ba.scan_len * 3 <= bf.scan_len
    assert float(ba.forced_steps[0]) == 0.0


def test_stepping_rejects_unknown_mode():
    grid, cfg = _mixed_grid(n=1)
    with pytest.raises(ValueError, match="stepping"):
        simulate_batch(grid, cfg, stepping="magic")


# ---------------------------------------------------------------- caching

def test_bucket_steps_ladder():
    """Geometric ladder: idempotent on its own rungs, monotone, never
    below the request, and coarse enough that nearby sizes collide."""
    assert bucket_steps(1) == 64
    assert bucket_steps(64) == 64
    for n in (65, 100, 1000, 240_000):
        b = bucket_steps(n)
        assert b >= n
        assert bucket_steps(b) == b           # rungs are fixed points
        assert b <= int(np.ceil(n * 1.25)) + 1
    assert bucket_steps(100) == bucket_steps(99)


@pytest.mark.parametrize("stepping", STEPPINGS)
def test_nearby_durations_share_one_compile(stepping):
    """Recompile-churn fix: two nearby durations land on the same
    n_slots/max-steps bucket, so the second sweep is a cache hit (the
    kernel traces a per-point traced duration, not a static one)."""
    from repro.runtime.batched import _compiled_sweep

    pts = [dict(t_s_us=20.0, t_l_us=200.0, m=2, rate_mpps=5.0, seed=0)]
    grid = SweepGrid.of_points(pts)
    caches = {"fixed": lambda: _compiled_sweep}
    if stepping == "adaptive":
        def _adaptive_cache():
            from repro.runtime import batched_adaptive
            return batched_adaptive._compiled_adaptive_sweep
        caches["adaptive"] = _adaptive_cache

    r = []
    infos = []
    for dur in (20_000.0, 20_400.0):    # within one 1.25x bucket rung
        cfg = SimRunConfig(duration_us=dur, sleep_model=HR_SLEEP_MODEL)
        bs = simulate_batch(grid, cfg, slot_us=0.5, stepping=stepping)
        r.append(bs)
        infos.append(caches[stepping]().cache_info())
    assert r[0].scan_len == r[1].scan_len
    assert infos[1].misses == infos[0].misses, \
        "nearby durations must share one compiled kernel"
    assert infos[1].hits >= infos[0].hits + 1
    # and the padding is inert: each run still simulates ITS duration
    assert float(r[0].sim_time_us[0]) < float(r[1].sim_time_us[0])


# ---------------------------------------------------------------- fleet

def test_fleet_adaptive_parity_and_steps():
    """Fleet event-jump mode: aggregate latency / cores / loss agree
    with the fixed fleet kernel within the documented quiet bands, with
    fewer live steps, exact sim time, and the LB stale refresh honored
    as a jump boundary."""
    from repro.runtime.fleet import FleetGrid, simulate_fleet
    from repro.runtime.simcore import FleetConfig

    cfg = SimRunConfig(duration_us=30_000.0, sleep_model=HR_SLEEP_MODEL)
    fg = FleetGrid.product(
        fleet=FleetConfig(n_hosts=4, lb="least-loaded", lb_stale_us=50.0),
        t_s_us=(30.0,), t_l_us=(400.0,),
        rate_mpps=(0.2 * 29.76 * 4, 0.6 * 29.76 * 4),
        m=(3,), n_queues=(2,), seeds=(0,))
    f = simulate_fleet(fg, cfg, slot_us=0.5, shard=False)
    a = simulate_fleet(fg, cfg, slot_us=0.5, shard=False,
                       stepping="adaptive")
    assert a.stepping == "adaptive" and f.stepping == "fixed"
    for i in range(len(fg)):
        lat_f, lat_a = float(f.mean_latency_us[i]), \
            float(a.mean_latency_us[i])
        assert abs(lat_a - lat_f) <= max(1.5, 0.12 * lat_f), (lat_a, lat_f)
        cores_f = float(f.total_cpu_cores[i])
        assert abs(float(a.total_cpu_cores[i]) - cores_f) \
            <= 4 * 0.02 + 0.05 * cores_f
        assert abs(float(a.loss_fraction[i])
                   - float(f.loss_fraction[i])) <= 0.03
    assert np.all(a.sim_time_us == np.float64(
        np.float32(cfg.duration_us)))
    assert np.all(a.n_steps <= 0.5 * f.n_steps)
    assert a.scan_len < f.scan_len


def test_fleet_stepping_rejects_unknown_mode():
    from repro.runtime.fleet import FleetGrid, simulate_fleet
    from repro.runtime.simcore import FleetConfig

    fg = FleetGrid.product(fleet=FleetConfig(n_hosts=2),
                           t_s_us=(20.0,), t_l_us=(200.0,),
                           rate_mpps=(5.0,))
    with pytest.raises(ValueError, match="stepping"):
        simulate_fleet(fg, SimRunConfig(duration_us=1_000.0),
                       stepping="magic")


# ------------------------------------------------- hypothesis (optional)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    point_st = st.fixed_dictionaries(dict(
        t_s_us=st.floats(min_value=4.0, max_value=60.0,
                         allow_nan=False, allow_infinity=False),
        t_l_us=st.floats(min_value=80.0, max_value=1000.0,
                         allow_nan=False, allow_infinity=False),
        m=st.integers(min_value=1, max_value=4),
        n_queues=st.integers(min_value=1, max_value=3),
        rate_mpps=st.floats(min_value=0.5, max_value=24.0,
                            allow_nan=False, allow_infinity=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    ))

    @settings(max_examples=10, deadline=None)
    @given(pts=st.lists(point_st, min_size=1, max_size=4),
           stepping=st.sampled_from(STEPPINGS),
           noisy=st.booleans())
    def test_invariants_hold_for_random_jump_sequences(pts, stepping,
                                                       noisy):
        env = INTERFERENCE_ENV if noisy else {}
        # one shared duration keeps hypothesis from forcing a recompile
        # per example; the invariants don't depend on it
        cfg = SimRunConfig(duration_us=20_000.0,
                           sleep_model=HR_SLEEP_MODEL,
                           window_us=1_000.0, **env)
        bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5,
                            stepping=stepping)
        _check_invariants(bs, cfg, stepping)
