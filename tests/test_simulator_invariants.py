"""Hypothesis property tests on the renewal-system invariants — for ANY
configuration the simulator must conserve packets, respect capacity, and
keep its accounting self-consistent (spec: property tests on the system's
invariants)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.simulator import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimConfig,
    simulate,
)

finite = dict(allow_nan=False, allow_infinity=False)

cfg_st = st.builds(
    SimConfig,
    m=st.integers(min_value=1, max_value=6),
    arrival_rate_mpps=st.floats(min_value=0.01, max_value=20.0, **finite),
    service_rate_mpps=st.floats(min_value=21.0, max_value=60.0, **finite),
    queue_capacity=st.sampled_from([64, 256, 1024, 4096]),
    duration_us=st.just(60_000.0),
    v_target_us=st.floats(min_value=2.0, max_value=50.0, **finite),
    t_long_us=st.floats(min_value=100.0, max_value=1000.0, **finite),
    adaptive=st.booleans(),
    equal_timeouts=st.booleans(),
    sleep_model=st.sampled_from(
        [HR_SLEEP_MODEL, NANOSLEEP_MODEL, PERFECT_SLEEP_MODEL]),
    interference_prob=st.sampled_from([0.0, 0.2]),
    interference_mean_us=st.just(200.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)


@given(cfg=cfg_st)
@settings(max_examples=40, deadline=None)
def test_packet_conservation_and_bounds(cfg):
    r = simulate(cfg)
    # conservation: everything offered is serviced, dropped, or still queued
    backlog = r.offered - r.dropped - r.serviced
    assert backlog >= -1, (r.offered, r.dropped, r.serviced)
    # a vacation's backlog can never exceed the ring
    if r.n_v.size:
        assert float(r.n_v.max()) <= cfg.queue_capacity + 1e-9
    # loss fraction is a probability
    assert 0.0 <= r.loss_fraction <= 1.0
    # CPU: at most M cores' worth of awake time
    assert 0.0 <= r.cpu_fraction <= cfg.m + 1e-9
    # periods are nonnegative and finite
    for arr in (r.vacations_us, r.busies_us):
        if arr.size:
            assert np.isfinite(arr).all()
            assert (arr >= -1e-9).all()
    # latency stats are ordered
    assert r.mean_latency_us <= r.worst_latency_us + 1e-9


@given(cfg=cfg_st)
@settings(max_examples=25, deadline=None)
def test_determinism_same_seed(cfg):
    a, b = simulate(cfg), simulate(cfg)
    assert a.offered == b.offered
    assert a.dropped == b.dropped
    assert a.serviced == b.serviced
    np.testing.assert_array_equal(a.vacations_us, b.vacations_us)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       lam=st.floats(min_value=0.5, max_value=14.0, **finite))
@settings(max_examples=20, deadline=None)
def test_no_loss_with_infinite_queue(seed, lam):
    cfg = SimConfig(arrival_rate_mpps=lam, service_rate_mpps=29.76,
                    queue_capacity=10**9, duration_us=60_000.0, seed=seed)
    r = simulate(cfg)
    assert r.dropped == 0


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_more_threads_never_lengthen_vacations_much(seed):
    """E[V] decreases (or stays ~flat) in M under identical settings."""
    means = []
    for m in (1, 3, 6):
        cfg = SimConfig(m=m, adaptive=False, v_target_us=30.0,
                        arrival_rate_mpps=5.0, service_rate_mpps=29.76,
                        sleep_model=PERFECT_SLEEP_MODEL,
                        duration_us=120_000.0, seed=seed)
        means.append(simulate(cfg).mean_vacation_us)
    assert means[2] <= means[0] * 1.25 + 1.0
