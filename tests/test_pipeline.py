"""Pipeline parallelism (gpipe) vs sequential reference — 8-device
subprocess (the main test process stays single-device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding.pipeline import gpipe

    mesh = jax.make_mesh((4, 2), ("pipe", "data"))
    L, B, D, M = 8, 16, 12, 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (L, D, D)) * 0.3
    bs = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
    params = {"w": ws, "b": bs}
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

    def block(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def seq(params, x):
        h = x
        for l in range(L):
            h = block(jax.tree.map(lambda p: p[l], params), h)
        return h

    ref = seq(params, x)
    got = jax.jit(lambda p, v: gpipe(block, p, v, mesh, n_microbatches=M))(
        params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # collective-permute must be on the wire
    txt = jax.jit(lambda p, v: gpipe(block, p, v, mesh, n_microbatches=M)
                  ).lower(params, x).compile().as_text()
    assert "collective-permute" in txt

    # gradients flow through the pipeline and match the sequential grads
    def loss_pipe(p, v):
        return jnp.sum(gpipe(block, p, v, mesh, n_microbatches=M) ** 2)
    def loss_seq(p, v):
        return jnp.sum(seq(p, v) ** 2)
    gp = jax.jit(jax.grad(loss_pipe))(params, x)
    gs = jax.jit(jax.grad(loss_seq))(params, x)
    np.testing.assert_allclose(np.asarray(gp["w"]), np.asarray(gs["w"]),
                               rtol=2e-4, atol=2e-4)
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_gpipe_matches_sequential_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SUBPROC],
                         capture_output=True, text=True, env=env, cwd=root,
                         timeout=560)
    assert "PIPELINE_OK" in out.stdout, out.stderr[-3000:]
