"""Controller-level adaptation guarantees: exact EWMA step response,
the T_L >= T_S clamp along a full schedule trajectory, operating-table
interpolation continuity at its knots, and the recorded rho/T_S
trajectory surface."""

import numpy as np
import pytest

from repro.core import MetronomeConfig, MetronomeController
from repro.runtime import (
    MetronomePolicy,
    PoissonWorkload,
    SimRunConfig,
    SinusoidSchedule,
    StepSchedule,
    simulate_run,
)
from repro.runtime.calibrate import OperatingPoint, OperatingTable


def test_ewma_step_response_is_exactly_one_minus_decay_pow_n():
    """Eq 10 against a rate step: feeding a constant observed load
    rho* = B/(V+B), the estimate's remaining error after n cycles is
    exactly (1-alpha)^n of the initial error — the textbook first-order
    step response, with no hidden state or bias."""
    alpha = 0.125
    ctl = MetronomeController(MetronomeConfig(alpha=alpha, rho_init=0.2))
    rho_star = 0.8          # B=8, V=2 -> B/(V+B) = 0.8
    err0 = rho_star - ctl.rho
    for n in range(1, 40):
        ctl.on_cycle_end(busy_us=8.0, vacation_us=2.0)
        expected = rho_star - err0 * (1.0 - alpha) ** n
        assert ctl.rho == pytest.approx(expected, abs=1e-12), n
    # the fractional progress toward the step is exactly 1-(1-a)^n
    ctl2 = MetronomeController(MetronomeConfig(alpha=0.3, rho_init=0.0))
    for n in range(1, 25):
        ctl2.on_cycle_end(busy_us=1.0, vacation_us=1.0)   # rho* = 0.5
        frac = ctl2.rho / 0.5
        assert frac == pytest.approx(1.0 - 0.7 ** n, abs=1e-12)


def test_tl_clamp_holds_along_full_schedule_trajectory():
    """An adversarial feed-forward table whose T_L rungs dip far below
    its T_S rungs must never invert the role split while the EWMA
    sweeps the whole load range (up and down): T_L >= T_S after every
    cycle of a full schedule trajectory."""
    evil = OperatingTable(
        target_mean_latency_us=15.0, service_rate_mpps=29.76,
        points=(
            OperatingPoint(rho=0.1, t_s_us=60.0, t_l_us=5.0, m=3,
                           mean_latency_us=10.0, cpu_fraction=0.1,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.5, t_s_us=30.0, t_l_us=2.0, m=3,
                           mean_latency_us=10.0, cpu_fraction=0.5,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.9, t_s_us=8.0, t_l_us=1.0, m=3,
                           mean_latency_us=10.0, cpu_fraction=0.9,
                           loss_fraction=0.0),
        ))
    pol = MetronomePolicy(
        MetronomeConfig(m=3, record_trajectory=True),
        operating_table=evil)
    sched = StepSchedule(times_us=(0.0, 15_000.0, 30_000.0),
                         scales=(0.2, 1.0, 0.3))
    cfg = SimRunConfig(duration_us=45_000.0, schedule=sched, seed=4)
    simulate_run(pol, PoissonWorkload(0.8 * 29.76), cfg)
    traj = pol.trajectory
    assert len(traj) > 100          # the loop actually cycled a lot
    for cycle, rho, ts, tl in traj:
        assert tl >= ts - 1e-9, (cycle, rho, ts, tl)
    # the trajectory really swept the schedule's load range
    rhos = np.asarray([r for _, r, _, _ in traj])
    assert rhos.min() < 0.3 and rhos.max() > 0.6


def test_operating_table_interpolation_is_continuous_at_knots():
    table = OperatingTable(
        target_mean_latency_us=15.0, service_rate_mpps=29.76,
        points=(
            OperatingPoint(rho=0.2, t_s_us=40.0, t_l_us=500.0, m=3,
                           mean_latency_us=12.0, cpu_fraction=0.2,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.5, t_s_us=20.0, t_l_us=300.0, m=3,
                           mean_latency_us=12.0, cpu_fraction=0.5,
                           loss_fraction=0.0),
            OperatingPoint(rho=0.8, t_s_us=10.0, t_l_us=150.0, m=3,
                           mean_latency_us=12.0, cpu_fraction=0.8,
                           loss_fraction=0.0),
        ))
    eps = 1e-9
    for knot in (0.2, 0.5, 0.8):
        lo_s, lo_l = table.timeouts_us(knot - eps)
        at_s, at_l = table.timeouts_us(knot)
        hi_s, hi_l = table.timeouts_us(knot + eps)
        assert lo_s == pytest.approx(at_s, abs=1e-6)
        assert hi_s == pytest.approx(at_s, abs=1e-6)
        assert lo_l == pytest.approx(at_l, abs=1e-6)
        assert hi_l == pytest.approx(at_l, abs=1e-6)
    # strictly between knots: linear interpolation, monotone here
    mid_s, _ = table.timeouts_us(0.35)
    assert 20.0 < mid_s < 40.0
    assert mid_s == pytest.approx((40.0 + 20.0) / 2)
    # outside the calibrated range: clamped, still continuous
    assert table.timeouts_us(0.0) == table.timeouts_us(0.2)
    assert table.timeouts_us(1.0) == table.timeouts_us(0.8)


def test_trajectory_recording_off_by_default_and_capped():
    ctl = MetronomeController(MetronomeConfig())
    ctl.on_cycle_end(1.0, 1.0)
    assert ctl.trajectory == []                # off by default
    ctl2 = MetronomeController(
        MetronomeConfig(record_trajectory=True, trajectory_cap=10))
    for _ in range(25):
        ctl2.on_cycle_end(1.0, 1.0)
    assert len(ctl2.trajectory) == 10          # bounded
    cyc, rho, ts, tl = ctl2.trajectory[-1]
    assert cyc == 10 and 0.0 <= rho <= 1.0 and tl >= ts
    # reset clears the trace (policies re-arm the controller in place)
    ctl2.__post_init__()
    assert ctl2.trajectory == []


def test_windows_surface_controller_ts_series():
    """The windowed series exposes the controller's T_S trajectory
    (ts_us), and it responds to the schedule: higher load -> shorter
    primary timeout."""
    sched = StepSchedule(times_us=(0.0, 20_000.0), scales=(0.25, 1.0))
    cfg = SimRunConfig(duration_us=40_000.0, schedule=sched,
                       window_us=2_000.0, seed=1)
    rs = simulate_run(MetronomePolicy(MetronomeConfig(alpha=0.125)),
                      PoissonWorkload(0.7 * 29.76), cfg)
    ts = rs.windows.ts_us
    lo = np.nanmean(ts[2:10])       # settled low-load windows
    hi = np.nanmean(ts[12:])        # settled high-load windows
    assert hi < lo                  # Eq 12: T_S shrinks as rho rises
    # rho estimate column tracks the step too
    assert np.nanmean(rs.windows.rho_est[12:]) > np.nanmean(
        rs.windows.rho_est[2:10]) + 0.2


def test_sinusoid_schedule_rho_tracking_rmse_is_small():
    sched = SinusoidSchedule(period_us=10_000.0, amplitude=0.3, mean=0.6)
    cfg = SimRunConfig(duration_us=40_000.0, schedule=sched,
                       window_us=1_000.0, seed=3)
    rs = simulate_run(MetronomePolicy(MetronomeConfig(alpha=0.2)),
                      PoissonWorkload(0.8 * 29.76), cfg)
    tk = rs.windows.tracking((), target_latency_us=50.0)
    assert tk.rho_rmse < 0.15       # EWMA follows a slow sinusoid
    assert tk.violation_fraction == 0.0
