"""Self-tests for ``repro.analysis``: the fixture suite.

Every rule ID has a known-bad snippet under ``tests/analysis_fixtures/``
(including a reconstruction of the PR-5 carry-shadowing bug) and a clean
twin.  Each pass must fire exactly on its bad fixture — right rule,
right count, right file — and stay silent on the twin.  The CLI tests
pin the exit-code contract the CI gate relies on (0 clean, 1 new
findings, 2 usage error) and the baseline's grandfathering semantics.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

import pytest

from repro.analysis import Baseline, Finding, registered_passes, run_analysis
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "analysis_fixtures"

# fixture -> exact rule-id multiset it must produce (and nothing else)
CASES = [
    ("units_mix_bad.py", {"UNITS001": 1}),
    ("units_mix_clean.py", {}),
    ("units_literal_bad.py", {"UNITS002": 1}),
    ("units_literal_clean.py", {}),
    ("scan_shadow_bad.py", {"SCAN001": 2}),   # shadow + dead overwrite
    ("scan_shadow_clean.py", {}),
    ("scan_impure_bad.py", {"SCAN002": 1}),
    ("scan_mutate_bad.py", {"SCAN003": 1}),
    ("scan_tracer_bad.py", {"SCAN004": 2}),   # if + float()
    ("scan_clean.py", {}),
    ("lock_cycle_bad.py", {"LOCK001": 1}),
    ("lock_block_bad.py", {"LOCK002": 1}),
    ("lock_stats_bad.py", {"LOCK003": 1}),
    ("lock_clean.py", {}),
    ("parity_bad", {"PARITY001": 2, "PARITY002": 3}),
    ("parity_clean", {}),
    ("race_write_bad.py", {"RACE001": 1}),
    ("race_write_clean.py", {}),
    ("race_rmw_bad.py", {"RACE002": 3}),      # 2 class RMWs + closure RMW
    ("race_rmw_clean.py", {}),
    ("race_cta_bad.py", {"RACE002": 2}),      # check-then-act, both roles
    ("race_cta_clean.py", {}),
    ("race_escape_bad.py", {"RACE003": 1}),
    ("race_escape_clean.py", {}),
]


def _analyze(name: str):
    return run_analysis([FIXTURES / name], root=REPO)


@pytest.mark.parametrize("name,expected", CASES,
                         ids=[c[0] for c in CASES])
def test_fixture_fires_exactly(name, expected):
    res = _analyze(name)
    assert Counter(f.rule for f in res.findings) == Counter(expected)
    for f in res.findings:
        assert f.path.startswith("tests/analysis_fixtures/")
        assert f.severity == "error"
        assert f.line > 0


def test_every_rule_has_a_bad_fixture():
    covered = {rid for _, exp in CASES for rid in exp}
    declared = {rid for ps in registered_passes() for rid in ps.rules}
    assert covered == declared


def test_pr5_reconstruction_both_hazards():
    # the PR-5 bug was two hazards at once: the carry element shadowed
    # the enclosing accumulator AND was overwritten before any read
    msgs = [f.message for f in _analyze("scan_shadow_bad.py").findings]
    assert any("shadows" in m for m in msgs)
    assert any("overwritten before" in m for m in msgs)
    assert all("'win'" in m for m in msgs)


def test_pr6_reconstruction_stats_buffering():
    # the PR-6 bug: sweep threads flushed stats counters with no guard;
    # the fixture reconstructs it and the RACE pass must name both
    # counters plus the function-scope twin of the same bug class
    msgs = [f.message for f in _analyze("race_rmw_bad.py").findings]
    assert any("'self.wakeups'" in m for m in msgs)
    assert any("'self.items'" in m for m in msgs)
    assert any("closed-over 'total'" in m for m in msgs)


def test_race_messages_name_roles_and_methods():
    write = _analyze("race_write_bad.py").findings[0]
    assert "_poll" in write.message          # the thread role
    assert "Telemetry" in write.message      # the class
    escape = _analyze("race_escape_bad.py").findings[0]
    assert "__init__" in escape.message      # where the late write lives


def test_clean_twins_are_parseable_python():
    # fixtures must stay real code: a syntax error would be silently
    # skipped by collect_files and turn every assertion above vacuous
    res = run_analysis([FIXTURES], root=REPO)
    assert len(res.files) == len(list(FIXTURES.rglob("*.py")))


# -- fingerprints and baseline semantics ------------------------------------


def _finding(line=1, message="m"):
    return Finding(rule="UNITS001", severity="error",
                   path="src/x.py", line=line, col=0, message=message)


def test_fingerprint_ignores_line_numbers():
    assert _finding(line=1).fingerprint == _finding(line=99).fingerprint
    assert (_finding(message="a").fingerprint
            != _finding(message="b").fingerprint)


def test_baseline_is_a_multiset():
    f = _finding()
    baseline = Baseline.from_findings([f])
    new, old = baseline.split([f, f])
    assert len(old) == 1 and len(new) == 1


def test_baseline_roundtrip(tmp_path):
    f = _finding()
    p = tmp_path / "b.json"
    Baseline.from_findings([f]).save(p)
    new, old = Baseline.load(p).split([f])
    assert not new and len(old) == 1


# -- CLI exit-code contract (what the CI gate runs) -------------------------


def test_cli_repo_is_clean():
    # the committed baseline is empty for runtime/core by construction
    # (ISSUE satellite: real findings were fixed, not grandfathered)
    assert analysis_main(["--paths", str(REPO / "src" / "repro")]) == 0


def test_cli_bad_fixture_exits_one(tmp_path, capsys):
    rc = analysis_main(["--paths", str(FIXTURES / "units_mix_bad.py"),
                        "--baseline", str(tmp_path / "b.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "UNITS001" in out


def test_cli_json_format(tmp_path, capsys):
    rc = analysis_main(["--paths", str(FIXTURES / "lock_stats_bad.py"),
                        "--baseline", str(tmp_path / "b.json"),
                        "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["schema"] == "repro-analysis/1"
    assert not payload["ok"]
    assert [f["rule"] for f in payload["new"]] == ["LOCK003"]


def test_cli_update_baseline_grandfathers(tmp_path, capsys):
    bad = str(FIXTURES / "scan_impure_bad.py")
    baseline = str(tmp_path / "b.json")
    assert analysis_main(["--paths", bad, "--baseline", baseline,
                          "--update-baseline"]) == 0
    # grandfathered: same findings no longer gate...
    assert analysis_main(["--paths", bad, "--baseline", baseline]) == 0
    # ...but a finding outside the baseline still does
    rc = analysis_main(["--paths", bad,
                        str(FIXTURES / "scan_mutate_bad.py"),
                        "--baseline", baseline])
    capsys.readouterr()
    assert rc == 1


def test_cli_json_rule_counts(tmp_path, capsys):
    rc = analysis_main(["--paths", str(FIXTURES / "race_rmw_bad.py"),
                        "--baseline", str(tmp_path / "b.json"),
                        "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["rule_counts"] == {"RACE002": 3}
    assert "RACE001" in payload["rules_known"]


def test_cli_since_scopes_and_intersects(monkeypatch, tmp_path, capsys):
    # the git diff says two files changed; only the one under --paths
    # may be scanned (a changed src file must not leak into a
    # fixtures-scoped run)
    import repro.analysis.__main__ as cli
    changed = [FIXTURES / "units_mix_bad.py",
               REPO / "src" / "repro" / "analysis" / "core.py"]
    monkeypatch.setattr(cli, "_changed_files", lambda root, since: changed)
    rc = cli.main(["--since", "some-rev", "--paths", str(FIXTURES),
                   "--baseline", str(tmp_path / "b.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "UNITS001" in out
    assert "1 file(s) scanned" in out


def test_cli_bad_revision_exits_two(capsys):
    rc = analysis_main(["--since", "definitely-not-a-revision"])
    err = capsys.readouterr().err
    assert rc == 2
    assert "git diff" in err


def test_cli_since_conflicts_with_changed_only():
    with pytest.raises(SystemExit) as ei:
        analysis_main(["--since", "HEAD", "--changed-only"])
    assert ei.value.code == 2


def test_cli_missing_path_exits_two(capsys):
    rc = analysis_main(["--paths", str(FIXTURES / "no_such_file.py")])
    capsys.readouterr()
    assert rc == 2


def test_cli_corrupt_baseline_exits_two(tmp_path, capsys):
    bad = tmp_path / "b.json"
    bad.write_text("not json{")
    rc = analysis_main(["--paths", str(FIXTURES / "scan_clean.py"),
                        "--baseline", str(bad)])
    err = capsys.readouterr().err
    assert rc == 2
    assert "unreadable baseline" in err
