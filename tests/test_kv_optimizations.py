"""int8 KV cache + ring-buffer local KV: correctness vs the full-precision
full-length reference decode path (§Perf B2/C1 optimizations)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model


def _teacher_force(cfg, s=24, b=2, max_len=40, seed=3):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)
    cache = model.init_cache(b, max_len)
    decode = jax.jit(model.decode_step)
    outs = []
    for i in range(s - 1):
        pos = jnp.full((b,), i, jnp.int32)
        logits, cache = decode(params, toks[:, i], cache, pos)
        outs.append(logits)
    return jnp.stack(outs, 1), params, toks


def test_int8_kv_decode_close_to_fp():
    base_cfg = get_config("granite-3-8b").reduced()
    ref, params, toks = _teacher_force(base_cfg)
    q_cfg = dataclasses.replace(base_cfg, kv_quant=True)
    got, _, _ = _teacher_force(q_cfg)
    # int8 KV: small logit perturbation, same argmax nearly everywhere
    diff = np.abs(np.asarray(ref) - np.asarray(got))
    rel = diff.max() / max(np.abs(np.asarray(ref)).max(), 1e-9)
    assert rel < 0.08, rel
    agree = (np.asarray(ref.argmax(-1)) == np.asarray(got.argmax(-1))).mean()
    assert agree > 0.95, agree


def test_int8_kv_prefill_then_decode():
    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              kv_quant=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits_full, _ = jax.jit(model.forward)(params, {"tokens": toks})
    logits_p, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :12]})

    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == 12:
            pw = [(0, 0)] * leaf.ndim
            pw[2] = (0, 8)
            return jnp.pad(leaf, pw)
        return leaf

    cache = jax.tree.map(pad, cache)
    decode = jax.jit(model.decode_step)
    for i in range(12, s):
        lg, cache = decode(params, toks[:, i],
                           cache, jnp.full((b,), i, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, i]),
                                   rtol=0.15, atol=0.15)


def test_ring_buffer_local_kv_matches_full_cache():
    """gemma2-style local layers with ring cache == full cache + masking."""
    base = get_config("gemma2-2b").reduced()     # local_window=8, period 2
    ref, _, _ = _teacher_force(base, s=24, max_len=40)
    ring_cfg = dataclasses.replace(base, kv_ring=True)
    model = Model(ring_cfg)
    cache = model.init_cache(2, 40)
    # local layers (layer0 of each pair) must have window-sized cache
    assert cache["layer0"]["k"].shape[2] == base.local_window
    assert cache["layer1"]["k"].shape[2] == 40
    got, _, _ = _teacher_force(ring_cfg, s=24, max_len=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ring_plus_quant_compose():
    base = get_config("gemma2-2b").reduced()
    cfg = dataclasses.replace(base, kv_ring=True, kv_quant=True)
    ref, _, _ = _teacher_force(base, s=20, max_len=32)
    got, _, _ = _teacher_force(cfg, s=20, max_len=32)
    agree = (np.asarray(ref.argmax(-1)) == np.asarray(got.argmax(-1))).mean()
    assert agree > 0.9, agree
