"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode),
per the assignment: "For each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle"."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),       # MHA
    (2, 256, 8, 2, 64, 128, 64),      # GQA 4:1
    (1, 192, 4, 1, 128, 64, 96),      # MQA, uneven blocks
    (1, 64, 2, 2, 256, 64, 64),       # gemma-style hd=256
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(dtype, b, s, h, kv, hd, bq, bk, causal):
    key = jax.random.PRNGKey(hash((b, s, h, kv, hd, causal)) % 2**31)
    q = _rand(key, (b, s, h, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (b, s, kv, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = flash_attention(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window,softcap", [(32, 0.0), (0, 20.0), (64, 30.0)])
def test_flash_attention_window_and_softcap(window, softcap):
    key = jax.random.PRNGKey(7)
    q = _rand(key, (2, 128, 4, 64), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (2, 128, 2, 64), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (2, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          block_q=64, block_k=32)
    ref = flash_attention(q, k, v, window=window, softcap=softcap,
                          use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """Kernel must agree with the model's _sdpa (the path it replaces)."""
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = get_config("gemma2-2b").reduced()
    key = jax.random.PRNGKey(3)
    b, s, h, kv, hd = 2, 64, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = _rand(key, (b, s, h, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, s, kv, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, s, kv, hd), jnp.float32)
    mask = A._causal_mask(s, s, 0, 0)[None, None, None]
    ref = A._sdpa(cfg, q, k, v, mask)
    out = flash_attention(q, k, v, causal=True,
                          softcap=cfg.attn_softcap, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,t,bk", [
    (2, 4, 4, 64, 256, 64),
    (3, 8, 2, 64, 512, 128),
    (1, 4, 1, 128, 256, 256),
])
def test_decode_attention_matches_oracle(dtype, b, h, kv, hd, t, bk):
    key = jax.random.PRNGKey(hash((b, h, kv, hd, t)) % 2**31)
    q = _rand(key, (b, h, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (b, t, kv, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (b, t, kv, hd), dtype)
    pos = jax.random.randint(jax.random.fold_in(key, 3), (b,), 0, t)
    out = decode_attention(q, k, v, pos, block_k=bk)
    ref = decode_attention(q, k, v, pos, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_decode_attention_ragged_positions():
    """Continuous-batching semantics: each sequence has its own length."""
    key = jax.random.PRNGKey(11)
    b, h, kv, hd, t = 4, 4, 2, 64, 128
    q = _rand(key, (b, h, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, t, kv, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, t, kv, hd), jnp.float32)
    pos = jnp.array([0, 1, 63, 127], jnp.int32)
    out = decode_attention(q, k, v, pos, block_k=32)
    ref = decode_attention(q, k, v, pos, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # pos=0 attends only to kv[0] => must equal v[0] (GQA-averaged heads)
    expect = v[0, 0]                          # (kv, hd)
    got = np.asarray(out[0]).reshape(kv, h // kv, hd)
    np.testing.assert_allclose(got[0, 0], np.asarray(expect[0]), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_decode_attention_local_window(window):
    key = jax.random.PRNGKey(13)
    b, h, kv, hd, t = 2, 4, 4, 64, 128
    q = _rand(key, (b, h, hd), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, t, kv, hd), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, t, kv, hd), jnp.float32)
    pos = jnp.array([100, 127], jnp.int32)
    out = decode_attention(q, k, v, pos, window=window, block_k=32)
    ref = decode_attention(q, k, v, pos, window=window, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,L,nh,hd,n,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 32, 64, 32),
    (1, 256, 2, 64, 128, 64),         # mamba2-370m-like head
])
def test_ssd_scan_matches_oracle(dtype, b, L, nh, hd, n, chunk):
    key = jax.random.PRNGKey(hash((b, L, nh, hd, n)) % 2**31)
    x = _rand(key, (b, L, nh, hd), dtype)
    dt = jax.nn.softplus(_rand(jax.random.fold_in(key, 1), (b, L, nh),
                               jnp.float32))
    a = -jnp.exp(_rand(jax.random.fold_in(key, 2), (nh,), jnp.float32) * 0.3)
    bm = _rand(jax.random.fold_in(key, 3), (b, L, n), jnp.float32) * 0.3
    cm = _rand(jax.random.fold_in(key, 4), (b, L, n), jnp.float32) * 0.3
    yk, hk = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    yr, hr = ssd_scan(x, dt, a, bm, cm, chunk=chunk, use_kernel=False)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), **tol)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the model's ssd_chunked (the path it accelerates)."""
    key = jax.random.PRNGKey(5)
    b, L, nh, hd, n, chunk = 2, 96, 3, 16, 32, 32
    x = _rand(key, (b, L, nh, hd), jnp.float32)
    dt = jax.nn.softplus(_rand(jax.random.fold_in(key, 1), (b, L, nh),
                               jnp.float32))
    a = -jnp.exp(_rand(jax.random.fold_in(key, 2), (nh,), jnp.float32) * 0.3)
    bm = _rand(jax.random.fold_in(key, 3), (b, L, n), jnp.float32) * 0.3
    cm = _rand(jax.random.fold_in(key, 4), (b, L, n), jnp.float32) * 0.3
    yk, hk = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    ym, hm = ssd_chunked(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hm),
                               rtol=3e-5, atol=3e-5)


def test_ssd_scan_state_continuity():
    """Chunk boundaries must be invisible: scanning L tokens in one call
    equals scanning with a different chunk size."""
    key = jax.random.PRNGKey(9)
    b, L, nh, hd, n = 1, 128, 2, 16, 16
    x = _rand(key, (b, L, nh, hd), jnp.float32)
    dt = jax.nn.softplus(_rand(jax.random.fold_in(key, 1), (b, L, nh),
                               jnp.float32))
    a = -jnp.exp(_rand(jax.random.fold_in(key, 2), (nh,), jnp.float32) * 0.3)
    bm = _rand(jax.random.fold_in(key, 3), (b, L, n), jnp.float32) * 0.3
    cm = _rand(jax.random.fold_in(key, 4), (b, L, n), jnp.float32) * 0.3
    y16, h16 = ssd_scan(x, dt, a, bm, cm, chunk=16)
    y64, h64 = ssd_scan(x, dt, a, bm, cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64),
                               rtol=2e-4, atol=2e-4)
