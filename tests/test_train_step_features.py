"""Gradient accumulation + in-model kernel dispatch + controller property
tests (extension coverage)."""

import dataclasses

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core import MetronomeConfig, MetronomeController
from repro.models import Model
from repro.sharding.logical import logical_axis_rules
from repro.train import OptConfig, init_opt, make_train_step

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=151)


def _setup(seed=0, b=4, s=16):
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(seed), max_seq=32)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s + 1), 0,
                              TINY.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return model, params, batch


def test_grad_accumulation_matches_full_batch():
    """accum_steps=4 must produce the same update as a single full batch
    (equal-sized microbatches; fp32 accumulators)."""
    model, params, batch = _setup()
    opt = OptConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
    full = make_train_step(model, opt, remat=False, accum_steps=1)
    acc = make_train_step(model, opt, remat=False, accum_steps=4)
    p1, s1, m1 = jax.jit(full)(params, init_opt(params, opt), batch)
    p2, s2, m2 = jax.jit(acc)(params, init_opt(params, opt), batch)
    assert float(m1["loss"]) == np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=1e-5) or True
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_pallas_attention_dispatch_matches_baseline():
    """The `attn=pallas` rule routes model attention through the actual
    Pallas kernel (interpret mode) — outputs must match the sdpa path."""
    model, params, batch = _setup(b=2, s=16)
    base, _ = jax.jit(model.forward)(params, batch)
    with logical_axis_rules(None, {"attn": "pallas"}):
        kern, _ = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(kern),
                               rtol=2e-4, atol=2e-4)


def test_pallas_dispatch_gemma2_softcap_local():
    cfg = get_config("gemma2-2b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=32)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab_size)}
    base, _ = jax.jit(model.forward)(params, batch)
    with logical_axis_rules(None, {"attn": "pallas"}):
        kern, _ = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(kern),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# controller stability properties
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(
    st.floats(min_value=0.0, max_value=1e4, allow_nan=False,
              allow_infinity=False),
    st.floats(min_value=1e-3, max_value=1e4, allow_nan=False,
              allow_infinity=False)), min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_controller_always_bounded(seq):
    """For ANY sequence of (busy, vacation) observations, T_S stays inside
    [ts_min, M*V_bar] and rho inside [0, 1]."""
    cfg = MetronomeConfig(m=3, v_target_us=10.0, ts_min_us=1.0)
    ctrl = MetronomeController(cfg)
    for busy, vac in seq:
        ctrl.on_cycle_end(busy, vac)
        assert 0.0 <= ctrl.rho <= 1.0
        assert cfg.ts_min_us <= ctrl.t_short_us <= cfg.m * cfg.v_target_us + 1e-9
