"""SCAN002 fixture: ``random.random()`` inside a scan step runs once
at trace time and bakes a single constant into the compiled loop."""
import random

import jax


def noisy_sum(xs):
    def step(carry, x):
        jitter = random.random()
        return carry + x * jitter, None

    total, _ = jax.lax.scan(step, 0.0, xs)
    return total
