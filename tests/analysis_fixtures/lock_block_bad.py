"""LOCK002 fixture: a blocking ``with`` acquisition inside the region
where a queue TryLock is held — the owner can stall every producer."""
import threading


class Poller:
    def __init__(self, queue):
        self.queue = queue
        self._io_lock = threading.Lock()

    def drain(self):
        q = self.queue
        if q.lock.try_acquire():
            try:
                with self._io_lock:
                    pass
            finally:
                q.lock.release()
