"""SCAN004 fixture: Python ``if`` and ``float()`` on tracer values
inside a scan step — both force concretization once actually traced."""
import jax


def clamp_sum(xs, limit):
    def step(carry, x):
        if x > limit:
            x = limit
        return carry + float(x), None

    total, _ = jax.lax.scan(step, 0.0, xs)
    return total
