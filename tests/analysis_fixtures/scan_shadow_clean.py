"""Clean twin of scan_shadow_bad: the carry element keeps a distinct
name from every pre-def enclosing binding, and is read before it is
updated — the carried value survives."""
import jax
import jax.numpy as jnp


def run(n_slots, stall_mean_us):
    acc0 = jnp.zeros(4)

    def step(carry, t):
        (backlog, win_acc) = carry
        stall = t + stall_mean_us
        win_acc = win_acc + stall
        backlog = backlog + win_acc
        return (backlog, win_acc), None

    (backlog, win_acc), _ = jax.lax.scan(
        step, (jnp.zeros(4), acc0), jnp.arange(n_slots))
    return backlog, win_acc
