"""SCAN003 fixture: appending to a closed-over list inside a scan step
is a trace-time side effect — it runs once, not per step."""
import jax


def collect(xs):
    seen = []

    def step(carry, x):
        seen.append(x)
        return carry + x, None

    total, _ = jax.lax.scan(step, 0.0, xs)
    return total, seen
