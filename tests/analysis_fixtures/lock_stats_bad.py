"""LOCK003 fixture: ``self.stats`` is established as stats-family by
the guarded write in ``_loop``; the second write in the same method
skips the guard and races the poller threads.  The write in ``stop``
is exempt — lifecycle methods run while the threads are quiescent."""
import threading


class Worker:
    def __init__(self):
        self.stats = {"items": 0}
        self._stats_lock = threading.Lock()

    def _loop(self):
        with self._stats_lock:
            self.stats["items"] += 1
        self.stats["items"] += 2

    def stop(self):
        self.stats["items"] = 0
