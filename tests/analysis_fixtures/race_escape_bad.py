"""RACE003 known-bad: ``self`` escapes half-constructed.  The worker
thread starts inside ``__init__`` and immediately reads
``self.batches`` — which is only assigned on the *next* line, so the
thread can observe the attribute missing entirely."""
import threading


class Loader:
    def __init__(self, src):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run)
        self._thread.start()
        self.batches = iter(src)

    def _run(self):
        while not self._stop.is_set():
            with self._lock:
                item = next(self.batches, None)
            if item is None:
                return

    def close(self):
        self._stop.set()
        self._thread.join()
