"""Clean twin of units_mix_bad: the conversion is written down."""


def total_wait(duration_us, overshoot_ns):
    overshoot_us = overshoot_ns / 1_000
    return duration_us + overshoot_us
