"""RACE002 known-bad (check-then-act): the worker thread and the
caller both run ``if not self.claimed: self.claimed = True`` with no
lock — the test and the act are not atomic, so both can win."""
import threading


class Claim:
    def __init__(self):
        self._lock = threading.Lock()
        self._running = threading.Event()
        self.claimed = False
        self._thread = None

    def start(self):
        self._running.set()
        self._thread = threading.Thread(target=self._work)
        self._thread.start()

    def stop(self):
        self._running.clear()
        self._thread.join()

    def _work(self):
        while self._running.is_set():
            if not self.claimed:
                self.claimed = True
                return

    def grab(self):
        if not self.claimed:
            self.claimed = True
            return True
        return False
