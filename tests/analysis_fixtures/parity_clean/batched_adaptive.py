"""Engine-parity fixture (clean side), adaptive engine: discovery pairs
every ``ENGINE_BASENAMES`` sibling with the config class, and this one
also reads-or-declares every field."""

_EVENT_ENGINE_ONLY_FIELDS = ("timeseries_bin_us",)


def adaptive_sweep_arrays(cfg):
    return cfg.duration_us * cfg.service_rate_mpps
