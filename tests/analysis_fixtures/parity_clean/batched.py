"""Engine-parity fixture (clean side): reads two fields, declares the
third as deliberately event-engine-only."""

_EVENT_ENGINE_ONLY_FIELDS = ("timeseries_bin_us",)


def simulate_batch(cfg):
    return cfg.duration_us * cfg.service_rate_mpps
