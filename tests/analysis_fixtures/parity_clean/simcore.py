"""Engine-parity fixture (clean side): every field is either read by
the sibling engine or declared in one of its *_FIELDS tuples."""
from dataclasses import dataclass


@dataclass(frozen=True)
class SimRunConfig:
    duration_us: float = 1_000.0
    service_rate_mpps: float = 29.76
    timeseries_bin_us: float = 50.0
