"""RACE002 known-bad: the PR-6 stats-buffering bug, reconstructed.

Two sweep threads drain queues under each queue's TryLock, then flush
their counters with ``self.wakeups += 1`` / ``self.items += got`` and
*no* guard — exactly the shape PR 6 fixed in ``Runtime._run`` by
buffering during the sweep and flushing under ``_stats_lock``.  A
load-op-store is not atomic even under the GIL, so concurrent sweeps
lose updates.  A function-scope twin of the same bug class rides along:
``total += 1`` on a closed-over name from N spawned threads.
"""
import threading


class Poller:
    def __init__(self, queues):
        self.queues = queues
        self.wakeups = 0
        self.items = 0
        self._flush_lock = threading.Lock()
        self._running = threading.Event()
        self._workers = []

    def start(self):
        self._running.set()
        self._workers = [threading.Thread(target=self._sweep)
                         for _ in range(2)]
        for t in self._workers:
            t.start()

    def stop(self):
        self._running.clear()
        for t in self._workers:
            t.join()

    def _sweep(self):
        while self._running.is_set():
            got = 0
            for q in self.queues:
                if q.lock.try_acquire():
                    try:
                        got += len(q.poll())
                    finally:
                        q.lock.release()
            self.wakeups += 1
            self.items += got

    def snapshot(self):
        with self._flush_lock:
            return (self.wakeups, self.items)


def run_workers(n):
    total = 0

    def work():
        nonlocal total
        for _ in range(1000):
            total += 1

    threads = [threading.Thread(target=work) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return total
