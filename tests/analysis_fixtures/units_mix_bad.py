"""UNITS001 fixture: us + ns arithmetic with no conversion factor."""


def total_wait(duration_us, overshoot_ns):
    return duration_us + overshoot_ns
