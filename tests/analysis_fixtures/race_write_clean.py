"""Clean twin of race_write_bad: every write to ``last_seen`` happens
under the same lock, so the write locksets intersect."""
import threading


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self._running = threading.Event()
        self.last_seen = 0
        self._threads = []

    def start(self):
        self._running.set()
        self._threads = [threading.Thread(target=self._poll)]
        for t in self._threads:
            t.start()

    def stop(self):
        self._running.clear()
        for t in self._threads:
            t.join()

    def _poll(self):
        while self._running.is_set():
            with self._lock:
                self.last_seen = 1

    def record(self, value):
        with self._lock:
            self.last_seen = value
