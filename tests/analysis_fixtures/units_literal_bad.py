"""UNITS002 fixture: one bare literal flows into a ns slot AND a us
slot — at least one of the two uses is off by a factor of 1000."""


def arm_timers(sleep_fn):
    timeout = 500
    sleep_ns = timeout
    budget_us = timeout
    return sleep_fn(sleep_ns), budget_us
