"""Clean twin of race_cta_bad: the test and the act sit inside one
lock region, so check-then-act is atomic."""
import threading


class Claim:
    def __init__(self):
        self._lock = threading.Lock()
        self._running = threading.Event()
        self.claimed = False
        self._thread = None

    def start(self):
        self._running.set()
        self._thread = threading.Thread(target=self._work)
        self._thread.start()

    def stop(self):
        self._running.clear()
        self._thread.join()

    def _work(self):
        while self._running.is_set():
            with self._lock:
                if not self.claimed:
                    self.claimed = True
                    return

    def grab(self):
        with self._lock:
            if not self.claimed:
                self.claimed = True
                return True
        return False
