"""Clean twin for the scan-purity rules: folded-in jax randomness, no
closure mutation, branchless clamping, no casts."""
import jax
import jax.numpy as jnp


def clamp_sum(xs, limit):
    def step(carry, x):
        key = jax.random.fold_in(jax.random.PRNGKey(0), 7)
        jitter = jax.random.uniform(key, ())
        x = jnp.minimum(x, limit)
        return carry + x * jitter, None

    total, _ = jax.lax.scan(step, 0.0, xs)
    return total
