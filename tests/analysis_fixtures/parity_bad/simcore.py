"""Engine-parity fixture (bad side): ``window_us`` is a config field
the sibling batched engine neither reads nor declares — PARITY001."""
from dataclasses import dataclass


@dataclass(frozen=True)
class SimRunConfig:
    duration_us: float = 1_000.0
    service_rate_mpps: float = 29.76
    window_us: float = 0.0
