"""Engine-parity fixture (bad side): both stale-declaration shapes.

``_EVENT_ONLY_FIELDS`` names a field that no longer exists on the
config class; ``_GRID_FIELDS`` names one the engine actually reads.
Each is a PARITY002.
"""

_EVENT_ONLY_FIELDS = ("timeseries_bin_us",)
_GRID_FIELDS = ("duration_us",)


def simulate_batch(cfg):
    return cfg.duration_us * cfg.service_rate_mpps
