"""Engine-parity fixture (bad side), adaptive engine: the adaptive
sibling is checked independently, so ``window_us`` (unread, undeclared
here too) is a second PARITY001, and the stale ``_JUMP_FIELDS`` entry
naming a nonexistent config field is a third PARITY002."""

_JUMP_FIELDS = ("no_such_knob_us",)


def adaptive_sweep_arrays(cfg):
    return cfg.duration_us * cfg.service_rate_mpps
