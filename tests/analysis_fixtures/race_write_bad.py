"""RACE001 known-bad: ``last_seen`` is written by the poller thread and
by the caller with no common lock, so the writes interleave."""
import threading


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self._running = threading.Event()
        self.last_seen = 0
        self._threads = []

    def start(self):
        self._running.set()
        self._threads = [threading.Thread(target=self._poll)]
        for t in self._threads:
            t.start()

    def stop(self):
        self._running.clear()
        for t in self._threads:
            t.join()

    def _poll(self):
        while self._running.is_set():
            self.last_seen = 1

    def record(self, value):
        self.last_seen = value
