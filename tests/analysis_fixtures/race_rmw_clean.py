"""Clean twin of race_rmw_bad: the PR-6 *fix*.  Counters are buffered
in locals during the TryLock sweep and flushed under one lock; the
function-scope accumulator takes a lock around its increment."""
import threading


class Poller:
    def __init__(self, queues):
        self.queues = queues
        self.wakeups = 0
        self.items = 0
        self._flush_lock = threading.Lock()
        self._running = threading.Event()
        self._workers = []

    def start(self):
        self._running.set()
        self._workers = [threading.Thread(target=self._sweep)
                         for _ in range(2)]
        for t in self._workers:
            t.start()

    def stop(self):
        self._running.clear()
        for t in self._workers:
            t.join()

    def _sweep(self):
        while self._running.is_set():
            got = 0
            for q in self.queues:
                if q.lock.try_acquire():
                    try:
                        got += len(q.poll())
                    finally:
                        q.lock.release()
            with self._flush_lock:
                self.wakeups += 1
                self.items += got

    def snapshot(self):
        with self._flush_lock:
            return (self.wakeups, self.items)


def run_workers(n):
    total = 0
    total_lock = threading.Lock()

    def work():
        nonlocal total
        for _ in range(1000):
            with total_lock:
                total += 1

    threads = [threading.Thread(target=work) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return total
