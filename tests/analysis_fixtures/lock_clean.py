"""Clean twin for the lock-discipline rules: consistent acquisition
order, nothing blocking inside the TryLock region (stats are buffered
and flushed after release), every stats write guarded."""
import threading


class Worker:
    def __init__(self):
        self.stats = {"items": 0}
        self._stats_lock = threading.Lock()
        self._intake_lock = threading.Lock()
        self._drain_lock = threading.Lock()

    def forward(self):
        with self._intake_lock:
            with self._drain_lock:
                pass

    def backward(self):
        with self._intake_lock:
            with self._drain_lock:
                pass

    def tally(self, queue):
        pending = []
        if queue.lock.try_acquire():
            try:
                pending.append(1)
            finally:
                queue.lock.release()
        with self._stats_lock:
            self.stats["items"] += len(pending)
