"""SCAN001 fixture: reconstruction of the PR-5 carry-shadowing bug.

The windowed accumulator ``win`` is carried through the scan, but the
step body (a) names its carry element after the enclosing function's
``win`` local and (b) overwrites it before ever reading it — so the
carried window state is silently dropped every step, exactly the bug
PR 5 shipped and had to fix.
"""
import jax
import jax.numpy as jnp


def run(n_slots, stall_mean_us):
    win = jnp.zeros(4)

    def step(carry, t):
        (backlog, win) = carry
        win = t + stall_mean_us
        backlog = backlog + win
        return (backlog, win), None

    (backlog, win_out), _ = jax.lax.scan(
        step, (jnp.zeros(4), win), jnp.arange(n_slots))
    return backlog, win_out
