"""LOCK001 fixture: two methods acquire the same pair of locks in
opposite orders — a deadlock when the acquisitions interleave."""
import threading


class Pipeline:
    def __init__(self):
        self._intake_lock = threading.Lock()
        self._drain_lock = threading.Lock()

    def forward(self):
        with self._intake_lock:
            with self._drain_lock:
                pass

    def backward(self):
        with self._drain_lock:
            with self._intake_lock:
                pass
