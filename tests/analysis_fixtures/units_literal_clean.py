"""Clean twin of units_literal_bad: each slot gets its own literal in
its own unit."""


def arm_timers(sleep_fn):
    timeout_ns = 500_000
    budget_us = 500
    return sleep_fn(timeout_ns), budget_us
