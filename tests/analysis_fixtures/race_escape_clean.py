"""Clean twin of race_escape_bad: every field the worker reads is
assigned before the thread starts — ``start()`` is the last thing
``__init__`` does."""
import threading


class Loader:
    def __init__(self, src):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.batches = iter(src)
        self._thread = threading.Thread(target=self._run)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            with self._lock:
                item = next(self.batches, None)
            if item is None:
                return

    def close(self):
        self._stop.set()
        self._thread.join()
