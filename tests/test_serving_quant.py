"""Serving engine end-to-end on the int8-KV configuration (§Perf B2 in the
production path, not just the dry-run)."""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving import EngineConfig, InferenceEngine, Request

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=101)


def _engine(cfg):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    return InferenceEngine(model, params,
                           EngineConfig(max_slots=3, max_len=64,
                                        prefill_buckets=(8,)))


def test_engine_runs_with_int8_kv_and_mostly_agrees():
    fp = _engine(TINY)
    q8 = _engine(dataclasses.replace(TINY, kv_quant=True))
    outs = {}
    for name, eng in (("fp", fp), ("q8", q8)):
        reqs = [Request(prompt=[i + 1, i + 2, i + 3], max_new_tokens=6)
                for i in range(5)]
        eng.submit(reqs)
        eng.pump()
        assert all(len(r.tokens) == 6 for r in reqs)
        outs[name] = [r.tokens for r in reqs]
    agree = np.mean([a == b for a, b in zip(outs["fp"], outs["q8"])])
    # greedy decode sequences agree for most requests on this tiny model
    flat_agree = np.mean([t1 == t2
                          for a, b in zip(outs["fp"], outs["q8"])
                          for t1, t2 in zip(a, b)])
    assert flat_agree > 0.8, (flat_agree, outs)


def test_engine_int8_cache_dtype():
    eng = _engine(dataclasses.replace(TINY, kv_quant=True))
    leaves = jax.tree.leaves(eng.cache)
    dtypes = {str(l.dtype) for l in leaves}
    assert "int8" in dtypes and "float32" in dtypes
    # int8 codes are half the bytes of the bf16 cache
    fp_eng = _engine(TINY)
    q_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(eng.cache))
    f_bytes = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(fp_eng.cache))
    assert q_bytes < 0.8 * f_bytes
