"""Batched JAX engine: parity vs the exact event engine, closed-form
cross-validation, and one-call sweep scale (the acceptance criteria of
the batched-backend refactor)."""

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.core import analytics as an
from repro.runtime import (
    MetronomePolicy,
    PoissonWorkload,
    SimRunConfig,
    SweepGrid,
    simulate_batch,
    simulate_run,
)
from repro.runtime.simcore import HR_SLEEP_MODEL, PERFECT_SLEEP_MODEL

# Documented parity tolerance (see repro/runtime/batched.py docstring):
# stable region, n_queues=1 —
#   mean sojourn within max(1.5us, 12%), cpu within 0.02 + 5%.
LAT_ABS_US, LAT_REL = 1.5, 0.12
CPU_ABS, CPU_REL = 0.02, 0.05
# Under interference (interference_prob > 0 AND stall_rate_per_us > 0)
# the band widens — heavy-tailed stall windows put finite-sample noise
# in both engines' means:
#   mean sojourn within max(4.5us, 22%), cpu within 0.025 + 6%, loss
#   within 0.03 absolute of the event engine.
ILAT_ABS_US, ILAT_REL = 4.5, 0.22
ICPU_ABS, ICPU_REL = 0.025, 0.06
ILOSS_ABS = 0.03

# the noisy-host environment the interference parity band is pinned in:
# a quarter of all wakes delayed by Exp(20us) (co-scheduled app), plus
# Exp(150us) system-wide stall windows every ~4ms (kernel pile-ups)
INTERFERENCE_ENV = dict(interference_prob=0.25, interference_mean_us=20.0,
                        stall_rate_per_us=1.0 / 4000.0, stall_mean_us=150.0)


def _random_configs(n=24, seed=42):
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        t_s = float(rng.uniform(5.0, 40.0))
        pts.append(dict(
            t_s_us=t_s,
            t_l_us=float(t_s * rng.uniform(4.0, 25.0)),
            m=int(rng.integers(1, 5)),
            rate_mpps=float(rng.uniform(0.15, 0.85) * 29.76),
            seed=i))
    return pts


@pytest.mark.slow
def test_parity_with_event_engine_24_random_configs():
    """>= 20 randomly drawn static configs: BOTH stepping modes' mean
    sojourn and CPU fraction agree with simulate_run within the
    documented tolerance (one event-engine truth run per config), and
    adaptive agrees with fixed inside the same bands."""
    pts = _random_configs()
    cfg = SimRunConfig(duration_us=120_000.0, sleep_model=HR_SLEEP_MODEL)
    grid = SweepGrid.of_points(pts)
    bs = simulate_batch(grid, cfg, slot_us=0.5)
    ba = simulate_batch(grid, cfg, slot_us=0.5, stepping="adaptive")
    for i, p in enumerate(pts):
        policy = MetronomePolicy(
            MetronomeConfig(m=p["m"], v_target_us=p["t_s_us"],
                            t_long_us=p["t_l_us"],
                            ts_min_us=min(1.0, p["t_s_us"])),
            adaptive=False)
        rs = simulate_run(policy, PoissonWorkload(p["rate_mpps"]), cfg)
        for tag, b in (("fixed", bs), ("adaptive", ba)):
            lat_b, lat_e = float(b.mean_latency_us[i]), rs.mean_sojourn_us
            cpu_b, cpu_e = float(b.cpu_fraction[i]), rs.cpu_fraction
            assert abs(lat_b - lat_e) <= max(LAT_ABS_US, LAT_REL * lat_e), \
                (tag, p, lat_b, lat_e)
            assert abs(cpu_b - cpu_e) <= CPU_ABS + CPU_REL * cpu_e, \
                (tag, p, cpu_b, cpu_e)
            # secondary accounting: wakeups within 15%, loss ~0
            assert b.wakeups[i] == pytest.approx(rs.wakeups, rel=0.15)
            assert float(b.loss_fraction[i]) < 1e-3
        assert rs.loss_fraction < 1e-3
        # adaptive-vs-fixed inside the same band
        lat_f, lat_a = float(bs.mean_latency_us[i]), \
            float(ba.mean_latency_us[i])
        assert abs(lat_a - lat_f) <= max(LAT_ABS_US, LAT_REL * lat_f), \
            (p, lat_a, lat_f)
        assert abs(float(ba.cpu_fraction[i]) - float(bs.cpu_fraction[i])) \
            <= CPU_ABS + CPU_REL * float(bs.cpu_fraction[i]), p
    # and the whole point of the adaptive kernel: far fewer live steps
    assert float(ba.n_steps.mean()) < 0.35 * float(bs.n_steps.mean())


@pytest.mark.slow
def test_parity_under_interference_16_random_configs():
    """Tentpole acceptance: >= 16 randomly drawn configs in a noisy-host
    environment (per-wake interference AND correlated stalls both
    active): batched mean sojourn / CPU / loss agree with simulate_run
    within the documented interference band."""
    pts = _random_configs(n=16, seed=7)
    cfg = SimRunConfig(duration_us=120_000.0, sleep_model=HR_SLEEP_MODEL,
                       **INTERFERENCE_ENV)
    assert cfg.interference_prob > 0 and cfg.stall_rate_per_us > 0
    grid = SweepGrid.of_points(pts)
    bs = simulate_batch(grid, cfg, slot_us=0.5)
    ba = simulate_batch(grid, cfg, slot_us=0.5, stepping="adaptive")
    for i, p in enumerate(pts):
        policy = MetronomePolicy(
            MetronomeConfig(m=p["m"], v_target_us=p["t_s_us"],
                            t_long_us=p["t_l_us"],
                            ts_min_us=min(1.0, p["t_s_us"])),
            adaptive=False)
        rs = simulate_run(policy, PoissonWorkload(p["rate_mpps"]), cfg)
        for tag, b in (("fixed", bs), ("adaptive", ba)):
            lat_b, lat_e = float(b.mean_latency_us[i]), rs.mean_sojourn_us
            cpu_b, cpu_e = float(b.cpu_fraction[i]), rs.cpu_fraction
            assert abs(lat_b - lat_e) <= max(ILAT_ABS_US,
                                             ILAT_REL * lat_e), \
                (tag, p, lat_b, lat_e)
            assert abs(cpu_b - cpu_e) <= ICPU_ABS + ICPU_REL * cpu_e, \
                (tag, p, cpu_b, cpu_e)
            assert abs(float(b.loss_fraction[i]) - rs.loss_fraction) \
                <= ILOSS_ABS, \
                (tag, p, float(b.loss_fraction[i]), rs.loss_fraction)
            assert b.wakeups[i] == pytest.approx(rs.wakeups, rel=0.15)
        # adaptive-vs-fixed inside the same interference band
        lat_f, lat_a = float(bs.mean_latency_us[i]), \
            float(ba.mean_latency_us[i])
        assert abs(lat_a - lat_f) <= max(ILAT_ABS_US, ILAT_REL * lat_f), \
            (p, lat_a, lat_f)
        assert abs(float(ba.cpu_fraction[i]) - float(bs.cpu_fraction[i])) \
            <= ICPU_ABS + ICPU_REL * float(bs.cpu_fraction[i]), p
        assert abs(float(ba.loss_fraction[i])
                   - float(bs.loss_fraction[i])) <= ILOSS_ABS, p


def test_interference_increases_latency_and_loss_vs_quiet_baseline():
    """Directional sanity on the batched engine itself: switching the
    noisy-host environment on strictly raises mean vacation, mean
    sojourn, and loss over the quiet baseline at fixed grid/seed."""
    pts = [dict(t_s_us=12.0, t_l_us=300.0, m=3, rate_mpps=0.5 * 29.76,
                seed=s) for s in range(3)]
    quiet = SimRunConfig(duration_us=60_000.0, sleep_model=HR_SLEEP_MODEL)
    noisy = SimRunConfig(duration_us=60_000.0, sleep_model=HR_SLEEP_MODEL,
                         **INTERFERENCE_ENV)
    bq = simulate_batch(SweepGrid.of_points(pts), quiet, slot_us=0.5)
    bn = simulate_batch(SweepGrid.of_points(pts), noisy, slot_us=0.5)
    assert np.all(bn.mean_vacation_us > bq.mean_vacation_us)
    assert np.all(bn.mean_latency_us > bq.mean_latency_us)
    assert float(bn.loss_fraction.mean()) > float(bq.loss_fraction.mean())


def test_thousand_point_sweep_is_one_compiled_call():
    """A >= 1000-point grid runs through a single jit-compiled function
    (one compilation, one vmapped call) and returns finite, load-ordered
    metrics."""
    from repro.runtime.batched import _compiled_sweep

    grid = SweepGrid.product(
        t_s_us=np.linspace(4.0, 40.0, 8),
        t_l_us=[150.0, 500.0],
        m=[2, 3, 4],
        rate_mpps=np.linspace(2.0, 25.0, 9),
        seeds=(0, 1, 2))
    assert len(grid) >= 1000
    before = _compiled_sweep.cache_info()
    bs = simulate_batch(grid, SimRunConfig(duration_us=10_000.0),
                        slot_us=1.0)
    after = _compiled_sweep.cache_info()
    # at most one new compilation for the whole batch — the sweep is one
    # call, not a per-point loop
    assert after.misses <= before.misses + 1
    assert len(bs) == len(grid)
    for name in ("mean_latency_us", "cpu_fraction", "loss_fraction",
                 "mean_vacation_us", "wakeups"):
        assert np.isfinite(getattr(bs, name)).all(), name
    # CPU grows with offered load on average (marginalize everything else)
    cpu = bs.reshaped("cpu_fraction").mean(axis=(0, 1, 2, 3, 5))
    assert np.all(np.diff(cpu) > 0)
    # and with more threads at fixed everything else
    cpu_m = bs.reshaped("cpu_fraction").mean(axis=(0, 1, 3, 4, 5))
    assert cpu_m[-1] > cpu_m[0]


def test_batched_latency_matches_closed_form_in_stable_region():
    """Satellite property: batched mean latency within tolerance of the
    E[V^2]/(2 E[V]) closed form (high-load regime, perfect timers)."""
    pts = []
    for t_s in (10.0, 20.0, 40.0):
        for m in (1, 2, 3):
            pts.append(dict(t_s_us=t_s, t_l_us=20.0 * t_s, m=m,
                            rate_mpps=0.5 * 29.76, seed=7))
    cfg = SimRunConfig(duration_us=100_000.0,
                       sleep_model=PERFECT_SLEEP_MODEL)
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5)
    for i, p in enumerate(pts):
        pred = float(an.mean_sojourn_high(p["t_s_us"], p["t_l_us"], p["m"]))
        got = float(bs.mean_latency_us[i])
        assert got == pytest.approx(pred, rel=0.25), (p, got, pred)


def test_batched_mean_vacation_tracks_eq6():
    """High load, T_L >> T_S: measured mean vacation ~= Eq (6)."""
    pts = [dict(t_s_us=10.0, t_l_us=500.0, m=m, rate_mpps=14.88, seed=3)
           for m in (1, 2, 3, 4)]
    cfg = SimRunConfig(duration_us=100_000.0,
                       sleep_model=PERFECT_SLEEP_MODEL)
    bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5)
    for i, p in enumerate(pts):
        pred = float(an.mean_vacation_high(10.0, 500.0, p["m"]))
        assert float(bs.mean_vacation_us[i]) == pytest.approx(pred,
                                                              rel=0.15)


def test_multi_queue_batched_accounting():
    """n_queues > 1: offered tracks the rate, nothing is lost at light
    load, and CPU stays below one thread-count's worth."""
    grid = SweepGrid.of_points([
        dict(t_s_us=15.0, t_l_us=300.0, m=4, n_queues=4,
             rate_mpps=10.0, seed=0)])
    cfg = SimRunConfig(duration_us=50_000.0)
    bs = simulate_batch(grid, cfg, slot_us=0.5)
    assert bs.offered[0] == pytest.approx(10.0 * 50_000.0, rel=0.05)
    assert float(bs.loss_fraction[0]) < 1e-3
    assert 0.0 < float(bs.cpu_fraction[0]) < 4.0
    assert float(bs.serviced[0]) <= bs.offered[0]


def test_to_run_stats_conversion():
    grid = SweepGrid.of_points([
        dict(t_s_us=10.0, t_l_us=500.0, m=3, rate_mpps=14.88, seed=0)])
    cfg = SimRunConfig(duration_us=30_000.0)
    bs = simulate_batch(grid, cfg, slot_us=0.5)
    rs = bs.to_run_stats(0)
    assert rs.backend == "batched"
    assert rs.items == int(bs.serviced[0])
    assert rs.cpu_fraction == pytest.approx(float(bs.cpu_fraction[0]),
                                            rel=1e-3)
    assert rs.mean_latency_us == pytest.approx(
        float(bs.mean_latency_us[0]), rel=1e-6)
    assert rs.mean_sojourn_us == pytest.approx(
        float(bs.mean_latency_us[0]), rel=1e-3)
    s = rs.summary()
    assert s["backend"] == "batched"
    assert s["cpu_fraction"] == pytest.approx(rs.cpu_fraction)


def test_batched_rejects_event_engine_only_features_eagerly():
    """Remaining event-engine-only config fields fail fast — by name, at
    validation time, before any compilation — and interference configs
    (once rejected here) are now accepted."""
    from repro.runtime.batched import (
        unsupported_config_fields,
        validate_batched_config,
    )

    grid = SweepGrid.of_points([dict(t_s_us=10.0, t_l_us=100.0, m=2,
                                     rate_mpps=1.0, seed=0)])
    bad = SimRunConfig(duration_us=1_000.0, timeseries_bin_us=100.0)
    assert unsupported_config_fields(bad) == ["timeseries_bin_us"]
    with pytest.raises(ValueError, match="timeseries_bin_us"):
        validate_batched_config(bad)
    with pytest.raises(ValueError, match="timeseries_bin_us"):
        simulate_batch(grid, bad)
    # interference/stall environments are first-class now
    ok = SimRunConfig(duration_us=1_000.0, interference_prob=0.1,
                      interference_mean_us=10.0,
                      stall_rate_per_us=1e-4, stall_mean_us=50.0)
    assert unsupported_config_fields(ok) == []
    bs = simulate_batch(grid, ok, slot_us=1.0)
    assert np.isfinite(bs.mean_latency_us).all()


def test_sweep_grid_product_shape_and_point():
    grid = SweepGrid.product(t_s_us=[5.0, 10.0], t_l_us=[100.0],
                             m=[2, 3], rate_mpps=[1.0, 2.0, 3.0],
                             seeds=(0, 1))
    assert len(grid) == 2 * 1 * 2 * 1 * 3 * 2
    assert grid.shape == (2, 1, 2, 1, 3, 2)
    p = grid.point(0)
    assert set(p) == set(grid.dims)
    # reshaped round-trips the cartesian structure
    cfg = SimRunConfig(duration_us=2_000.0)
    bs = simulate_batch(grid, cfg, slot_us=1.0)
    assert bs.reshaped("cpu_fraction").shape == grid.shape
