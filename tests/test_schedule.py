"""LoadSchedule kinds, the time-warping ScheduledWorkload, the windowed
adaptation series on both engines, and the shared TrackingStats
computation (the nonstationary-traffic tier's fast tests)."""

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    BusyPollPolicy,
    CBRWorkload,
    MetronomePolicy,
    MMPPSchedule,
    PoissonWorkload,
    RampSchedule,
    ScheduledWorkload,
    SimRunConfig,
    SinusoidSchedule,
    StepSchedule,
    Workload,
    from_trace,
    simulate_run,
)
from repro.runtime.stats import WindowedSeries


# ---------------------------------------------------------------------------
# schedule kinds
# ---------------------------------------------------------------------------

def test_step_schedule_lookup_integral_inverse():
    s = StepSchedule(times_us=(0.0, 10_000.0, 30_000.0),
                     scales=(1.0, 2.0, 0.5))
    assert s.scale_at(5_000.0) == 1.0
    assert s.scale_at(10_000.0) == 2.0          # right-continuous
    assert s.scale_at(50_000.0) == 0.5
    # integral is piecewise linear and exact
    assert s.integral(10_000.0) == pytest.approx(10_000.0)
    assert s.integral(30_000.0) == pytest.approx(10_000.0 + 2.0 * 20_000.0)
    assert s.integral(40_000.0) == pytest.approx(50_000.0 + 0.5 * 10_000.0)
    # inverse round-trips
    for t in (0.0, 3_000.0, 10_000.0, 25_000.0, 39_000.0):
        assert s.inverse_integral(s.integral(t),
                                  hint_until_us=50_000.0) == pytest.approx(t)
    assert s.transitions(40_000.0) == (10_000.0, 30_000.0)


def test_step_schedule_validation():
    with pytest.raises(ValueError):
        StepSchedule(times_us=(1.0,), scales=(1.0,))        # t0 != 0
    with pytest.raises(ValueError):
        StepSchedule(times_us=(0.0, 5.0, 5.0), scales=(1.0, 2.0, 3.0))
    with pytest.raises(ValueError):
        StepSchedule(times_us=(0.0,), scales=(-0.1,))


def test_ramp_schedule_staircase_and_transitions():
    r = RampSchedule(t_start_us=10_000.0, t_end_us=20_000.0,
                     scale_from=0.5, scale_to=1.5, n_steps=10)
    assert r.scale_at(0.0) == 0.5
    assert r.scale_at(25_000.0) == 1.5
    mid = r.scale_at(15_000.0)
    assert 0.5 < mid < 1.5
    # staircase is monotone along the ramp
    vals = [r.scale_at(t) for t in np.linspace(10_000.0, 20_000.0, 21)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    # ramp integral ~ trapezoid (staircase midpoint rule is exact here)
    assert r.integral(20_000.0) == pytest.approx(
        0.5 * 10_000.0 + (0.5 + 1.5) / 2 * 10_000.0, rel=1e-9)
    assert r.transitions(60_000.0) == (10_000.0, 20_000.0)


def test_sinusoid_schedule_periodic_and_mean_preserving():
    s = SinusoidSchedule(period_us=10_000.0, amplitude=0.5, mean=1.0,
                         steps_per_period=32)
    # exactly periodic
    assert s.scale_at(2_500.0) == pytest.approx(s.scale_at(12_500.0))
    # one full period integrates to the mean
    assert s.integral(10_000.0) / 10_000.0 == pytest.approx(1.0, abs=1e-9)
    # never negative even when amplitude > mean
    deep = SinusoidSchedule(period_us=1_000.0, amplitude=2.0, mean=1.0)
    assert min(deep.segments(5_000.0)[1]) == 0.0
    assert s.transitions(50_000.0) == ()


def test_mmpp_schedule_deterministic_replay():
    a = MMPPSchedule(states=(0.5, 1.0, 2.0), mean_dwell_us=5_000.0, seed=7)
    b = MMPPSchedule(states=(0.5, 1.0, 2.0), mean_dwell_us=5_000.0, seed=7)
    ea, va = a.segments(100_000.0)
    eb, vb = b.segments(100_000.0)
    np.testing.assert_allclose(ea, eb)
    np.testing.assert_allclose(va, vb)
    assert a == b                              # env-record equality
    # never self-jumps, and only visits declared states
    assert all(x != y for x, y in zip(va, va[1:]))
    assert set(va) <= {0.5, 1.0, 2.0}
    c = MMPPSchedule(states=(0.5, 1.0, 2.0), mean_dwell_us=5_000.0, seed=8)
    assert not np.array_equal(c.segments(100_000.0)[0], ea)


def test_from_trace_builds_relative_step_schedule():
    s = from_trace([0.0, 1_000.0, 3_000.0], [5.0, 10.0, 2.5],
                   base_rate_mpps=5.0)
    assert s.scale_at(500.0) == 1.0
    assert s.scale_at(2_000.0) == 2.0
    assert s.scale_at(10_000.0) == 0.5
    assert s.name == "trace"


def test_compiled_fixed_width_padding_and_resampling():
    s = StepSchedule(times_us=(0.0, 10_000.0), scales=(1.0, 2.0))
    edges, scales = s.compiled(40_000.0, max_segments=8)
    assert edges.shape == scales.shape == (8,)
    assert np.all(np.diff(edges) > 0)          # strictly increasing
    assert scales[-1] == 2.0                   # padded with last value
    # denser than the cap: resampled to window means, width preserved
    sin = SinusoidSchedule(period_us=1_000.0, steps_per_period=64)
    e2, v2 = sin.compiled(100_000.0, max_segments=16)
    assert e2.shape == v2.shape == (16,)


# ---------------------------------------------------------------------------
# ScheduledWorkload: time warping
# ---------------------------------------------------------------------------

def test_scheduled_workload_satisfies_protocol_and_name():
    wl = ScheduledWorkload(PoissonWorkload(5.0),
                           StepSchedule(times_us=(0.0,), scales=(2.0,)))
    assert isinstance(wl, Workload)
    assert wl.name.startswith("poisson@step")


def test_scheduled_poisson_counts_follow_the_schedule():
    s = StepSchedule(times_us=(0.0, 50_000.0), scales=(0.5, 2.0))
    wl = ScheduledWorkload(PoissonWorkload(4.0), s)
    wl.reset(np.random.default_rng(0))
    lo = sum(wl.counts_in(t, t + 1_000.0) for t in range(0, 50_000, 1_000))
    hi = sum(wl.counts_in(t, t + 1_000.0)
             for t in range(50_000, 100_000, 1_000))
    assert lo / 50_000.0 == pytest.approx(2.0, rel=0.05)     # 4 * 0.5
    assert hi / 50_000.0 == pytest.approx(8.0, rel=0.05)     # 4 * 2.0
    assert wl.rate_at(10_000.0) == pytest.approx(2.0)
    assert wl.rate_at(60_000.0) == pytest.approx(8.0)


def test_scheduled_cbr_is_exact_time_warp():
    # CBR at rate 1/100us, scale 2 -> one arrival every 50us exactly
    s = StepSchedule(times_us=(0.0,), scales=(2.0,))
    wl = ScheduledWorkload(CBRWorkload(0.01), s)
    wl.reset(np.random.default_rng(0))
    assert wl.counts_in(0.0, 1_000.0) == 20
    ts = list(wl.iter_arrivals(500.0, np.random.default_rng(0)))
    assert ts == pytest.approx([50.0 * k for k in range(1, 10)])


def test_scheduled_iter_arrivals_rate_tracks_schedule():
    s = StepSchedule(times_us=(0.0, 20_000.0), scales=(1.0, 3.0))
    wl = ScheduledWorkload(PoissonWorkload(2.0), s)
    ts = np.asarray(list(wl.iter_arrivals(40_000.0,
                                          np.random.default_rng(3))))
    lo = (ts < 20_000.0).sum() / 20_000.0
    hi = (ts >= 20_000.0).sum() / 20_000.0
    assert lo == pytest.approx(2.0, rel=0.1)
    assert hi == pytest.approx(6.0, rel=0.1)
    assert np.all(np.diff(ts) >= 0)


# ---------------------------------------------------------------------------
# engines: schedule + windowed series
# ---------------------------------------------------------------------------

STEP = StepSchedule(times_us=(0.0, 20_000.0), scales=(0.4, 1.2))


def test_event_engine_windows_conserve_totals_and_track_load():
    cfg = SimRunConfig(duration_us=40_000.0, schedule=STEP,
                       window_us=2_000.0, seed=2)
    rs = simulate_run(MetronomePolicy(MetronomeConfig()),
                      PoissonWorkload(0.5 * 29.76), cfg)
    w = rs.windows
    assert w is not None and w.n_windows == 20
    # conservation: windowed sums (plus the post-duration spill, e.g.
    # the final drain) equal the run totals
    assert w.offered.sum() + w.spill_offered == pytest.approx(rs.offered)
    assert w.served.sum() + w.spill_served == pytest.approx(rs.items)
    assert (w.awake_us.sum() + w.spill_awake_us) * 1e3 \
        == pytest.approx(rs.awake_ns, rel=1e-6, abs=1e3)
    assert w.lat_area_us.sum() + w.spill_lat_area_us \
        == pytest.approx(rs.latency_area_us, rel=1e-6)
    assert w.energy_uj.sum() + w.spill_energy_uj \
        == pytest.approx(rs.energy_uj, rel=1e-6)
    # true rho follows the schedule; the EWMA estimate tracks it
    assert w.rho_true[:10].mean() == pytest.approx(0.5 * 0.4, rel=0.15)
    assert w.rho_true[10:].mean() == pytest.approx(0.5 * 1.2, rel=0.15)
    est_err = np.abs(w.rho_est[12:] - w.rho_true[12:])
    assert np.nanmean(est_err) < 0.08
    # schedule descriptor is stamped on the stats and its summary
    assert rs.schedule.startswith("step[")
    assert rs.summary()["schedule"] == rs.schedule


def test_event_engine_stationary_run_has_no_windows_and_no_schedule():
    cfg = SimRunConfig(duration_us=10_000.0)
    rs = simulate_run(MetronomePolicy(MetronomeConfig()),
                      PoissonWorkload(5.0), cfg)
    assert rs.windows is None
    assert rs.schedule == ""


def test_spin_model_windows_burn_flat_core_under_any_schedule():
    cfg = SimRunConfig(duration_us=40_000.0, schedule=STEP,
                       window_us=2_000.0)
    rs = simulate_run(BusyPollPolicy(), PoissonWorkload(0.5 * 29.76), cfg)
    w = rs.windows
    np.testing.assert_allclose(w.cpu_fraction, 1.0)
    # but the offered rate still follows the schedule
    assert w.offered_mpps[-1] > 2.0 * w.offered_mpps[0]
    assert rs.schedule.startswith("step[")


def test_golden_stationary_run_unchanged_by_feature():
    """The nonstationary plumbing must not disturb the stationary event
    sequence: schedule=None + window_us=0 reproduces the exact counters
    of a pre-feature run at the same seed."""
    cfg = SimRunConfig(duration_us=30_000.0, seed=5)
    a = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(10.0), cfg)
    b = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(10.0), cfg)
    for f in ("wakeups", "cycles", "items", "offered", "dropped",
              "awake_ns"):
        assert getattr(a, f) == getattr(b, f)
    # windowed twin at the same seed: same totals as the plain run
    cfg_w = SimRunConfig(duration_us=30_000.0, seed=5, window_us=3_000.0)
    c = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(10.0), cfg_w)
    for f in ("wakeups", "cycles", "items", "offered", "dropped"):
        assert getattr(a, f) == getattr(c, f), f


# ---------------------------------------------------------------------------
# WindowedSeries / TrackingStats (shared computation)
# ---------------------------------------------------------------------------

def _series(lat, offered=None, window_us=1_000.0, mu=29.76):
    lat = np.asarray(lat, dtype=np.float64)
    served = np.full(lat.size, 100.0)
    offered = (np.asarray(offered, dtype=np.float64)
               if offered is not None else served.copy())
    return WindowedSeries(
        window_us=window_us, service_rate_mpps=mu,
        offered=offered, served=served, lat_area_us=lat * served,
        awake_us=np.full(lat.size, 500.0))


def test_tracking_convergence_and_overshoot():
    # settled at 10, transition at 5ms -> spike to 30 decaying to 12
    lat = [10.0] * 5 + [30.0, 20.0, 14.0, 12.0, 12.0, 12.0, 12.0]
    tk = _series(lat).tracking([5_000.0], target_latency_us=25.0)
    assert tk.transitions_us == (5_000.0,)
    # settled post-step value = 12; band = max(2, .25*12) = 3 -> the
    # first in-band window is index 7 (14.0), so convergence = 3 windows
    assert tk.convergence_us == (3_000.0,)
    assert tk.mean_convergence_us == 3_000.0
    assert tk.max_overshoot_us == pytest.approx(30.0 - 12.0)
    assert tk.violation_fraction == pytest.approx(1.0 / 12.0)
    assert np.isnan(tk.rho_rmse)               # no controller samples


def test_tracking_never_converges_is_nan():
    lat = [10.0] * 4 + [50.0, 45.0, 55.0, 50.0, 60.0, 40.0, 55.0, 65.0]
    tk = _series(lat).tracking([4_000.0], target_latency_us=100.0)
    assert np.isnan(tk.convergence_us[0]) or tk.convergence_us[0] > 0
    # a flat tail can settle; assert only the API shape here
    assert len(tk.convergence_us) == 1


def test_tracking_violation_fraction_counts_all_windows():
    lat = [10.0, 20.0, 30.0, 40.0]
    tk = _series(lat).tracking([], target_latency_us=25.0)
    assert tk.violation_fraction == pytest.approx(0.5)
    assert tk.transitions_us == ()
    assert np.isnan(tk.mean_convergence_us)


def test_windowed_series_merge_pools_accumulators():
    a = _series([10.0, 20.0])
    b = _series([30.0, 40.0])
    a.merge(b)
    assert a.served[0] == 200.0
    assert a.mean_latency_us[0] == pytest.approx(20.0)   # (10+30)/2 pooled
    with pytest.raises(ValueError):
        a.merge(_series([1.0, 2.0, 3.0]))


def test_run_stats_merge_pools_windows():
    cfg = SimRunConfig(duration_us=20_000.0, window_us=2_000.0, seed=0)
    a = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(5.0), cfg)
    b = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(5.0),
                     SimRunConfig(duration_us=20_000.0, window_us=2_000.0,
                                  seed=1))
    tot = a.windows.offered.sum() + b.windows.offered.sum()
    a.merge(b)
    assert a.windows.offered.sum() == pytest.approx(tot)
    # mismatched grids drop the series instead of corrupting it
    c = simulate_run(MetronomePolicy(MetronomeConfig()),
                     PoissonWorkload(5.0),
                     SimRunConfig(duration_us=20_000.0, window_us=5_000.0,
                                  seed=2))
    a.merge(c)
    assert a.windows is None
